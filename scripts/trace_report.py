#!/usr/bin/env python
"""Latency-breakdown report over a recorded trace.

    python scripts/trace_report.py TRACE [--top K] [--max-rows N] [--json]

``TRACE`` is either

* a **Perfetto / Chrome-trace JSON** written by
  :func:`repro.obs.perfetto.export_perfetto` — the report validates the
  exporter's schema first (exit nonzero on violations, which is what makes
  the exporter CI-checkable) and recomputes the per-session breakdown from
  the exported ``X`` slices' ``args: {sid, plane, kind}``, or
* an **events JSONL** dump (:func:`repro.obs.trace.dump_events_jsonl`) —
  replayed through the :class:`~repro.obs.trace.Tracer` state machine.

Either way the output is the per-session latency-breakdown table, the
fleet-level per-plane aggregate, and the top-k critical-path segments.

Lossy traces (the source ring evicted events: nonzero ``dropped`` in the
JSONL ``trace_meta`` header or the Perfetto ``otherData.dropped_events``)
print a warning — every exclusive-timeline number is then a lower bound.
``--strict`` turns the warning into exit code 2 (CI gates the nightly
full-fidelity export with it; flight-recorder bundles are ring-truncated
by design and are smoked *without* it).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from repro.obs.trace import (PLANES, Tracer, breakdown_table,
                             load_events_jsonl)

_REQUIRED_BY_PH = {
    "X": ("name", "pid", "tid", "ts", "dur"),
    "M": ("name", "pid", "args"),
    "C": ("name", "pid", "ts", "args"),
    "b": ("name", "pid", "tid", "ts", "id", "cat"),
    "e": ("name", "pid", "tid", "ts", "id", "cat"),
    "i": ("name", "pid", "tid", "ts"),
}


def validate_perfetto(trace: dict) -> List[str]:
    """Schema check for the exporter's output; returns human-readable
    violations (empty list == valid)."""
    errs: List[str] = []
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    procs = set()
    async_open: Dict[Tuple, int] = {}
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in _REQUIRED_BY_PH:
            errs.append(f"event {i}: unknown ph {ph!r}")
            continue
        missing = [k for k in _REQUIRED_BY_PH[ph] if k not in e]
        if missing:
            errs.append(f"event {i} (ph={ph}): missing {missing}")
            continue
        if "ts" in e and (not isinstance(e["ts"], (int, float))
                          or e["ts"] < 0):
            errs.append(f"event {i}: bad ts {e['ts']!r}")
        if ph == "X":
            if not isinstance(e["dur"], (int, float)) or e["dur"] < 0:
                errs.append(f"event {i}: bad dur {e.get('dur')!r}")
            args = e.get("args", {})
            if "sid" in args:
                for k in ("plane", "kind"):
                    if k not in args:
                        errs.append(f"event {i}: session slice missing "
                                    f"args.{k}")
                if args.get("plane") not in PLANES:
                    errs.append(f"event {i}: unknown plane "
                                f"{args.get('plane')!r}")
        elif ph == "M":
            if e["name"] == "process_name":
                procs.add(e["pid"])
        elif ph == "C":
            if "value" not in e.get("args", {}):
                errs.append(f"event {i}: counter without args.value")
        elif ph == "b":
            async_open[(e["pid"], e["cat"], e["id"], e["name"])] = (
                async_open.get((e["pid"], e["cat"], e["id"], e["name"]), 0)
                + 1)
        elif ph == "e":
            key = (e["pid"], e["cat"], e["id"], e["name"])
            if async_open.get(key, 0) <= 0:
                errs.append(f"event {i}: async end without begin {key}")
            else:
                async_open[key] -= 1
    for key, n in async_open.items():
        if n > 0:
            errs.append(f"async begin without end: {key} x{n}")
    if not procs:
        errs.append("no process_name metadata (expected one per replica)")
    for e in evs:
        if "pid" in e and e["pid"] not in procs:
            errs.append(f"event references unnamed pid {e['pid']}")
            break
    od = trace.get("otherData", {})
    if "generator" not in od:
        errs.append("otherData.generator missing")
    return errs


def rows_from_perfetto(trace: dict, top: int = 5) -> List[dict]:
    """Recompute critical-path rows from the exported session slices."""
    by_sid: Dict[int, List[dict]] = {}
    for e in trace["traceEvents"]:
        if e.get("ph") != "X":
            continue
        args = e.get("args", {})
        if "sid" not in args or "plane" not in args:
            continue   # tick slices etc.
        by_sid.setdefault(args["sid"], []).append(e)
    rows = []
    for sid, slices in sorted(by_sid.items()):
        buckets = dict.fromkeys(PLANES, 0.0)
        segs = []
        for e in slices:
            dur_s = e["dur"] / 1e6
            buckets[e["args"]["plane"]] += dur_s
            segs.append({"kind": e["args"]["kind"],
                         "plane": e["args"]["plane"], "dur": dur_s,
                         "start": e["ts"] / 1e6,
                         "round": e["args"].get("round", 0)})
        segs.sort(key=lambda s: -s["dur"])
        e2e = sum(buckets.values())
        rows.append({
            "sid": sid, "e2e": e2e, "buckets": buckets,
            "bucket_frac": {k: (v / e2e if e2e > 0 else 0.0)
                            for k, v in buckets.items()},
            "dominant_bucket": max(buckets, key=buckets.get),
            "dominant": segs[0] if segs else None,
            "top_segments": segs[:top],
        })
    return rows


def rows_from_jsonl(path: str, top: int = 5) -> Tuple[List[dict], int]:
    """(critical-path rows, upstream dropped-event count). The dump's
    ``trace_meta`` header carries the source ring's eviction counter."""
    events = load_events_jsonl(path)
    dropped = sum(int(e.data.get("dropped", 0)) for e in events
                  if e.kind == "trace_meta")
    tr = Tracer.replay(events)
    return ([tr.critical_path(sid, top=top)
             for sid in tr.finished_sids()], dropped)


def top_segments(rows: List[dict], k: int) -> List[dict]:
    segs = []
    for r in rows:
        for s in r.get("top_segments", []):
            segs.append({**s, "sid": r["sid"]})
    segs.sort(key=lambda s: -s["dur"])
    return segs[:k]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Perfetto JSON or events JSONL")
    ap.add_argument("--top", type=int, default=10,
                    help="top-k critical-path segments to list")
    ap.add_argument("--max-rows", type=int, default=20,
                    help="session rows to show in the table")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of tables")
    ap.add_argument("--strict", action="store_true",
                    help="exit 2 if the trace is lossy (dropped events "
                         "upstream — timelines are lower bounds)")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        head = f.read(1)
    is_perfetto = False
    if head == "{":
        with open(args.trace) as f:
            try:
                doc = json.load(f)
                is_perfetto = isinstance(doc, dict) and "traceEvents" in doc
            except json.JSONDecodeError:
                is_perfetto = False
    if is_perfetto:
        errs = validate_perfetto(doc)
        if errs:
            for e in errs[:50]:
                print(f"SCHEMA VIOLATION: {e}", file=sys.stderr)
            print(f"{len(errs)} schema violation(s) in {args.trace}",
                  file=sys.stderr)
            return 1
        rows = rows_from_perfetto(doc, top=args.top)
        dropped = int(doc.get("otherData", {}).get("dropped_events", 0))
        src = "perfetto"
    else:
        rows, dropped = rows_from_jsonl(args.trace, top=args.top)
        src = "jsonl"
    rows = [r for r in rows if r is not None]

    if dropped:
        print(f"WARNING: lossy trace — {dropped} event(s) evicted from the "
              f"source ring before export; timelines are lower bounds",
              file=sys.stderr)
        if args.strict:
            print("--strict: failing on lossy trace", file=sys.stderr)
            return 2

    tops = top_segments(rows, args.top)
    if args.json:
        print(json.dumps({"source": src, "sessions": len(rows),
                          "dropped_events": dropped,
                          "rows": rows, "top_segments": tops}, indent=1))
        return 0
    print(f"# {args.trace} ({src}): {len(rows)} finished sessions")
    if not rows:
        print("no finished sessions in trace")
        return 0
    print()
    print(breakdown_table(rows, max_rows=args.max_rows))
    print()
    print(f"top {len(tops)} critical-path segments:")
    for s in tops:
        print(f"  {s['dur']:>9.3f}s  {s['kind']:<13} plane={s['plane']:<8}"
              f" sid={s['sid']} r{s.get('round', 0)} @{s['start']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
