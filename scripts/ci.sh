#!/usr/bin/env bash
# CI pipeline: hygiene gates, tier-1 test suite, benchmark smokes.
# Mirrors ROADMAP.md "Tier-1 verify"; runs hermetically (no network,
# hypothesis optional — tests fall back to tests/_hypo.py).
#
# Env knobs (all optional):
#   PYTEST_JUNIT=path.xml  write a junit report (uploaded as a CI artifact)
#   PYTEST_MARKS=<expr>    override the default marker expression; set it
#                          EMPTY for the nightly-style full set:
#                          PYTEST_MARKS= bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# hygiene: no tracked bytecode (regression guard for the PR 2 purge — a
# tracked .pyc shadows its .py at import time and is invisible in review)
if git ls-files | grep -E '(\.pyc$|(^|/)__pycache__(/|$))'; then
    echo "ERROR: tracked bytecode files found (listed above)" >&2
    exit 1
fi

# fast syntax gate: a SyntaxError fails in seconds, not after the suite
python -m compileall -q src

python -m pytest -x -q ${PYTEST_JUNIT:+--junitxml="$PYTEST_JUNIT"} \
    ${PYTEST_MARKS+-m "$PYTEST_MARKS"}

python benchmarks/kernel_bench.py --dry
python benchmarks/kvcache_bench.py --dry
python benchmarks/paged_runner_bench.py --dry
python benchmarks/swap_stream_bench.py --dry
