#!/usr/bin/env bash
# CI pipeline: hygiene gates, lint, tier-1 test suite, benchmark smokes,
# bench-regression gate. Mirrors ROADMAP.md "Tier-1 verify"; runs
# hermetically (no network, hypothesis optional — tests fall back to
# tests/_hypo.py; ruff optional — enforced where requirements-dev.txt is
# installed, i.e. the GitHub workflows).
#
# Env knobs (all optional):
#   PYTEST_JUNIT=path.xml  write a junit report (uploaded as a CI artifact)
#   PYTEST_MARKS=<expr>    override the default marker expression; set it
#                          EMPTY for the nightly-style full set:
#                          PYTEST_MARKS= bash scripts/ci.sh
#   BENCH_JSON_DIR=dir     where the benchmark --json outputs land
#                          (default: a mktemp dir; uploaded by nightly)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# hygiene: no tracked bytecode (regression guard for the PR 2 purge — a
# tracked .pyc shadows its .py at import time and is invisible in review)
if git ls-files | grep -E '(\.pyc$|(^|/)__pycache__(/|$))'; then
    echo "ERROR: tracked bytecode files found (listed above)" >&2
    exit 1
fi

# lint gate (ruff pinned in requirements-dev.txt, config in pyproject.toml);
# skipped only where dev deps can't be installed (hermetic local images)
if command -v ruff >/dev/null 2>&1; then
    ruff check src benchmarks scripts tests examples
else
    echo "NOTE: ruff not installed; lint gate skipped (CI enforces it)"
fi

# fast syntax gate: a SyntaxError fails in seconds, not after the suite
python -m compileall -q src

python -m pytest -x -q ${PYTEST_JUNIT:+--junitxml="$PYTEST_JUNIT"} \
    ${PYTEST_MARKS+-m "$PYTEST_MARKS"}

# benchmark smokes emit machine-readable metrics; check_bench gates them
# against committed baselines so a perf regression fails the PR here, not
# a reader of BENCH files three weeks later
BENCH_JSON_DIR="${BENCH_JSON_DIR:-$(mktemp -d)}"
mkdir -p "$BENCH_JSON_DIR"
python benchmarks/kernel_bench.py --dry --json "$BENCH_JSON_DIR/kernel.json"
python benchmarks/kvcache_bench.py --dry --json "$BENCH_JSON_DIR/kvcache.json"
python benchmarks/paged_runner_bench.py --dry --json "$BENCH_JSON_DIR/paged_runner.json"
python benchmarks/swap_stream_bench.py --dry --json "$BENCH_JSON_DIR/swap_stream.json"
python benchmarks/cross_replica_bench.py --dry --json "$BENCH_JSON_DIR/cross_replica.json"
python benchmarks/tiered_store_bench.py --dry --json "$BENCH_JSON_DIR/tiered_store.json"
python benchmarks/continuous_batching_bench.py --dry --json "$BENCH_JSON_DIR/continuous_batching.json"
python benchmarks/cpu_contention_bench.py --dry --json "$BENCH_JSON_DIR/cpu_contention.json"
# obs bench also writes a Perfetto trace; trace_report validates the
# exporter's schema (nonzero exit on violations) and prints the breakdown.
# --strict: the full-fidelity export must not be lossy (dropped events)
python benchmarks/obs_overhead_bench.py --dry --json "$BENCH_JSON_DIR/obs.json" \
    --trace "$BENCH_JSON_DIR/obs_trace.json"
python scripts/trace_report.py "$BENCH_JSON_DIR/obs_trace.json" --max-rows 5 --strict
# incident plane: fault-injection detection recall/precision + clean-run
# false-positive gate; flight-recorder bundles land under BENCH_JSON_DIR
# (the workflow uploads them as artifacts)
python benchmarks/slo_bench.py --dry --json "$BENCH_JSON_DIR/slo.json" \
    --bundle-dir "$BENCH_JSON_DIR/slo_bundles"
# smoke trace_report over a recorder bundle (ring-truncated by design, so
# no --strict here — the dump replays to a partial timeline, not an error)
SLO_BUNDLE=$(find "$BENCH_JSON_DIR/slo_bundles" -name events.jsonl | sort | head -n1)
python scripts/trace_report.py "$SLO_BUNDLE" --max-rows 5
# docs hygiene: every relative link in README.md and docs/ must resolve
python scripts/check_docs_links.py
# the ten fresh files are named explicitly — a glob would also pick up
# stale/quick-config rows persisting in an externally-supplied dir (e.g.
# nightly's *-quick.json), and same-(figure,name) rows would shadow these
python scripts/check_bench.py --baselines benchmarks/baselines.json \
    "$BENCH_JSON_DIR"/kernel.json "$BENCH_JSON_DIR"/kvcache.json \
    "$BENCH_JSON_DIR"/paged_runner.json "$BENCH_JSON_DIR"/swap_stream.json \
    "$BENCH_JSON_DIR"/cross_replica.json "$BENCH_JSON_DIR"/tiered_store.json \
    "$BENCH_JSON_DIR"/obs.json "$BENCH_JSON_DIR"/continuous_batching.json \
    "$BENCH_JSON_DIR"/cpu_contention.json "$BENCH_JSON_DIR"/slo.json
