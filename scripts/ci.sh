#!/usr/bin/env bash
# Minimal CI smoke: tier-1 test suite + kernel entry-point smoke.
# Mirrors ROADMAP.md "Tier-1 verify"; runs hermetically (no network,
# hypothesis optional — tests fall back to tests/_hypo.py).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -x -q
python benchmarks/kernel_bench.py --dry
python benchmarks/kvcache_bench.py --dry
python benchmarks/paged_runner_bench.py --dry
