#!/usr/bin/env python
"""Docs hygiene gate: every relative markdown link in README.md and
docs/*.md must resolve to a real file/directory in the repo. External
http(s) links, mailto:, and pure #anchors are skipped."""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

bad = []
for md in [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]:
    for target in LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        if not (md.parent / target.split("#", 1)[0]).resolve().exists():
            bad.append(f"{md.relative_to(ROOT)}: dead link -> {target}")
for b in bad:
    print("FAIL  " + b)
if bad:
    sys.exit(1)
print("docs links ok")
