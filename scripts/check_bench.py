#!/usr/bin/env python
"""Benchmark-regression gate: compare bench ``--json`` outputs against
committed baselines with per-metric tolerances.

    python scripts/check_bench.py --baselines benchmarks/baselines.json \
        out/kernel.json out/kvcache.json ...

``benchmarks/baselines.json`` holds a list of checks:

    {"checks": [{"figure": ..., "name": ...,   # row selector
                 "field": ...,                 # metric key in that row
                 "baseline": <committed value>,
                 "min": v | "max": v |         # absolute bounds, and/or
                 "rel": r,                     # |value-baseline| <= r*|baseline|
                 "note": "..."}]}

A check fails when its row/field is missing from the collected outputs or
any stated tolerance is violated; all checks are evaluated (no fail-fast)
and the exit code gates CI — a perf regression fails the PR instead of
waiting for a human to diff BENCH numbers. ``--update`` rewrites each
check's ``baseline`` from the current rows (tolerances untouched) for
intentional re-baselining; the diff still goes through review.

Coverage is also enforced in the other direction: every ``figure`` that
appears in the collected bench outputs must have at least one check in the
baselines file. A brand-new bench wired into CI without a baseline entry
therefore *fails* instead of silently passing — new benches must be
baselined in the same PR that adds them.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple


def load_rows(paths: List[str]) -> List[dict]:
    rows: List[dict] = []
    for p in paths:
        with open(p) as f:
            data = json.load(f)
        rows.extend(data["rows"] if isinstance(data, dict) else data)
    return rows


def find_row(rows: List[dict], figure: str, name: str) -> Optional[dict]:
    for r in rows:
        if r.get("figure") == figure and r.get("name") == name:
            return r
    return None


def evaluate(check: dict, rows: List[dict]) -> Tuple[bool, str]:
    """(ok, human-readable detail) for one baseline check."""
    where = f"{check['figure']}/{check['name']}.{check['field']}"
    row = find_row(rows, check["figure"], check["name"])
    if row is None:
        return False, f"{where}: row missing from bench output"
    val = row.get(check["field"])
    if val is None:
        return False, f"{where}: field missing/null in bench output"
    probs = []
    if "min" in check and val < check["min"]:
        probs.append(f"{val} < min {check['min']}")
    if "max" in check and val > check["max"]:
        probs.append(f"{val} > max {check['max']}")
    if "rel" in check:
        base = check["baseline"]
        if base == 0:
            # rel-to-zero degenerates to exact-match ("any nonzero value
            # drifted"); flag the config loudly — including a baseline that
            # --update rewrote to 0 — instead of emitting confusing drift
            probs.append("rel tolerance is meaningless with baseline 0 "
                         "(use min/max bounds)")
        elif abs(val - base) > check["rel"] * abs(base):
            probs.append(f"{val} drifted > {check['rel']:.0%} from "
                         f"baseline {base}")
    if probs:
        return False, f"{where}: " + "; ".join(probs)
    return True, f"{where}: {val} ok (baseline {check.get('baseline')})"


def coverage_failures(spec: dict, rows: List[dict]) -> List[str]:
    """Figures present in the bench outputs but absent from the baselines
    — each is a gate hole (an unbaselined bench would silently pass)."""
    checked = {c["figure"] for c in spec["checks"]}
    emitted = {r.get("figure") for r in rows}
    out = []
    for fig in sorted(str(f) for f in emitted - checked):
        out.append(f"figure {fig!r}: bench emits rows but baselines.json "
                   f"has no check for it — baseline new benches in the "
                   f"same PR")
    return out


def update_baselines(spec: dict, rows: List[dict], path: str) -> None:
    for check in spec["checks"]:
        row = find_row(rows, check["figure"], check["name"])
        if row is not None and row.get(check["field"]) is not None:
            check["baseline"] = row[check["field"]]
    with open(path, "w") as f:
        json.dump(spec, f, indent=1)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("outputs", nargs="+",
                    help="bench --json output files to check")
    ap.add_argument("--baselines", default="benchmarks/baselines.json")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline values from the current rows "
                         "(tolerances untouched), then check")
    args = ap.parse_args(argv)
    with open(args.baselines) as f:
        spec = json.load(f)
    rows = load_rows(args.outputs)
    if args.update:
        update_baselines(spec, rows, args.baselines)
        print(f"baselines rewritten: {args.baselines}")
    failures = 0
    for check in spec["checks"]:
        ok, detail = evaluate(check, rows)
        print(("PASS  " if ok else "FAIL  ") + detail)
        failures += 0 if ok else 1
    uncovered = coverage_failures(spec, rows)
    for detail in uncovered:
        print("FAIL  " + detail)
    failures += len(uncovered)
    n_total = len(spec["checks"]) + len(uncovered)
    if failures:
        print(f"\n{failures}/{n_total} bench checks failed "
              f"(see {args.baselines} for tolerances)", file=sys.stderr)
        return 1
    print(f"\nall {len(spec['checks'])} bench checks passed "
          f"({len({c['figure'] for c in spec['checks']})} figures covered)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
