import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=" +
                               os.environ.get("REPRO_DRYRUN_DEVICES", "256")).strip()

"""Roofline analysis (deliverable g).

Reads dry-run records (or runs the cells) and derives the three terms per
(arch x shape) on the single-pod production mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis of the partitioned module is per-device, so these equal the
assignment's chips-normalized formulas.) Also reports MODEL_FLOPS = 6·N·D
(train) / 2·N_active·tokens (serve), the useful-compute ratio, the dominant
term, and a one-line lever.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
import argparse
import json
import sys
from typing import Dict, List, Optional

from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES
from repro.models import perf_model as pm

HW = pm.TPU_V5E


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return pm.train_flops(cfg, tokens)
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return pm.flops_per_token(cfg, spec.seq_len // 2) * tokens
    # decode: one token per sequence against seq_len context
    return pm.flops_per_token(cfg, spec.seq_len) * spec.global_batch


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    arch, shape = rec["arch"], rec["shape"]
    n_dev = rec["n_devices"]
    t_compute = rec.get("flops", 0.0) / HW.peak_flops
    t_memory = rec.get("bytes_accessed", 0.0) / HW.hbm_bw
    t_coll = rec.get("collectives", {}).get("total", 0.0) / HW.link_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    hlo_global = rec.get("flops", 0.0) * n_dev
    ratio = mf / hlo_global if hlo_global else float("nan")
    step_time = max(terms.values())
    useful_rate = mf / n_dev / max(step_time, 1e-12)
    frac = useful_rate / HW.peak_flops
    lever = {
        "compute": "raise MFU: larger fused matmul tiles / reduce remat "
                   "recompute / bf16 everywhere",
        "memory": "cut HBM traffic: fuse attention (flash), chunk the CE "
                  "loss, shrink logits dtype, cap local-layer KV",
        "collective": "reshard: fewer all-gathers (keep activations sharded),"
                      " overlap psum with compute, bf16 collectives",
    }[dominant]
    return {"arch": arch, "shape": shape, "n_devices": n_dev,
            "terms_s": {k: round(v, 6) for k, v in terms.items()},
            "dominant": dominant, "model_flops": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": round(ratio, 4),
            "roofline_fraction": round(frac, 4),
            "per_device_bytes": {
                "args": rec.get("argument_size_in_bytes"),
                "temp": rec.get("temp_size_in_bytes")},
            "lever": lever}


def table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        t = r["terms_s"]
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {t['compute']:10.4f} "
            f"{t['memory']:10.4f} {t['collective']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {100*r['roofline_fraction']:7.2f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default=None,
                    help="dryrun JSON report to analyze")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.report:
        with open(args.report) as f:
            records = json.load(f)
    else:
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=False)
        records = [run_cell(a, s, mesh=mesh)
                   for a in ARCH_IDS for s in SHAPES]
    rows = [a for a in (analyze(r) for r in records) if a]
    print(table(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
