import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

MUST be the process entry point (the XLA flag above is read at first jax
init, before any other import). For each cell it lowers the appropriate step
with sharded ShapeDtypeStruct inputs on the production mesh, compiles it,
and records memory_analysis / cost_analysis / per-device collective bytes
(parsed from the post-SPMD HLO) into a JSON report for the roofline pass.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
        --shape train_4k [--multi-pod] [--out report.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs.registry import ARCH_IDS, cell_is_supported, get_config
from repro.configs.shapes import SHAPES
from repro.distributed.steps import build_cell
from repro.launch.mesh import make_production_mesh

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind, from post-SPMD HLO.

    The compiled module is per-partition, so summed operand sizes are
    per-device traffic. Counts the *output* shape of each collective op
    (all-reduce: payload; all-gather: gathered result; etc.)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in s or f" {k}-start(" in s:
                kind = k
                break
        if kind is None:
            continue
        # output shape(s) sit between '=' and the op name:
        #   "%ar = bf16[4,128]{1,0} all-reduce(...)"
        head = s.split("=", 1)[1].split(kind)[0]
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(head):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    ok, why = cell_is_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": dict(zip(mesh.axis_names,
                                  [int(mesh.shape[a]) for a in mesh.axis_names])),
                 "n_devices": int(mesh.size)}
    try:
        fn, args, jkw = build_cell(cfg, spec, mesh)
        with mesh:
            lowered = jax.jit(fn, **jkw).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        if cost:
            rec["flops"] = float(cost.get("flops", 0.0))
            rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["status"] = "ok"
    except Exception as e:                         # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" flops={rec.get('flops', 0):.3e}"
                     f" argB={rec.get('argument_size_in_bytes', 0):.3e}"
                     f" tmpB={rec.get('temp_size_in_bytes', 0):.3e}"
                     f" collB={rec['collectives']['total']:.3e}"
                     f" compile={rec.get('compile_s')}s")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[dryrun] {arch} x {shape_name} "
              f"{'multi-pod' if multi_pod else 'single-pod'}: {status}{extra}",
              flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"[dryrun] mesh: {dict(zip(mesh.axis_names, [mesh.shape[a] for a in mesh.axis_names]))} "
          f"({mesh.size} devices, backend={jax.default_backend()})", flush=True)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    records = []
    for a, s in cells:
        records.append(run_cell(a, s, multi_pod=args.multi_pod, mesh=mesh))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    n_err = sum(1 for r in records if r["status"] == "error")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
