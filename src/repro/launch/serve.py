"""Serving driver: the MARS engine over either backend.

    # simulated paper-scale serving (H100 x Qwen3-Coder-30B, ILR-2):
    PYTHONPATH=src python -m repro.launch.serve --backend sim \
        --policy mars --regime ILR-2 --rate 0.2 --sessions 32

    # live engine on this host (reduced model, real tools):
    PYTHONPATH=src python -m repro.launch.serve --backend jax \
        --arch llama3.2-1b --policy mars --sessions 4
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.registry import get_config
from repro.core.events import EventBus
from repro.core.goodput import summarize
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_live, run_sim
from repro.engine.tools import RealToolExecutor
from repro.models import perf_model as pm
from repro.workloads.generator import WorkloadSpec, describe, generate


def serve_sim(*, policy: str, regime: str, rate: float, n_sessions: int,
              hw_name: str = "h100", model: str = "qwen3", seed: int = 0,
              alpha: float = 3.0, verbose: bool = True):
    if model == "qwen3":
        from repro.configs.qwen3_coder_30b import CONFIG as cfg, CONTEXT_LIMIT
    else:
        from repro.configs.gpt_oss_120b import CONFIG as cfg, CONTEXT_LIMIT
    hw = pm.HW[hw_name]
    kv_budget = hw.hbm_bytes - 2.1 * cfg.param_count()   # weights + overhead
    blocks = int(kv_budget / pm.kv_cache_bytes(cfg, 1) / 32)
    spec = WorkloadSpec(regime=regime, arrival_rate=rate,
                        n_sessions=n_sessions, seed=seed,
                        max_context=CONTEXT_LIMIT, slo_alpha=alpha)
    sessions = generate(spec, cfg, hw)
    backend = SimBackend(cfg, hw)
    eng = Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                              token_budget=8192, max_decode_batch=64,
                              decode_granularity=8, cpu_slots=8),
                 policy, backend)
    finished, horizon = run_sim(eng, sessions, max_time=2e5)
    stats = summarize(finished, horizon)
    if verbose:
        print(f"[serve-sim] {policy} {regime} rate={rate}: "
              f"fin={stats['n_finished']}/{n_sessions} "
              f"mean={stats['latency'].mean:.1f}s p95={stats['latency'].p95:.1f}s "
              f"goodput(a=3)={stats['goodput'][3.0]*1e3:.2f} m req/s")
    return stats, eng


def serve_live(*, arch: str, policy: str, n_sessions: int, verbose: bool = True):
    import jax.numpy as jnp
    from repro.core.session import Round, make_session
    from repro.engine.jax_runner import JaxBackend
    cfg = get_config(arch).reduced()
    backend = JaxBackend(cfg, max_slots=max(4, n_sessions), max_len=512)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=2, bus=bus)
    eng = Engine(EngineConfig(
        total_kv_blocks=max(4, n_sessions) * 511 // 32, block_size=32,
        token_budget=256, max_decode_batch=8, decode_granularity=4,
        cpu_slots=2), policy, backend, bus=bus, tool_exec=tools)
    rng = np.random.default_rng(0)
    sessions = []
    for i in range(n_sessions):
        rounds = [Round(int(rng.integers(64, 160)), 12, "terminal", 0.2),
                  Round(32, 8, "file_editor", 0.1),
                  Round(24, 8, None, 0.0)]
        sessions.append(make_session(0.05 * i, rounds, ideal_time=1.0))
    finished, horizon = run_live(eng, sessions, timeout=180)
    tools.shutdown()
    if verbose:
        for s in finished:
            gen = len(s.meta.get("generated", []))
            print(f"[serve-live] sid={s.sid} e2e={s.e2e_latency:.2f}s "
                  f"tokens={gen} ttfts={[round(t, 3) for t in s.ttfts]}")
    return finished


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["sim", "jax"], default="sim")
    ap.add_argument("--policy", default="mars")
    ap.add_argument("--regime", default="ILR-1")
    ap.add_argument("--rate", type=float, default=0.2)
    ap.add_argument("--sessions", type=int, default=24)
    ap.add_argument("--hw", default="h100")
    ap.add_argument("--model", default="qwen3")
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args(argv)
    if args.backend == "sim":
        serve_sim(policy=args.policy, regime=args.regime, rate=args.rate,
                  n_sessions=args.sessions, hw_name=args.hw, model=args.model)
    else:
        serve_live(arch=args.arch, policy=args.policy,
                   n_sessions=args.sessions)


if __name__ == "__main__":
    main()
