import os
os.environ["REPRO_UNROLL_SCANS"] = "1"
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=" +
                               os.environ.get("REPRO_DRYRUN_DEVICES", "256")).strip()

"""Depth-extrapolated roofline probe.

XLA's cost_analysis counts while-loop bodies ONCE, so the rolled-scan dry-run
under-reports in-loop flops/bytes/collectives. This probe compiles each
(arch x shape) cell at two REDUCED depths with every scan UNROLLED
(REPRO_UNROLL_SCANS=1), fits the exactly-linear-in-depth cost model

    cost(L) = fixed + L * per_layer

and extrapolates to the full architecture depth. Emits the same record
schema as launch/dryrun.py so launch/roofline.py consumes either.

    PYTHONPATH=src python -m repro.launch.roofline_probe --all --out probe.json
"""
import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional, Tuple

from repro.configs.registry import ARCH_IDS, cell_is_supported, get_config
from repro.configs.shapes import SHAPES
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

_FIELDS = ("flops", "bytes_accessed")


def _probe_depths(cfg) -> Tuple[int, int, int]:
    """(L1, L2, full_L) chosen so layer patterns stay representative."""
    if cfg.family == "zamba2":
        k = cfg.shared_attn_every
        return k, 2 * k, cfg.n_layers
    step = len(cfg.layer_pattern)
    return 2 * step, 4 * step, cfg.n_layers


def _with_depth(cfg, L: int):
    if cfg.family == "whisper":
        # encoder and decoder scale together
        frac = L / cfg.n_layers
        return dataclasses.replace(cfg, n_layers=L,
                                   n_enc_layers=max(1, round(cfg.n_enc_layers * frac)))
    return dataclasses.replace(cfg, n_layers=L)


def _extract(rec: Dict) -> Optional[Dict[str, float]]:
    if rec.get("status") != "ok":
        return None
    out = {f: rec.get(f, 0.0) for f in _FIELDS}
    for k, v in rec.get("collectives", {}).items():
        out[f"coll_{k}"] = v
    return out


def probe_cell(arch: str, shape_name: str, mesh, registry_patch) -> Dict:
    cfg = get_config(arch)
    ok, why = cell_is_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    L1, L2, Lf = _probe_depths(cfg)
    costs = {}
    for L in (L1, L2):
        registry_patch[arch] = _with_depth(cfg, L)
        rec = run_cell(arch, shape_name, mesh=mesh, verbose=False)
        registry_patch.pop(arch, None)
        c = _extract(rec)
        if c is None:
            rec.update({"arch": arch, "shape": shape_name, "probe_depth": L})
            return rec
        costs[L] = c
    out = {"arch": arch, "shape": shape_name, "status": "ok",
           "n_devices": int(mesh.size),
           "probe_depths": [L1, L2, Lf], "collectives": {}}
    for key in costs[L1]:
        per_layer = (costs[L2][key] - costs[L1][key]) / (L2 - L1)
        fixed = costs[L1][key] - L1 * per_layer
        val = max(0.0, fixed + Lf * per_layer)
        if key.startswith("coll_"):
            out["collectives"][key[5:]] = val
        else:
            out[key] = val
    print(f"[probe] {arch} x {shape_name}: flops={out.get('flops', 0):.3e} "
          f"bytes={out.get('bytes_accessed', 0):.3e} "
          f"coll={out['collectives'].get('total', 0):.3e}", flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    # patch the registry so run_cell sees the reduced-depth config
    import repro.configs.registry as registry
    patch: Dict = {}
    orig_get = registry.get_config
    registry.get_config = lambda a: patch.get(a, orig_get(a))
    import repro.launch.dryrun as dryrun
    dryrun.get_config = registry.get_config

    mesh = make_production_mesh(multi_pod=False)
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    records = []
    for a, s in cells:
        records.append(probe_cell(a, s, mesh, patch))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[probe] wrote {args.out}")
    return 1 if any(r["status"] == "error" for r in records) else 0


if __name__ == "__main__":
    sys.exit(main())
