"""Training driver: real steps on this host's devices (reduced configs) or
any mesh. Includes checkpoint/restart fault tolerance and the data-stream
state capture needed for exact resume.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50 --ckpt /tmp/ckpt [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.distributed import checkpoint as ckpt
from repro.distributed import sharding as sh
from repro.distributed.steps import build_train_step, cross_entropy
from repro.launch.mesh import make_test_mesh
from repro.models import model_zoo
from repro.train.data import DataConfig, SyntheticLMStream
from repro.train.optimizer import OptConfig, OptState, init_opt


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          seq_len: int = 128, batch: int = 8, ckpt_dir: str = None,
          resume: bool = False, ckpt_every: int = 20, log_every: int = 10,
          dtype=jnp.float32, verbose: bool = True, stop_after: int = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_test_mesh()
    params = model_zoo.init(cfg, jax.random.PRNGKey(0), dtype)
    opt_state = init_opt(params)
    data = SyntheticLMStream(DataConfig(cfg.vocab_size, seq_len, batch))
    opt = OptConfig(total_steps=steps, warmup_steps=max(1, steps // 10))
    step_fn = jax.jit(build_train_step(cfg, mesh, opt=opt, remat=False),
                      donate_argnums=(0, 1))
    start = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        (params, opt_state, data_state), start = ckpt.restore(
            ckpt_dir, (params, opt_state, data.state_dict()))
        data.load_state_dict(data_state)
        if verbose:
            print(f"[train] resumed from step {start}")
    losses = []
    pending = None
    end = steps if stop_after is None else min(steps, stop_after)
    with mesh:
        for step in range(start, end):
            toks, tgts = data.next_batch()
            batch_d = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
            if cfg.family == "whisper":
                batch_d["frames"] = jnp.zeros(
                    (toks.shape[0], 16, cfg.d_model), dtype)
            if cfg.frontend == "image_patches":
                batch_d["embeds"] = jnp.zeros(
                    (toks.shape[0], cfg.n_frontend_tokens, cfg.d_model), dtype)
            params, opt_state, metrics = step_fn(params, opt_state, batch_d)
            losses.append(float(metrics["loss"]))
            if verbose and (step % log_every == 0 or step == steps - 1):
                print(f"[train] step {step:4d} loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = ckpt.save(ckpt_dir,
                                    (params, opt_state, data.state_dict()),
                                    step=step + 1, async_=True)
    if pending is not None:
        pending.join()
    return losses, params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)
    t0 = time.time()
    losses, _ = train(args.arch, reduced=args.reduced, steps=args.steps,
                      seq_len=args.seq_len, batch=args.batch,
                      ckpt_dir=args.ckpt, resume=args.resume)
    print(f"[train] {len(losses)} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
