"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state. Single-pod: (data=16, model=16) = 256 chips; multi-pod adds a leading
pure-DP 'pod' axis (2 x 16 x 16 = 512 chips, DCN-crossing gradient psum).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = None):
    """Tiny mesh over however many devices exist (unit tests)."""
    n = n_devices or len(jax.devices())
    model = 2 if n % 2 == 0 and n >= 2 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
