"""Critical-path tracer over the unified event stream.

Subscribes to every :mod:`repro.core.events` kind and assembles, per
session, two views of the same lifetime:

* a **span tree** — possibly-overlapping intervals grouped by round:
  admission wait, scheduler-queue wait, prefill chunks, decode rounds, tool
  enqueue/exec, swap-out/in, pinned windows, tiered demote/promote staged
  restores. Overlap is real (a pin revoked to NVMe *during* a tool yields a
  demote span under the tool-exec span) and preserved.

* an **exclusive segment timeline** — a single cursor walks each session
  from ``submit`` to ``finish``; every event closes the open wait interval
  and/or appends an execution interval, so segments partition the session's
  end-to-end latency exactly. ``critical_path(sid)`` folds the timeline
  into per-plane buckets and names the dominant segment.

Span kinds map onto the paper's §4.1 event taxonomy (Table 1):

    GPU plane      prefill / decode        <- gpu_submit..gpu_end envelope,
                                              prefill_chunk / decode_step
    CPU plane      tool_queue / tool_exec  <- tool_enqueue / tool_start /
                                              tool_end
    I/O plane      swap_in / restore_wait  <- swap_out / swap_in / demote /
                   (+ demote/promote spans)   promote / swap_abandon
    control plane  admit_wait / sched_wait <- submit / admit / gpu_submit /
                                              preempt / evict / finish

The tracer is an ordinary subscriber: attach it before submitting sessions
(``Tracer.install(engine)`` also flips ``engine.trace_ticks`` so the engine
emits per-tick phase timings and retention audit records). Each ``TICK``
event additionally carries the iteration's batch composition — ``mixed``
(scheduler mode), ``decode_tokens``, ``prefill_tokens`` — which the
Perfetto exporter surfaces as tick-slice args; under the default mixed
scheduler one tick is one model iteration (every decode lane exactly one
token), so tick density is much higher than under ``scheduler="round"``.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional

from repro.core import events as ev
from repro.core.events import Event, EventBus

# segment/span kind -> latency plane
PLANE_OF = {
    "prefill": "gpu",
    "decode": "gpu",
    "tool_queue": "cpu",
    "tool_exec": "cpu",
    "cpu_queue_wait": "cpu",
    "swap_in": "io",
    "restore_wait": "io",
    "demote": "io",
    "promote": "io",
    "swap_out": "io",
    "admit_wait": "control",
    "sched_wait": "control",
    "pinned": "control",
}
PLANES = ("gpu", "cpu", "io", "control")


class Span:
    """One interval (or instant, start == end) in a session's lifetime."""

    __slots__ = ("kind", "plane", "start", "end", "sid", "round", "data")

    def __init__(self, kind: str, start: float, end: float, sid: int,
                 round_: int = 0, data: Optional[dict] = None):
        self.kind = kind
        self.plane = PLANE_OF.get(kind, "control")
        self.start = start
        self.end = end
        self.sid = sid
        self.round = round_
        self.data = data or {}

    @property
    def dur(self) -> float:
        return self.end - self.start

    def __repr__(self):
        return (f"Span({self.kind} sid={self.sid} r{self.round} "
                f"[{self.start:.3f},{self.end:.3f}])")


class SessionTrace:
    """Per-session assembly state + finished artifacts."""

    __slots__ = ("sid", "submitted", "admitted", "finished", "rejected",
                 "spans", "segments", "cursor", "wait", "round",
                 "swapped", "pin_start", "tool_start")

    def __init__(self, sid: int, submitted: float):
        self.sid = sid
        self.submitted = submitted
        self.admitted: Optional[float] = None
        self.finished: Optional[float] = None
        self.rejected = False
        self.spans: List[Span] = []
        self.segments: List[Span] = []   # exclusive, contiguous
        self.cursor = submitted          # time attributed so far
        self.wait = "admit_wait"         # open wait interval's kind
        self.round = 0
        self.swapped = False             # KV parked off-device right now
        self.pin_start: Optional[float] = None
        self.tool_start: Optional[float] = None

    # -- exclusive timeline ------------------------------------------------
    def close_wait(self, t: float, kind: Optional[str] = None) -> None:
        """Close the open wait interval [cursor, t] as ``kind`` (default:
        the current wait label) and advance the cursor."""
        t = max(t, self.cursor)
        k = kind or self.wait
        if t > self.cursor:
            seg = Span(k, self.cursor, t, self.sid, self.round)
            self.segments.append(seg)
            self.spans.append(seg)
        self.cursor = t

    def exec_segment(self, kind: str, start: float, end: float,
                     data: Optional[dict] = None) -> None:
        """Close the wait up to ``start``, then append an execution
        segment [start, end]."""
        self.close_wait(start)
        start = max(start, self.cursor)
        end = max(end, start)
        seg = Span(kind, start, end, self.sid, self.round, data)
        self.segments.append(seg)
        self.spans.append(seg)
        self.cursor = end

    def marker(self, kind: str, t: float, dur: float = 0.0,
               data: Optional[dict] = None) -> Span:
        """Overlay span (not part of the exclusive timeline)."""
        sp = Span(kind, t, t + dur, self.sid, self.round, data)
        self.spans.append(sp)
        return sp


class Tracer:
    """Event-stream subscriber assembling span trees + critical paths.

    ``metrics`` (a :class:`repro.obs.metrics.MetricsRegistry`) is optional:
    when given, the tracer feeds latency histograms (``trace.e2e_s``,
    ``trace.ttft_s``, ``trace.tool_s``, ``trace.tick_wall_s``) as events
    arrive. ``max_sessions`` bounds retained finished traces (ring; the
    aggregate bucket totals keep counting dropped ones).
    """

    def __init__(self, bus: Optional[EventBus] = None, *, metrics=None,
                 max_sessions: int = 100_000, max_ticks: int = 200_000):
        self.metrics = metrics
        self.sessions: Dict[int, SessionTrace] = {}
        self.finished_order: Deque[int] = deque()
        self.max_sessions = max_sessions
        self.ticks: Deque[Event] = deque(maxlen=max_ticks)
        self.events_seen = 0
        # aggregate per-plane bucket totals over *all* finished sessions
        # (survives the per-session ring)
        self.bucket_totals = dict.fromkeys(PLANES, 0.0)
        self.e2e_total = 0.0
        self.finished_count = 0
        self._dispatch = {
            ev.SUBMIT: self._on_submit,
            ev.REJECT: self._on_reject,
            ev.GPU_SUBMIT: self._on_gpu_submit,
            ev.PREFILL_CHUNK: self._on_prefill_chunk,
            ev.DECODE_STEP: self._on_decode_step,
            ev.GPU_FIRST_TOKEN: self._on_first_token,
            ev.GPU_END: self._on_gpu_end,
            ev.TOOL_ENQUEUE: self._on_tool_enqueue,
            ev.TOOL_START: self._on_tool_start,
            ev.TOOL_END: self._on_tool_end,
            ev.SWAP_OUT: self._on_swap_out,
            ev.SWAP_IN: self._on_swap_in,
            ev.SWAP_ABANDON: self._on_swap_abandon,
            ev.PIN: self._on_pin,
            ev.UNPIN: self._on_unpin,
            ev.PREEMPT: self._on_marker,
            ev.EVICT: self._on_evict,
            ev.DEMOTE: self._on_demote,
            ev.PROMOTE: self._on_promote,
            ev.PREFIX_HIT: self._on_marker,
            ev.RETENTION: self._on_marker,
            ev.INCIDENT: self._on_marker,
            ev.FINISH: self._on_finish,
            ev.TICK: self._on_tick,
        }
        self.bus = bus
        if bus is not None:
            bus.subscribe(None, self.on_event)

    # -- attachment --------------------------------------------------------
    @classmethod
    def install(cls, engine, *, metrics=None, **kw) -> "Tracer":
        """Attach to an engine's bus and enable its tick/audit emission."""
        tr = cls(engine.bus, metrics=metrics, **kw)
        engine.trace_ticks = True
        return tr

    @classmethod
    def replay(cls, events, **kw) -> "Tracer":
        """Rebuild a tracer from a recorded event sequence (e.g. the JSONL
        dump ``scripts/trace_report.py`` consumes)."""
        tr = cls(None, **kw)
        for e in events:
            tr.on_event(e)
        return tr

    # -- event pump --------------------------------------------------------
    def on_event(self, e: Event) -> None:
        self.events_seen += 1
        fn = self._dispatch.get(e.kind)
        if fn is not None:
            fn(e)

    def _trace(self, e: Event) -> Optional[SessionTrace]:
        return self.sessions.get(e.sid)

    # -- handlers ----------------------------------------------------------
    def _on_submit(self, e: Event) -> None:
        # a re-placed session (cluster failover) re-submits: keep the
        # original trace — its clock started at first arrival
        if e.sid not in self.sessions:
            self.sessions[e.sid] = SessionTrace(e.sid, e.t)

    def _on_reject(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.rejected = True

    def _on_gpu_submit(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.close_wait(e.t)
        tr.round = e.data.get("round", tr.round)
        if tr.admitted is None:
            tr.admitted = e.t
        # admitted / resumed: from here the open wait is scheduler-queue
        # time — unless an off-device restore gates it (I/O plane)
        tr.wait = "restore_wait" if tr.swapped else "sched_wait"

    def _on_prefill_chunk(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.exec_segment("prefill", e.data.get("start", e.t), e.t,
                        {"tokens": e.data.get("tokens", 0)})
        tr.wait = "sched_wait"

    def _on_decode_step(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.exec_segment("decode", e.data.get("start", e.t), e.t,
                        {"tokens": e.data.get("tokens", 0)})
        tr.wait = "sched_wait"

    def _on_first_token(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.marker("first_token", e.t, data=dict(e.data))
        if self.metrics is not None:
            self.metrics.histogram("trace.ttft_s").observe(
                e.data.get("ttft", 0.0))

    def _on_gpu_end(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.close_wait(e.t)

    def _on_tool_enqueue(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.close_wait(e.t)
        tr.wait = "tool_queue"

    def _on_tool_start(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        # split the pre-start wait: everything before the core-pool wait
        # is ordinary tool-queue time (executor backlog), the trailing
        # ``queue_wait`` seconds are CPU-pool core contention
        qw = e.data.get("queue_wait", 0.0)
        if qw > 0.0:
            tr.close_wait(max(tr.cursor, e.t - qw), "tool_queue")
            tr.close_wait(e.t, "cpu_queue_wait")
        else:
            tr.close_wait(e.t, "tool_queue")
        tr.tool_start = e.t
        tr.wait = "tool_exec"

    def _on_tool_end(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.close_wait(e.t, "tool_exec")
            tr.tool_start = None
            # post-tool limbo: gated on the off-device restore when the KV
            # was parked, plain scheduler wait otherwise
            tr.wait = "restore_wait" if tr.swapped else "sched_wait"
        if self.metrics is not None:
            self.metrics.histogram("trace.tool_s").observe(
                e.data.get("duration", 0.0))

    def _on_swap_out(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.swapped = True
        tr.marker("swap_out", e.t, data=dict(e.data))

    def _on_swap_in(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        start = e.data.get("start", e.t)
        # ``cpu_wait_s``: core-pool queueing charged into the restore cost
        # (the H2D staging pump waited for a core before the DMA could
        # run) — carve it out of the swap window as its own CPU segment
        cw = min(e.data.get("cpu_wait_s", 0.0), max(0.0, e.t - start))
        if cw > 0.0:
            tr.close_wait(start)
            tr.close_wait(start + cw, "cpu_queue_wait")
            start = start + cw
        tr.exec_segment("swap_in", start, e.t,
                        {"tokens": e.data.get("tokens", 0),
                         "tier": e.data.get("tier", "host")})
        tr.swapped = False
        tr.wait = "sched_wait"

    def _on_swap_abandon(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        # the wait so far was restore gating; from here the session is an
        # ordinary (recompute) scheduler client again
        tr.close_wait(e.t)
        tr.swapped = False
        tr.wait = "sched_wait"
        tr.marker("swap_abandon", e.t, data=dict(e.data))

    def _on_pin(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.pin_start = e.t
        tr.marker("pin", e.t, data=dict(e.data))

    def _on_unpin(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        if tr.pin_start is not None:
            tr.marker("pinned", tr.pin_start, e.t - tr.pin_start,
                      dict(e.data))
            tr.pin_start = None

    def _on_evict(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        if tr.pin_start is not None:          # reclaim path drops the pin
            tr.marker("pinned", tr.pin_start, e.t - tr.pin_start)
            tr.pin_start = None
        tr.marker("evict", e.t, data=dict(e.data))

    def _on_demote(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.marker("demote", e.t, e.data.get("write_s", 0.0),
                      dict(e.data))

    def _on_promote(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.marker("promote", e.t, e.data.get("read_s", 0.0),
                      dict(e.data))

    def _on_marker(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is not None:
            tr.marker(e.kind, e.t, data=dict(e.data))

    def _on_finish(self, e: Event) -> None:
        tr = self._trace(e)
        if tr is None:
            return
        tr.close_wait(e.t)
        tr.finished = e.t
        self.finished_count += 1
        e2e = tr.finished - tr.submitted
        self.e2e_total += e2e
        for seg in tr.segments:
            self.bucket_totals[seg.plane] += seg.dur
        if self.metrics is not None:
            self.metrics.histogram("trace.e2e_s").observe(e2e)
        self.finished_order.append(e.sid)
        while len(self.finished_order) > self.max_sessions:
            self.sessions.pop(self.finished_order.popleft(), None)

    def _on_tick(self, e: Event) -> None:
        self.ticks.append(e)
        if self.metrics is not None:
            self.metrics.histogram(
                "trace.tick_wall_s").observe(e.data.get("wall_s", 0.0))

    # -- queries -----------------------------------------------------------
    def trace(self, sid: int) -> Optional[SessionTrace]:
        return self.sessions.get(sid)

    def span_tree(self, sid: int) -> Optional[dict]:
        """Session -> rounds -> spans. Round r covers its GPU phase *and*
        the tool yielded at its end; overlay spans (demote/promote/
        swap_out) stay under the round they occurred in."""
        tr = self.sessions.get(sid)
        if tr is None:
            return None
        rounds: Dict[int, List[Span]] = {}
        for sp in tr.spans:
            rounds.setdefault(sp.round, []).append(sp)
        return {
            "sid": sid, "submitted": tr.submitted, "admitted": tr.admitted,
            "finished": tr.finished,
            "rounds": [
                {"round": r,
                 "start": min(sp.start for sp in sps),
                 "end": max(sp.end for sp in sps),
                 "spans": sorted(sps, key=lambda sp: (sp.start, sp.end))}
                for r, sps in sorted(rounds.items())],
        }

    def critical_path(self, sid: int, top: int = 5, *,
                      allow_unfinished: bool = False) -> Optional[dict]:
        """Exclusive per-plane latency decomposition of a finished session.

        Buckets partition ``finished - submitted`` exactly (segments are
        contiguous by construction); ``dominant`` is the single longest
        segment, ``dominant_bucket`` the largest plane total.

        ``allow_unfinished`` decomposes an in-flight session up to its
        cursor instead of returning None — the flight recorder attributes
        *stuck* sessions, which by definition have not finished. Such rows
        carry ``"partial": True`` and ``"finished": None``; the open tail
        wait past the cursor is not attributed (it has no closing event).
        """
        tr = self.sessions.get(sid)
        if tr is None or (tr.finished is None and not allow_unfinished):
            return None
        partial = tr.finished is None
        horizon = tr.cursor if partial else tr.finished
        buckets = dict.fromkeys(PLANES, 0.0)
        by_kind: Dict[str, float] = {}
        for seg in tr.segments:
            buckets[seg.plane] += seg.dur
            by_kind[seg.kind] = by_kind.get(seg.kind, 0.0) + seg.dur
        e2e = horizon - tr.submitted
        segs = sorted(tr.segments, key=lambda sp: -sp.dur)
        dom = segs[0] if segs else None
        return {
            "sid": sid, "e2e": e2e, "partial": partial,
            "submitted": tr.submitted, "finished": tr.finished,
            "buckets": buckets,
            "bucket_frac": {k: (v / e2e if e2e > 0 else 0.0)
                            for k, v in buckets.items()},
            "by_kind": by_kind,
            "dominant_bucket": max(buckets, key=buckets.get),
            "dominant": (None if dom is None else
                         {"kind": dom.kind, "plane": dom.plane,
                          "start": dom.start, "end": dom.end,
                          "dur": dom.dur, "round": dom.round}),
            "top_segments": [
                {"kind": sp.kind, "plane": sp.plane, "dur": sp.dur,
                 "start": sp.start, "round": sp.round}
                for sp in segs[:top]],
        }

    def finished_sids(self) -> List[int]:
        return list(self.finished_order)

    def aggregate(self) -> dict:
        """Fleet view over every finished session (including ones the ring
        dropped): per-plane bucket totals and fractions of total e2e."""
        total = self.e2e_total
        return {
            "sessions": self.finished_count,
            "e2e_total": total,
            "buckets": dict(self.bucket_totals),
            "bucket_frac": {k: (v / total if total > 0 else 0.0)
                            for k, v in self.bucket_totals.items()},
        }


# -- raw event (JSONL) round trip -------------------------------------------

def write_events_jsonl(events: Iterable[Event], path: str, *,
                       dropped: int = 0) -> int:
    """Write an event sequence as line-delimited JSON (one object per
    line: kind/t/sid/data) — the raw-trace format ``scripts/
    trace_report.py`` replays. The first line is a ``trace_meta`` header
    carrying the upstream ``dropped`` count, so a dump built from a lossy
    ring announces the loss to every consumer (``trace_report.py
    --strict`` fails on it). Returns the number of *event* lines written
    (the header is excluded)."""
    events = list(events)
    with open(path, "w") as f:
        header = {"kind": ev.TRACE_META, "t": 0.0, "sid": -1,
                  "data": {"dropped": dropped, "events": len(events)}}
        f.write(json.dumps(header) + "\n")
        for e in events:
            f.write(json.dumps({"kind": e.kind, "t": e.t, "sid": e.sid,
                                "data": e.data}, default=str) + "\n")
    return len(events)


def dump_events_jsonl(bus: EventBus, path: str) -> int:
    """Dump a bus's retained log (see :func:`write_events_jsonl`); the
    header's ``dropped`` is the bus ring's eviction count."""
    return write_events_jsonl(bus.log, path, dropped=bus.dropped)


def load_events_jsonl(path: str) -> List[Event]:
    """Parse a JSONL dump back to events. Tolerant of damage: malformed
    or truncated lines (a dump cut off mid-write, a corrupted ring
    bundle) are skipped rather than raised on — ``Tracer.replay`` then
    degrades to partial timelines, which is exactly what a postmortem
    wants from a lossy trace."""
    out: List[Event] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
                out.append(Event(d["kind"], float(d["t"]),
                                 int(d.get("sid", -1)), d.get("data") or {}))
            except (ValueError, KeyError, TypeError):
                continue
    return out


def events_from_dicts(rows: Iterable[dict]) -> List[Event]:
    """Adapt already-parsed event dicts (tests, notebooks) to Events."""
    return [Event(d["kind"], float(d["t"]), int(d.get("sid", -1)),
                  d.get("data") or {}) for d in rows]


# -- reporting helpers -------------------------------------------------------

def breakdown_table(rows: List[dict], *, max_rows: int = 20) -> str:
    """Render critical-path rows (``Tracer.critical_path`` results) as the
    per-session latency-breakdown table the examples print at exit."""
    out = [f"{'sid':>6} {'e2e_s':>9} {'gpu_s':>9} {'cpu_s':>9} "
           f"{'io_s':>9} {'ctrl_s':>9}  dominant"]
    shown = rows[:max_rows]
    for r in shown:
        b = r["buckets"]
        dom = r["dominant"]
        dom_s = (f"{dom['kind']} ({dom['dur']:.3f}s r{dom['round']})"
                 if dom else "-")
        out.append(f"{r['sid']:>6} {r['e2e']:>9.3f} {b['gpu']:>9.3f} "
                   f"{b['cpu']:>9.3f} {b['io']:>9.3f} "
                   f"{b['control']:>9.3f}  {dom_s}")
    if len(rows) > len(shown):
        out.append(f"  ... {len(rows) - len(shown)} more sessions")
    if rows:
        tot = {p: sum(r["buckets"][p] for r in rows) for p in PLANES}
        e2e = sum(r["e2e"] for r in rows)
        out.append(f"{'TOTAL':>6} {e2e:>9.3f} {tot['gpu']:>9.3f} "
                   f"{tot['cpu']:>9.3f} {tot['io']:>9.3f} "
                   f"{tot['control']:>9.3f}")
        if e2e > 0:
            out.append(f"{'%':>6} {'':>9} "
                       + " ".join(f"{100 * tot[p] / e2e:>9.1f}"
                                  for p in PLANES))
    return "\n".join(out)
