"""Observability plane over the unified event stream (paper §4.1).

``Tracer`` assembles per-session span trees and exclusive critical-path
segments from the :class:`repro.core.events.EventBus`; ``MetricsRegistry``
unifies the repo's ad-hoc counters behind one snapshot API; and
``export_perfetto`` writes a Chrome-trace JSON that opens in
``ui.perfetto.dev``. See ROADMAP.md "Observability" for the trace format
and metric naming conventions.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               bind_engine_probes, bind_router_probe,
                               log_bounds)
from repro.obs.perfetto import export_perfetto
from repro.obs.trace import (PLANES, SessionTrace, Span, Tracer,
                             breakdown_table, dump_events_jsonl,
                             events_from_dicts, load_events_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "bind_engine_probes", "bind_router_probe", "log_bounds",
    "export_perfetto",
    "PLANES", "SessionTrace", "Span", "Tracer", "breakdown_table",
    "dump_events_jsonl", "events_from_dicts", "load_events_jsonl",
]
