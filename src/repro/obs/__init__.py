"""Observability plane over the unified event stream (paper §4.1).

Postmortem half: ``Tracer`` assembles per-session span trees and
exclusive critical-path segments from the :class:`repro.core.events.
EventBus`; ``MetricsRegistry`` unifies the repo's ad-hoc counters behind
one snapshot API; and ``export_perfetto`` writes a Chrome-trace JSON that
opens in ``ui.perfetto.dev``.

Online half: ``SloTracker`` scores sessions against their declared
:class:`SLOClass` as events arrive; ``DetectorSuite`` turns anomaly
signatures (livelock, stalls, storms, thrash, event loss) into structured
``INCIDENT`` events; ``FlightRecorder`` freezes a replayable JSONL bundle
the moment one fires; and ``HealthReport`` rolls replica vitals and
incident counts up to one fleet status. See ROADMAP.md "Observability"
and docs/OBSERVABILITY.md for formats and naming conventions.
"""
from repro.obs.detect import INCIDENT_KINDS, DetectorConfig, DetectorSuite
from repro.obs.health import HealthReport, ReplicaHealth
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               bind_engine_probes, bind_router_probe,
                               log_bounds)
from repro.obs.perfetto import export_perfetto
from repro.obs.recorder import FlightRecorder
from repro.obs.slo import DEFAULT_SLO_CLASSES, SLOClass, SloTracker
from repro.obs.trace import (PLANES, SessionTrace, Span, Tracer,
                             breakdown_table, dump_events_jsonl,
                             events_from_dicts, load_events_jsonl,
                             write_events_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "bind_engine_probes", "bind_router_probe", "log_bounds",
    "export_perfetto",
    "PLANES", "SessionTrace", "Span", "Tracer", "breakdown_table",
    "dump_events_jsonl", "events_from_dicts", "load_events_jsonl",
    "write_events_jsonl",
    "INCIDENT_KINDS", "DetectorConfig", "DetectorSuite",
    "SLOClass", "DEFAULT_SLO_CLASSES", "SloTracker",
    "FlightRecorder", "HealthReport", "ReplicaHealth",
]
