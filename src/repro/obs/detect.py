"""Streaming anomaly detectors over the unified event stream.

Each detector is a small state machine fed by :class:`DetectorSuite`'s
single dispatch pump; when one trips it appends a structured incident
record *and* (live) emits an ``INCIDENT`` event back onto the bus — so
downstream consumers (the flight recorder, the fleet health rollup, the
cluster router in later PRs) see anomalies in the same stream as
everything else. Evidence rides in the record: the raw measurements that
crossed the threshold, not just a name.

Detector catalogue (kind -> signature):

    decode_livelock     a DECODING session stopped producing DECODE_STEPs
                        for ``livelock_ticks`` engine iterations while the
                        engine kept ticking (scheduler bug / starved lane)
    tool_stall          a started tool ran ``tool_stall_factor`` x its
                        promised ``expected_s`` (hung subprocess); measured
                        from TOOL_START so core-pool *queueing* — however
                        bad — never false-fires this one
    admission_stall     sessions kept waiting for ``admission_stall_ticks``
                        iterations with no round-0 GPU_SUBMIT even though
                        >= ``admission_free_frac`` of the KV pool is free
                        (a frozen control plane, not backpressure)
    swap_storm          the io bucket ate >= ``swap_io_frac`` of modeled
                        time over the last ``swap_window_ticks`` swap-
                        carrying iterations (degraded PCIe / thrash spiral)
    cpu_queue_collapse  shared-core backlog at/above ``cpu_min_backlog``
                        after growing >= ``cpu_min_growth`` within the
                        window (co-tenant flood; the coupled-pressure
                        failure mode MARS admission exists to avoid)
    kv_thrash           one session's KV ping-ponged demote<->promote
                        >= ``thrash_cycles`` round trips inside
                        ``thrash_window_s`` (retention mis-pricing)
    event_loss          the bus ring dropped events (live: ``bus.dropped``
                        advanced; replay: the dump's TRACE_META header says
                        so) — every downstream invariant is now suspect

Thresholds live in :class:`DetectorConfig`; the defaults are tuned so the
deterministic benchmark workloads (``benchmarks/slo_bench.py``) produce
zero incidents on clean runs and catch every injected fault —
``benchmarks/baselines.json`` gates exactly that.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

from repro.core import events as ev
from repro.core.events import Event, EventBus

INCIDENT_KINDS = ("decode_livelock", "tool_stall", "admission_stall",
                  "swap_storm", "cpu_queue_collapse", "kv_thrash",
                  "event_loss")


@dataclass
class DetectorConfig:
    # decode_livelock
    livelock_ticks: int = 400         # iterations with no DECODE_STEP
    # tool_stall (judged from TOOL_START; sim stretch is <= 1.25x, so 4x
    # the promise is unambiguous)
    tool_stall_factor: float = 4.0
    tool_stall_min_s: float = 60.0    # floor: never flag a quick tool
    tool_stall_max_s: float = 1800.0  # cap / fallback when expected_s unknown
    # admission_stall
    admission_stall_ticks: int = 300
    admission_free_frac: float = 0.5  # stall only counts with this much free
    # swap_storm
    swap_window_ticks: int = 64
    swap_io_frac: float = 0.8
    swap_min_busy_s: float = 5.0      # window io-seconds floor
    # cpu_queue_collapse
    cpu_window_ticks: int = 64
    cpu_min_backlog: int = 16
    cpu_min_growth: int = 8
    # kv_thrash
    thrash_cycles: int = 3            # demote<->promote round trips
    thrash_window_s: float = 120.0
    # re-fire suppression per (kind, sid)
    cooldown_s: float = 300.0


class DetectorSuite:
    """All detectors behind one bus subscription (or one replay pump)."""

    def __init__(self, bus: Optional[EventBus] = None, *,
                 config: Optional[DetectorConfig] = None, metrics=None):
        self.cfg = config or DetectorConfig()
        self.bus = bus
        self.metrics = metrics
        self.incidents: List[dict] = []
        self.tick_count = 0
        self._last_fired: Dict[Tuple[str, int], float] = {}
        # decode_livelock
        self._decoding: Dict[int, Tuple[int, float, int]] = {}
        #   sid -> (last decode tick index, last decode t, decoded tokens)
        self._livelock_armed: Dict[int, bool] = {}
        # tool_stall
        self._tools: Dict[int, Tuple[float, float, str]] = {}
        #   sid -> (start t, expected_s, kind)
        self._tool_fired: Dict[int, bool] = {}
        self._tool_expected: Dict[int, float] = {}
        #   sid -> promised duration (TOOL_ENQUEUE carries it; TOOL_START
        #   does not — the promise predates any queueing or fault stretch)
        # admission_stall
        self._last_admit_tick = 0
        self._waiting_streak = 0
        self._admission_armed = True
        # swap_storm: (elapsed, io_busy) per tick, with running sums so the
        # per-tick cost stays O(1) instead of O(window)
        self._swap_win: Deque[Tuple[float, float]] = deque(
            maxlen=self.cfg.swap_window_ticks)
        self._swap_tot = 0.0
        self._swap_busy = 0.0
        self._swap_armed = True
        # cpu_queue_collapse: backlog per tick
        self._cpu_win: Deque[int] = deque(maxlen=self.cfg.cpu_window_ticks)
        self._cpu_armed = True
        # kv_thrash: sid -> migration timestamps
        self._migrations: Dict[int, Deque[float]] = {}
        # event_loss
        self._dropped_seen = 0
        self._dispatch = {
            ev.TICK: self._on_tick,
            ev.DECODE_STEP: self._on_decode_step,
            ev.GPU_END: self._on_not_decoding,
            ev.TOOL_ENQUEUE: self._on_tool_enqueue,
            ev.FINISH: self._on_not_decoding,
            ev.PREEMPT: self._on_not_decoding,
            ev.EVICT: self._on_not_decoding,
            ev.SWAP_OUT: self._on_not_decoding,
            ev.GPU_SUBMIT: self._on_gpu_submit,
            ev.TOOL_START: self._on_tool_start,
            ev.TOOL_END: self._on_tool_end,
            ev.DEMOTE: self._on_migrate,
            ev.PROMOTE: self._on_migrate,
            ev.TRACE_META: self._on_trace_meta,
        }
        if bus is not None:
            bus.subscribe(None, self.on_event)

    # -- attachment --------------------------------------------------------
    @classmethod
    def install(cls, engine, **kw) -> "DetectorSuite":
        """Attach to an engine's bus; flips ``trace_ticks`` (the TICK-driven
        detectors need the per-iteration telemetry)."""
        suite = cls(engine.bus, **kw)
        engine.trace_ticks = True
        return suite

    @classmethod
    def replay(cls, events, **kw) -> "DetectorSuite":
        suite = cls(None, **kw)
        for e in events:
            suite.on_event(e)
        return suite

    # -- pump --------------------------------------------------------------
    def on_event(self, e: Event) -> None:
        fn = self._dispatch.get(e.kind)   # INCIDENT has no entry: no loops
        if fn is not None:
            fn(e)

    def count(self, kind: Optional[str] = None) -> int:
        if kind is None:
            return len(self.incidents)
        return sum(1 for i in self.incidents if i["kind"] == kind)

    def _fire(self, kind: str, t: float, sid: int, evidence: dict) -> None:
        last = self._last_fired.get((kind, sid))
        if last is not None and t - last < self.cfg.cooldown_s:
            return
        self._last_fired[(kind, sid)] = t
        rec = {"kind": kind, "t": t, "sid": sid, "evidence": evidence}
        self.incidents.append(rec)
        if self.metrics is not None:
            self.metrics.counter(f"incidents.{kind}").inc()
        if self.bus is not None:
            self.bus.emit(ev.INCIDENT, t, sid, kind=kind, evidence=evidence)

    # -- decode_livelock ---------------------------------------------------
    def _on_decode_step(self, e: Event) -> None:
        self._decoding[e.sid] = (self.tick_count, e.t,
                                 int(e.data.get("decoded", 0)))
        self._livelock_armed[e.sid] = True

    def _on_not_decoding(self, e: Event) -> None:
        self._decoding.pop(e.sid, None)
        self._livelock_armed.pop(e.sid, None)

    # -- admission_stall ---------------------------------------------------
    def _on_gpu_submit(self, e: Event) -> None:
        if e.data.get("round", 0) == 0:
            self._last_admit_tick = self.tick_count
            self._admission_armed = True

    # -- tool_stall --------------------------------------------------------
    def _on_tool_enqueue(self, e: Event) -> None:
        self._tool_expected[e.sid] = float(e.data.get("expected_s") or 0.0)
        self._on_not_decoding(e)

    def _on_tool_start(self, e: Event) -> None:
        expected = self._tool_expected.pop(
            e.sid, float(e.data.get("expected_s") or 0.0))
        self._tools[e.sid] = (e.t, expected, e.data.get("kind", "?"))
        self._tool_fired[e.sid] = False

    def _on_tool_end(self, e: Event) -> None:
        self._tools.pop(e.sid, None)
        self._tool_fired.pop(e.sid, None)
        self._on_not_decoding(e)

    def _tool_bound(self, expected: float) -> float:
        c = self.cfg
        if expected <= 0.0:
            return c.tool_stall_max_s
        return min(c.tool_stall_max_s,
                   max(c.tool_stall_min_s, c.tool_stall_factor * expected))

    # -- kv_thrash ---------------------------------------------------------
    def _on_migrate(self, e: Event) -> None:
        c = self.cfg
        win = self._migrations.get(e.sid)
        if win is None:
            win = self._migrations[e.sid] = deque(maxlen=2 * c.thrash_cycles)
        win.append(e.t)
        if (len(win) == 2 * c.thrash_cycles
                and e.t - win[0] <= c.thrash_window_s):
            self._fire("kv_thrash", e.t, e.sid, {
                "migrations": len(win), "window_s": e.t - win[0],
                "first_t": win[0]})

    # -- TRACE_META (replayed dumps) ---------------------------------------
    def _on_trace_meta(self, e: Event) -> None:
        dropped = int(e.data.get("dropped", 0))
        if dropped > 0:
            self._fire("event_loss", e.t, -1, {
                "dropped": dropped, "source": "trace_meta",
                "events": e.data.get("events")})

    # -- per-tick scans ----------------------------------------------------
    # per-session scans run every _SCAN_STRIDE ticks: detection resolution
    # drops by at most the stride (negligible next to the 300-400 tick
    # thresholds) and the per-tick hot path stays O(1) — the obs plane's
    # <=3% CPU budget (obs_overhead_bench) is gated with these installed
    _SCAN_STRIDE = 8

    def _on_tick(self, e: Event) -> None:
        self.tick_count += 1
        c = self.cfg
        d = e.data
        t = e.t
        if self.tick_count % self._SCAN_STRIDE == 0:
            # decode_livelock: armed decoding sessions that stopped stepping
            for sid, (last_tick, last_t, decoded) in \
                    list(self._decoding.items()):
                stalled = self.tick_count - last_tick
                if stalled >= c.livelock_ticks \
                        and self._livelock_armed.get(sid):
                    self._livelock_armed[sid] = False  # re-arm on next step
                    self._fire("decode_livelock", t, sid, {
                        "ticks_stalled": stalled, "last_decode_t": last_t,
                        "decoded": decoded})
            # tool_stall: started tools exceeding their promise
            for sid, (start, expected, kind) in list(self._tools.items()):
                if self._tool_fired.get(sid):
                    continue
                bound = self._tool_bound(expected)
                if t - start > bound:
                    self._tool_fired[sid] = True
                    self._fire("tool_stall", t, sid, {
                        "kind": kind, "running_s": t - start,
                        "expected_s": expected, "bound_s": bound})
        # admission_stall: waiting streak, idle admission, free pool
        waiting = int(d.get("waiting", 0))
        self._waiting_streak = self._waiting_streak + 1 if waiting > 0 else 0
        if waiting == 0:
            self._admission_armed = True
        total = int(d.get("total_blocks", 0))
        free_frac = (d.get("free_blocks", 0) / total) if total else 0.0
        since_admit = self.tick_count - self._last_admit_tick
        if (self._admission_armed
                and self._waiting_streak >= c.admission_stall_ticks
                and since_admit >= c.admission_stall_ticks
                and free_frac >= c.admission_free_frac):
            self._admission_armed = False
            self._fire("admission_stall", t, -1, {
                "waiting": waiting, "ticks_since_admit": since_admit,
                "waiting_streak": self._waiting_streak,
                "free_frac": round(free_frac, 4)})
        # swap_storm: io share of modeled time across the window
        elapsed = float(d.get("elapsed", 0.0))
        io_busy = elapsed if (d.get("n_swapins", 0)
                              or d.get("n_swapouts", 0)) else 0.0
        if len(self._swap_win) == self._swap_win.maxlen:
            old_el, old_io = self._swap_win[0]
            self._swap_tot -= old_el
            self._swap_busy -= old_io
        self._swap_win.append((elapsed, io_busy))
        self._swap_tot += elapsed
        self._swap_busy += io_busy
        if len(self._swap_win) == self._swap_win.maxlen:
            tot = max(0.0, self._swap_tot)
            busy = max(0.0, self._swap_busy)
            frac = busy / tot if tot > 0 else 0.0
            if (self._swap_armed and frac >= c.swap_io_frac
                    and busy >= c.swap_min_busy_s):
                self._swap_armed = False
                self._fire("swap_storm", t, -1, {
                    "io_frac": round(frac, 4), "io_busy_s": round(busy, 3),
                    "window_ticks": len(self._swap_win)})
            elif frac < c.swap_io_frac / 2:
                self._swap_armed = True
        # cpu_queue_collapse: backlog level + growth inside the window
        backlog = int(d.get("cpu_backlog", 0))
        self._cpu_win.append(backlog)
        growth = backlog - self._cpu_win[0]
        if (self._cpu_armed and backlog >= c.cpu_min_backlog
                and growth >= c.cpu_min_growth):
            self._cpu_armed = False
            self._fire("cpu_queue_collapse", t, -1, {
                "cpu_backlog": backlog, "growth": growth,
                "window_ticks": len(self._cpu_win)})
        elif backlog < c.cpu_min_backlog / 2:
            self._cpu_armed = True
        # event_loss (live): the ring advanced its eviction counter
        if self.bus is not None and self.bus.dropped > self._dropped_seen:
            n = self.bus.dropped
            self._fire("event_loss", t, -1, {
                "dropped": n - self._dropped_seen, "total_dropped": n,
                "source": "ring"})
            self._dropped_seen = n
