"""Bounded flight recorder: replayable post-incident trace bundles.

A crash dump for the scheduler: the recorder rides the bus with a bounded
event ring (cheap append, no I/O on the hot path) and, the moment a
detector emits an ``INCIDENT``, freezes the ring into a *bundle*
directory:

    <out_dir>/incident-000-<kind>/
        events.jsonl    the ring contents in the standard JSONL dump
                        format — ``Tracer.replay`` and
                        ``scripts/trace_report.py`` consume it directly;
                        its TRACE_META header carries the total dropped
                        count (bus ring + recorder ring), so lossy bundles
                        announce themselves
        incident.json   the incident record (kind, t, sid, evidence) plus
                        critical-path attribution: the implicated
                        session's partial per-plane breakdown (it usually
                        has not finished — that is why there is an
                        incident) and the fleet aggregate at dump time

``max_bundles`` caps disk usage for incident storms: later incidents are
counted but not dumped (the detector records still hold them).
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Deque, List, Optional

from repro.core import events as ev
from repro.core.events import Event, EventBus
from repro.obs.trace import Tracer, write_events_jsonl


class FlightRecorder:
    def __init__(self, bus: EventBus, out_dir: str, *,
                 max_events: int = 200_000, max_bundles: int = 8):
        self.bus = bus
        self.out_dir = out_dir
        self.max_bundles = max_bundles
        self.ring: Deque[Event] = deque(maxlen=max_events)
        self.ring_dropped = 0
        self.bundles: List[str] = []
        self.incidents_seen = 0
        bus.subscribe(None, self.on_event)

    @classmethod
    def install(cls, engine, out_dir: str, **kw) -> "FlightRecorder":
        return cls(engine.bus, out_dir, **kw)

    def on_event(self, e: Event) -> None:
        ring = self.ring
        if ring.maxlen is not None and len(ring) == ring.maxlen:
            self.ring_dropped += 1
        ring.append(e)
        if e.kind == ev.INCIDENT:
            self.incidents_seen += 1
            if len(self.bundles) < self.max_bundles:
                self._dump(e)

    # -- bundle assembly ---------------------------------------------------
    def _dump(self, incident: Event) -> None:
        kind = incident.data.get("kind", "unknown")
        name = f"incident-{len(self.bundles):03d}-{kind}"
        path = os.path.join(self.out_dir, name)
        os.makedirs(path, exist_ok=True)
        events = list(self.ring)
        dropped = self.bus.dropped + self.ring_dropped
        write_events_jsonl(events, os.path.join(path, "events.jsonl"),
                           dropped=dropped)
        with open(os.path.join(path, "incident.json"), "w") as f:
            json.dump(self._attribution(incident, events, dropped), f,
                      indent=1, default=str)
        self.bundles.append(path)

    def _attribution(self, incident: Event, events: List[Event],
                     dropped: int) -> dict:
        """Critical-path context for the implicated session: replay the
        ring through a fresh tracer (partial timelines allowed — the
        session is usually still stuck at dump time)."""
        tr = Tracer.replay(events)
        sid = incident.sid
        cp: Optional[dict] = None
        if sid >= 0:
            cp = tr.critical_path(sid, allow_unfinished=True)
        return {
            "incident": {"kind": incident.data.get("kind"), "t": incident.t,
                         "sid": sid,
                         "evidence": incident.data.get("evidence", {})},
            "critical_path": cp,
            "aggregate": tr.aggregate(),
            "ring": {"events": len(events), "dropped": dropped},
        }
