"""Chrome-trace / Perfetto JSON export of assembled traces.

Emits the legacy Chrome trace-event JSON (``{"traceEvents": [...]}``) that
``ui.perfetto.dev`` (and ``chrome://tracing``) open directly:

* one **process track per replica** (pid = replica index, named after the
  replica id; a single-engine run exports one process),
* **per-plane threads** in each process — ``gpu`` carries the engine tick
  slices (non-overlapping: the tick loop is serial) and ``io`` /
  ``control`` carry instant markers — plus per-process **counter tracks**
  (free KV blocks, active tools, waiting queue, host/disk tier occupancy)
  sampled from the engine's ``tick`` events,
* one **thread per traced session** whose slices are the session's
  *exclusive* critical-path segments (contiguous by construction, so they
  nest trivially); each slice carries ``args: {sid, plane, kind}`` — this
  is the schema ``scripts/trace_report.py`` recomputes the latency
  breakdown from, which is what makes the exporter CI-checkable,
* overlay spans that genuinely overlap the timeline (pinned windows,
  demote/promote staged I/O, async swap-out drains) as async ``b``/``e``
  pairs keyed by sid.

Timestamps are event-stream seconds scaled to microseconds (sim runs use
the modeled clock; live runs the engine's wall clock).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Union

from repro.obs.trace import Tracer

_US = 1e6
# fixed per-plane thread ids inside each replica process; session detail
# threads start above _SESSION_TID_BASE
_PLANE_TIDS = {"gpu": 1, "cpu": 2, "io": 3, "control": 4}
_PLANE_THREAD_NAMES = {"gpu": "gpu (engine ticks)", "cpu": "cpu-tools",
                       "io": "io (swap/tier)", "control": "control-plane"}
_SESSION_TID_BASE = 100

_COUNTER_FIELDS = (("free_blocks", "kv free blocks"),
                   ("active_tools", "active tools"),
                   ("waiting", "admission queue"),
                   # shared host-core pool pressure (tools + swap + spool)
                   ("cpu_busy", "cpu pool busy cores"),
                   ("cpu_backlog", "cpu pool backlog"),
                   ("host_used", "host tier blocks"),
                   ("disk_used", "disk tier blocks"),
                   # live-backend prefill HBM traffic (cumulative): what
                   # the legacy gather path would have touched vs what the
                   # gather-free (block-table steered) path touches
                   ("prefill_gather_bytes", "prefill gather bytes"),
                   ("prefill_inplace_bytes", "prefill in-place bytes"))


def _segment_counts(tr) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for sid in tr.finished_sids():
        st = tr.sessions.get(sid)
        if st is not None:
            out[str(sid)] = len(st.segments)
    return out


def export_perfetto(tracers: Union[Tracer, Dict[str, Tracer]],
                    path: Optional[str] = None, *,
                    max_session_tracks: int = 1000) -> dict:
    """Build (and optionally write) the trace JSON.

    ``tracers`` is one tracer, or ``{replica_id: tracer}`` for a cluster
    run. Returns the trace dict; writes it to ``path`` when given.
    """
    if isinstance(tracers, Tracer):
        tracers = {"engine": tracers}
    events: List[dict] = []
    dropped_sessions = 0
    for pid, (rid, tr) in enumerate(sorted(tracers.items())):
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "args": {"name": rid}})
        for plane, tid in _PLANE_TIDS.items():
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid,
                           "args": {"name": _PLANE_THREAD_NAMES[plane]}})
        # engine tick slices + counter tracks (present when the source
        # engine ran with trace_ticks on; replayed JSONL keeps them too)
        for te in tr.ticks:
            d = te.data
            ts = te.t * _US
            dur = max(0.0, d.get("elapsed", 0.0)) * _US
            if dur > 0:
                events.append({
                    "ph": "X", "pid": pid, "tid": _PLANE_TIDS["gpu"],
                    "name": "tick", "ts": ts, "dur": dur,
                    "args": {"wall_s": d.get("wall_s", 0.0),
                             "phases": d.get("phases", {}),
                             "decodes": d.get("n_decodes", 0),
                             "prefills": d.get("n_prefills", 0),
                             "swapins": d.get("n_swapins", 0),
                             # iteration composition (mixed scheduler):
                             # token split of this dispatch
                             "mixed": d.get("mixed", False),
                             "decode_tokens": d.get("decode_tokens", 0),
                             "prefill_tokens": d.get("prefill_tokens", 0)}})
            for field, label in _COUNTER_FIELDS:
                if field in d:
                    events.append({"ph": "C", "pid": pid, "name": label,
                                   "ts": ts,
                                   "args": {"value": d.get(field, 0)}})
        # per-session detail threads: exclusive segments as complete slices
        sids = tr.finished_sids()
        if len(sids) > max_session_tracks:
            dropped_sessions += len(sids) - max_session_tracks
            sids = sids[:max_session_tracks]
        for k, sid in enumerate(sids):
            st = tr.sessions.get(sid)
            if st is None:
                continue
            tid = _SESSION_TID_BASE + k
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": f"sid {sid}"}})
            for seg in st.segments:
                events.append({
                    "ph": "X", "pid": pid, "tid": tid, "name": seg.kind,
                    "ts": seg.start * _US,
                    "dur": max(0.0, seg.dur) * _US,
                    "args": {"sid": sid, "plane": seg.plane,
                             "kind": seg.kind, "round": seg.round}})
            seg_ids = {id(seg) for seg in st.segments}
            for sp in st.spans:
                if id(sp) in seg_ids:
                    continue
                if sp.dur > 0:        # overlapping overlay: async pair
                    base = {"cat": sp.plane, "id": sid, "pid": pid,
                            "tid": _PLANE_TIDS[sp.plane],
                            "name": f"{sp.kind} sid={sid}"}
                    events.append({**base, "ph": "b", "ts": sp.start * _US,
                                   "args": {"sid": sid, "kind": sp.kind}})
                    events.append({**base, "ph": "e", "ts": sp.end * _US})
                else:                 # instant marker on the plane thread
                    events.append({
                        "ph": "i", "pid": pid,
                        "tid": _PLANE_TIDS[sp.plane], "name": sp.kind,
                        "ts": sp.start * _US, "s": "t",
                        "args": {"sid": sid, **{k2: v for k2, v in
                                                sp.data.items()
                                                if isinstance(v, (int, float,
                                                                  str, bool))
                                                }}})
    trace = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.obs.perfetto",
            "replicas": sorted(tracers),
            "sessions": {rid: t.finished_count
                         for rid, t in tracers.items()},
            "dropped_session_tracks": dropped_sessions,
            # upstream event loss: nonzero means the source rings evicted
            # events before assembly and every timeline here is suspect
            # (trace_report --strict fails on it)
            "dropped_events": sum(
                t.bus.dropped for t in tracers.values()
                if getattr(t, "bus", None) is not None),
        },
    }
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace
