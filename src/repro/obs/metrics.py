"""Metrics plane: counters, gauges and fixed-bucket histograms behind one
snapshot/export API.

The repo grew ad-hoc counters wherever a subsystem needed one — ``Telemetry``
(offload/prefix/digest counters), ``SwapStream`` (per-direction transfer
counts), ``TieredStore.stats()``, ``ClusterRouter.events`` — each with its
own read path. The :class:`MetricsRegistry` absorbs them behind *probes*:
a probe is a callable returning a dict, registered once and re-run at every
``snapshot()``, so live sources keep owning their counters (tests read them
directly, unchanged) while dashboards and exporters read one tree.

Histograms are fixed-bucket (log-spaced bounds by default): ``observe`` is
O(log buckets) and percentiles (p50/p95/p99) come from linear interpolation
inside the covering bucket — no sample retention, so a 10k-session soak
costs the same memory as a 10-session smoke.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Callable, Dict, List, Optional, Sequence


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written instantaneous value — or, with ``set_fn``, a live
    view: the callable is re-read at every ``snapshot()``, so sources
    that already own their counter (e.g. the EventBus ring's ``dropped``)
    surface without a copy-on-write hook in their hot path."""

    __slots__ = ("value", "_fn")

    def __init__(self):
        self.value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        self.value = v
        self._fn = None

    def set_fn(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    def read(self) -> float:
        if self._fn is not None:
            self.value = self._fn()
        return self.value


def log_bounds(lo: float = 1e-4, hi: float = 1e4,
               per_decade: int = 4) -> List[float]:
    """Log-spaced bucket upper bounds covering [lo, hi]."""
    n = int(round(per_decade * math.log10(hi / lo)))
    return [lo * (hi / lo) ** (i / n) for i in range(n + 1)]


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``bounds`` are bucket *upper* bounds; an extra overflow bucket catches
    values beyond the last bound (its percentile contribution is clamped to
    the largest observed value, so a stray outlier cannot report +inf).
    """

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds: Optional[Sequence[float]] = None):
        self.bounds = sorted(bounds) if bounds else log_bounds()
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Interpolated q-quantile (q in [0, 1]); 0.0 when empty."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= rank and c > 0:
                lo = self.bounds[i - 1] if i > 0 else min(self.min, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max) if self.max > -math.inf else hi
                if hi <= lo:
                    return lo
                frac = (rank - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.max

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0,
                    "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min, "max": self.max,
                "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class MetricsRegistry:
    """Get-or-create named metrics + registered live-source probes.

    Naming convention (see ROADMAP "Observability"): dot-separated
    ``<subsystem>.<noun>[_<unit>]`` — e.g. ``trace.e2e_s``,
    ``swap_stream.d2h_seconds``, ``router.requeue_depth``. Histogram names
    carry their unit suffix (``_s`` seconds, ``_tok`` tokens, ``_blocks``).
    """

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self._probes: Dict[str, Callable[[], Optional[dict]]] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(bounds)
        return h

    def register_probe(self, name: str,
                       fn: Callable[[], Optional[dict]]) -> None:
        """``fn`` re-runs at every snapshot; a None return drops the key
        (source not configured — e.g. no swap stream on the sim path)."""
        self._probes[name] = fn

    def snapshot(self) -> dict:
        out: dict = {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.read() for k, g in self.gauges.items()},
            "histograms": {k: h.snapshot()
                           for k, h in self.histograms.items()},
        }
        for name, fn in self._probes.items():
            v = fn()
            if v is not None:
                out[name] = v
        return out

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, default=str)


def bind_engine_probes(reg: MetricsRegistry, engine) -> None:
    """Absorb an engine's ad-hoc counter surfaces into ``reg``:

    * ``telemetry`` — the dual-pressure snapshot (flags, churn EMA, offload
      /prefix/digest counters, per-kind tool EMAs)
    * ``kv_tiers`` — ``Telemetry.kv_tier_stats()`` (TieredStore breakdown)
    * ``cpu_pool`` — shared host-core pool gauges (lease counts, busy and
      queue-wait seconds per kind, peak backlog/stretch)
    * ``swap_stream`` — live-backend background stream counters + queue
      depth (absent on the sim path)
    * ``dispatch`` — live-path run_batch phase timing (absent in sim)
    """
    telem = engine.telem

    def _telemetry():
        return {
            "free_blocks": telem.free_blocks,
            "total_blocks": telem.total_blocks,
            "pinned_blocks": telem.pinned_blocks,
            "kv_utilization": round(telem.kv_utilization, 4),
            "active_sessions": telem.active_sessions,
            "running_decodes": telem.running_decodes,
            "active_tools": telem.active_tools,
            "cpu_overloaded": telem.cpu_overloaded,
            "kv_overloaded": telem.kv_overloaded,
            "churn_ema_blocks": round(telem.churn_ema, 3),
            "offload_stores": telem.offload_stores,
            "offload_hits": telem.offload_hits,
            "prefix_queries": telem.prefix_queries,
            "prefix_hits": telem.prefix_hits,
            "prefix_hit_tokens": telem.prefix_hit_tokens,
            "digest_anchors": telem.digest_anchors,
            "digest_indexed_blocks": telem.digest_indexed_blocks,
            "tool_ema_s": {k: round(v, 3)
                           for k, v in telem.tool_ema.items()},
        }

    reg.register_probe("telemetry", _telemetry)
    reg.register_probe("kv_tiers", telem.kv_tier_stats)
    pool = getattr(engine, "cpu_pool", None)
    if pool is not None:
        reg.register_probe("cpu_pool", pool.stats)
    stream_stats = getattr(engine.backend, "swap_stream_stats", None)
    if stream_stats is not None:
        reg.register_probe("swap_stream", stream_stats)
    dispatch = getattr(engine.backend, "dispatch_stats", None)
    if dispatch is not None:
        reg.register_probe("dispatch", lambda: dict(dispatch))
    reg.register_probe(
        "events", lambda: {"counts": dict(engine.bus.counts),
                           "dropped": engine.bus.dropped})
    # the ring's eviction count as a first-class gauge: dashboards alert on
    # it directly (dropped > 0 voids the exclusive-timeline invariant — see
    # obs.detect's event_loss incident and trace_report --strict)
    reg.gauge("events.dropped").set_fn(lambda: float(engine.bus.dropped))


def bind_router_probe(reg: MetricsRegistry, router) -> None:
    """Absorb the cluster router's membership/placement/requeue counters
    and heartbeat-digest prefix stats."""
    reg.register_probe("router", router.stats)
    reg.register_probe("cluster_prefix", router.cluster_prefix_stats)
