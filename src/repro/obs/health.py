"""Fleet health rollup over :class:`repro.distributed.router.ClusterRouter`.

One structured answer to "how is the fleet doing right now": per-replica
vitals from the router's heartbeat state, fleet counters from
``router.stats()``, and — when the per-replica observability stack is
wired in — incident counts from each replica's :class:`~repro.obs.detect.
DetectorSuite` and goodput from a fleet :class:`~repro.obs.slo.SloTracker`.

Status ladder (worst replica wins, incidents escalate):

    healthy     every replica alive, nothing draining, no incidents
    degraded    a replica is draining/straggling, or incidents fired but
                every replica is still alive
    critical    a replica is dead, or the requeue backlog is non-empty
                (sessions displaced with nowhere to go)

``examples/cluster_serving.py`` prints ``HealthReport.render()`` at exit;
later PRs feed the same rollup to the fleet router's placement scoring.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class ReplicaHealth:
    rid: str
    alive: bool
    draining: bool
    kv_utilization: float
    tool_backlog: int
    active_sessions: int
    step_latency_ema: float
    last_heartbeat: float
    incidents: Dict[str, int] = field(default_factory=dict)

    @property
    def status(self) -> str:
        if not self.alive:
            return "dead"
        if self.draining or self.incidents:
            return "degraded"
        return "ok"


@dataclass
class HealthReport:
    status: str
    fleet: dict
    replicas: List[ReplicaHealth]
    incidents: Dict[str, int]
    slo: Optional[dict] = None

    @classmethod
    def collect(cls, router, *, detectors: Optional[dict] = None,
                slo=None) -> "HealthReport":
        """``detectors`` maps rid -> DetectorSuite (or anything exposing
        ``incidents``); ``slo`` is a fleet-level SloTracker."""
        detectors = detectors or {}
        fleet = router.stats()
        replicas: List[ReplicaHealth] = []
        incident_totals: Dict[str, int] = {}
        for rid, r in sorted(router.replicas.items()):
            counts: Dict[str, int] = {}
            suite = detectors.get(rid)
            if suite is not None:
                for rec in suite.incidents:
                    counts[rec["kind"]] = counts.get(rec["kind"], 0) + 1
                    incident_totals[rec["kind"]] = \
                        incident_totals.get(rec["kind"], 0) + 1
            replicas.append(ReplicaHealth(
                rid=rid, alive=r.alive, draining=r.draining,
                kv_utilization=r.kv_utilization,
                tool_backlog=r.tool_backlog,
                active_sessions=r.active_sessions,
                step_latency_ema=r.step_latency_ema,
                last_heartbeat=r.last_heartbeat, incidents=counts))
        dead = sum(1 for r in replicas if not r.alive)
        if dead or fleet.get("requeue_depth", 0):
            status = "critical"
        elif any(r.status == "degraded" for r in replicas) or incident_totals:
            status = "degraded"
        else:
            status = "healthy"
        return cls(status=status, fleet=fleet, replicas=replicas,
                   incidents=incident_totals,
                   slo=slo.report() if slo is not None else None)

    def to_dict(self) -> dict:
        return {
            "status": self.status,
            "fleet": self.fleet,
            "incidents": self.incidents,
            "replicas": [{
                "rid": r.rid, "status": r.status, "alive": r.alive,
                "draining": r.draining,
                "kv_utilization": round(r.kv_utilization, 4),
                "tool_backlog": r.tool_backlog,
                "active_sessions": r.active_sessions,
                "step_latency_ema": round(r.step_latency_ema, 6),
                "incidents": r.incidents,
            } for r in self.replicas],
            "slo": self.slo,
        }

    def render(self) -> str:
        out = [f"fleet health: {self.status.upper()}  "
               f"(replicas={self.fleet.get('replicas', 0)} "
               f"alive={self.fleet.get('alive', 0)} "
               f"draining={self.fleet.get('draining', 0)} "
               f"requeue={self.fleet.get('requeue_depth', 0)})"]
        out.append(f"{'rid':>10} {'status':>9} {'kv_util':>8} "
                   f"{'tools':>6} {'sess':>5} {'step_ema':>9}  incidents")
        for r in self.replicas:
            inc = ",".join(f"{k}x{n}" for k, n in sorted(r.incidents.items())) \
                or "-"
            out.append(f"{r.rid:>10} {r.status:>9} "
                       f"{r.kv_utilization:>8.3f} {r.tool_backlog:>6} "
                       f"{r.active_sessions:>5} {r.step_latency_ema:>9.4f}  "
                       f"{inc}")
        if self.incidents:
            tot = ", ".join(f"{k}: {n}"
                            for k, n in sorted(self.incidents.items()))
            out.append(f"incidents: {tot}")
        if self.slo:
            for name, c in sorted(self.slo.get("classes", {}).items()):
                out.append(
                    f"slo[{name}]: goodput {c['goodput_frac']:.2%} "
                    f"({c['good']}/{c['finished']} finished), "
                    f"violated sessions {c['violated_sessions']}")
        return "\n".join(out)
