"""Streaming per-session SLO tracking over the unified event stream.

PR 6's tracer answers *where the time went* after the fact; this module
answers *is the fleet meeting its contract* while the run is still going.
An :class:`SLOClass` declares per-metric bounds — TTFT, decode inter-token
latency, tool turnaround overhead, and an end-to-end slowdown factor —
and the workload spec stamps a class name onto each session
(``WorkloadSpec.slo_class`` -> ``session.meta["slo_class"]`` -> the
``SUBMIT`` event). :class:`SloTracker` subscribes to the bus, folds every
latency sample into the fixed-bucket histograms from :mod:`repro.obs.
metrics` (rolling quantiles, no sample retention) and keeps per-class
violation and goodput accounting.

All state is driven purely by event *data* (``SUBMIT`` carries
``slo_class`` / ``slo_alpha`` / ``ideal_s``), so the same tracker runs
live on an engine bus or reconstructs from a JSONL dump via
:meth:`SloTracker.replay` — identical numbers either way.

Metric definitions (modeled clock):

    ttft_s          GPU_FIRST_TOKEN.ttft — per round, submit-to-first-token
    itl_s           DECODE_STEP (t - start) / tokens — per dispatched quantum
    tool_overhead_s TOOL_END.t - TOOL_ENQUEUE.t - TOOL_END.duration — the
                    *queueing + stretch* overhead beyond the tool's own
                    runtime (the part scheduling is accountable for)
    e2e_s           FINISH.latency, judged against alpha x ideal_s where
                    alpha is the session's slo_alpha (fallback: the class's
                    e2e_alpha) — sessions without an ideal_s are exempt

``goodput`` follows the paper's definition: finished sessions that met
their end-to-end bound, as a fraction and as req/s over the horizon.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core import events as ev
from repro.core.events import Event, EventBus
from repro.obs.metrics import MetricsRegistry

SLO_METRICS = ("ttft_s", "itl_s", "tool_overhead_s", "e2e_s")


@dataclass(frozen=True)
class SLOClass:
    """Per-metric bounds one workload class is served under."""
    name: str
    ttft_s: float = 10.0            # submit/resume -> first decode token
    itl_s: float = 0.5              # per-token decode latency
    tool_overhead_s: float = 60.0   # turnaround beyond the tool's runtime
    e2e_alpha: float = 3.0          # e2e bound = alpha x isolated ideal

    def bound(self, metric: str) -> float:
        return getattr(self, metric if metric != "e2e_s" else "e2e_alpha")


DEFAULT_SLO_CLASSES: Dict[str, SLOClass] = {
    c.name: c for c in (
        SLOClass("interactive", ttft_s=2.0, itl_s=0.25,
                 tool_overhead_s=15.0, e2e_alpha=2.0),
        SLOClass("standard", ttft_s=10.0, itl_s=0.5,
                 tool_overhead_s=60.0, e2e_alpha=3.0),
        SLOClass("batch", ttft_s=60.0, itl_s=2.0,
                 tool_overhead_s=600.0, e2e_alpha=10.0),
    )
}


class _SessionSLO:
    __slots__ = ("cls", "alpha", "ideal_s", "enqueued_at", "violations",
                 "finished", "e2e_ok")

    def __init__(self, cls: SLOClass, alpha: float, ideal_s: float):
        self.cls = cls
        self.alpha = alpha
        self.ideal_s = ideal_s
        self.enqueued_at: Optional[float] = None   # open tool turnaround
        self.violations: Dict[str, int] = {}
        self.finished = False
        self.e2e_ok: Optional[bool] = None

    def violate(self, metric: str) -> None:
        self.violations[metric] = self.violations.get(metric, 0) + 1


class SloTracker:
    """EventBus subscriber scoring sessions against their SLO class."""

    def __init__(self, bus: Optional[EventBus] = None, *,
                 classes: Optional[Dict[str, SLOClass]] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 default_class: str = "standard"):
        self.classes = dict(classes) if classes is not None \
            else dict(DEFAULT_SLO_CLASSES)
        if default_class not in self.classes:
            self.classes[default_class] = SLOClass(default_class)
        self.default_class = default_class
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.sessions: Dict[int, _SessionSLO] = {}
        self.rejected = 0
        self.horizon = 0.0
        self._dispatch = {
            ev.SUBMIT: self._on_submit,
            ev.REJECT: self._on_reject,
            ev.GPU_FIRST_TOKEN: self._on_first_token,
            ev.DECODE_STEP: self._on_decode_step,
            ev.TOOL_ENQUEUE: self._on_tool_enqueue,
            ev.TOOL_END: self._on_tool_end,
            ev.FINISH: self._on_finish,
        }
        if bus is not None:
            bus.subscribe(None, self.on_event)

    # -- attachment --------------------------------------------------------
    @classmethod
    def install(cls, engine, **kw) -> "SloTracker":
        return cls(engine.bus, **kw)

    @classmethod
    def replay(cls, events, **kw) -> "SloTracker":
        tr = cls(None, **kw)
        for e in events:
            tr.on_event(e)
        return tr

    # -- event pump --------------------------------------------------------
    def on_event(self, e: Event) -> None:
        if e.t > self.horizon:
            self.horizon = e.t
        fn = self._dispatch.get(e.kind)
        if fn is not None:
            fn(e)

    def _observe(self, st: _SessionSLO, metric: str, value: float,
                 bound: float) -> None:
        self.metrics.histogram(f"slo.{st.cls.name}.{metric}").observe(value)
        if value > bound:
            st.violate(metric)
            self.metrics.counter(
                f"slo.{st.cls.name}.{metric}.violations").inc()

    # -- handlers ----------------------------------------------------------
    def _on_submit(self, e: Event) -> None:
        if e.sid in self.sessions:       # cluster re-placement: keep state
            return
        name = e.data.get("slo_class") or self.default_class
        cls = self.classes.get(name)
        if cls is None:
            cls = self.classes[name] = SLOClass(name)
        alpha = float(e.data.get("slo_alpha") or cls.e2e_alpha)
        self.sessions[e.sid] = _SessionSLO(
            cls, alpha, float(e.data.get("ideal_s") or 0.0))

    def _on_reject(self, e: Event) -> None:
        self.rejected += 1

    def _st(self, e: Event) -> Optional[_SessionSLO]:
        return self.sessions.get(e.sid)

    def _on_first_token(self, e: Event) -> None:
        st = self._st(e)
        if st is not None:
            self._observe(st, "ttft_s", float(e.data.get("ttft", 0.0)),
                          st.cls.ttft_s)

    def _on_decode_step(self, e: Event) -> None:
        st = self._st(e)
        if st is None:
            return
        toks = max(1, int(e.data.get("tokens", 1)))
        itl = (e.t - float(e.data.get("start", e.t))) / toks
        self._observe(st, "itl_s", itl, st.cls.itl_s)

    def _on_tool_enqueue(self, e: Event) -> None:
        st = self._st(e)
        if st is not None:
            st.enqueued_at = e.t

    def _on_tool_end(self, e: Event) -> None:
        st = self._st(e)
        if st is None or st.enqueued_at is None:
            return
        turnaround = e.t - st.enqueued_at
        overhead = turnaround - float(e.data.get("duration", 0.0))
        st.enqueued_at = None
        self._observe(st, "tool_overhead_s", max(0.0, overhead),
                      st.cls.tool_overhead_s)

    def _on_finish(self, e: Event) -> None:
        st = self._st(e)
        if st is None or st.finished:
            return
        st.finished = True
        e2e = float(e.data.get("latency", 0.0))
        self.metrics.histogram(f"slo.{st.cls.name}.e2e_s").observe(e2e)
        if st.ideal_s > 0.0:
            st.e2e_ok = e2e <= st.alpha * st.ideal_s
            if not st.e2e_ok:
                st.violate("e2e_s")
                self.metrics.counter(
                    f"slo.{st.cls.name}.e2e_s.violations").inc()
        else:
            st.e2e_ok = True             # no declared ideal: exempt

    # -- rollup ------------------------------------------------------------
    def report(self) -> dict:
        """Per-class goodput/violation rollup + rolling quantiles."""
        by_cls: Dict[str, dict] = {}
        for st in self.sessions.values():
            c = by_cls.setdefault(st.cls.name, {
                "sessions": 0, "finished": 0, "good": 0,
                "violations": dict.fromkeys(SLO_METRICS, 0),
                "violated_sessions": 0})
            c["sessions"] += 1
            if st.finished:
                c["finished"] += 1
                if st.e2e_ok:            # paper goodput: e2e bound met
                    c["good"] += 1
            if st.violations:
                c["violated_sessions"] += 1
                for m, n in st.violations.items():
                    c["violations"][m] += n
        horizon = max(self.horizon, 1e-9)
        for name, c in by_cls.items():
            fin = c["finished"]
            c["goodput_frac"] = c["good"] / fin if fin else 0.0
            c["goodput_rps"] = c["good"] / horizon
            c["quantiles"] = {
                m: h.snapshot() for m in SLO_METRICS
                if (h := self.metrics.histograms.get(
                    f"slo.{name}.{m}")) is not None}
        return {"classes": by_cls, "rejected": self.rejected,
                "horizon_s": self.horizon,
                "sessions": len(self.sessions)}
