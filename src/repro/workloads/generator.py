"""Agentic workload generator — ILR-1..4 and S-ILR-1..3 regimes (paper §6.1).

A hybrid pool in the style of SWE-bench / GitTaskBench / Terminal-Bench /
RepoBench / ∞Bench: multi-round sessions whose *prompt footprint* grows
monotonically across regimes (mean request-level prompt volume 125K -> 167K ->
220K -> 263K tokens) while ideal isolated execution time stays in the same
broad range (the controlled progression is context size, not task length).

Each session: a large first-round context (repository/task state) followed by
rounds of tool-output appends + decodes + tool executions drawn from four
tool kinds with distinct duration distributions.

**Session families** (``n_families > 0``): sessions are grouped into
families sharing a repository context — the dominant real-world structure of
ILR workloads (many agents on one repo). Each member's round-0 context is
``family shared prefix + member-unique tail``; a ``dup_frac`` slice of
members duplicates the family's canonical round-0 context outright (task
retries). Sessions carry ``meta["prefix_hashes"]`` — (chunk key, n_tokens)
pairs at KV-block granularity — which the engine's radix index matches so
family members attach to already-built physical KV blocks instead of
recomputing the shared prefix.
"""
from __future__ import annotations

import math
from dataclasses import astuple, dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.session import Round, Session, make_session
from repro.kvcache.radix import chunk_key_digest
from repro.models import perf_model as pm
from repro.models.config import ModelConfig

# regime -> mean total prompt tokens per session
ILR_MEAN_PROMPT = {
    "ILR-1": 125_000, "ILR-2": 167_000, "ILR-3": 220_000, "ILR-4": 263_000,
    # GPT-OSS regimes: same methodology, restricted upper bound (131K ctx)
    "S-ILR1": 45_000, "S-ILR2": 70_000, "S-ILR3": 95_000,
}

# kind: (p_short, short_mean_s, short_sigma, long_mean_s, long_sigma).
# Durations are *bimodal mixtures* — a terminal command is an `ls` or a
# 5-minute build; a test run is one unit test or the whole suite. This is the
# unpredictability the paper blames for one-shot tool-time heuristics
# misfiring (per-kind EMA is a poor predictor of a bimodal draw). Calibrated
# so ideal session times land in Fig. 6's range (~400-2000 s, tool-dominated).
TOOL_KINDS = {
    "terminal": (0.70, 3.0, 0.6, 90.0, 0.8),
    "file_editor": (0.90, 2.5, 0.5, 30.0, 0.7),
    "task_tracker": (0.95, 1.5, 0.5, 15.0, 0.6),
    "test_runner": (0.45, 15.0, 0.7, 300.0, 0.8),
}

# Long-idle kinds (opt-in via ``WorkloadSpec.tool_mix`` — NOT part of the
# default draw, which must stay byte-stable for seeded baselines): CI
# pipelines and human-in-the-loop waits, the heavy-tailed multi-minute idle
# windows where host DRAM fills with parked KV and the NVMe cold tier pays
# for itself (Astraea's state-aware scheduling observes the same structure).
LONG_TOOL_KINDS = {
    "ci_runner": (0.15, 45.0, 0.6, 600.0, 0.7),
    "human_review": (0.10, 90.0, 0.8, 1500.0, 0.9),
}

ALL_TOOL_KINDS = {**TOOL_KINDS, **LONG_TOOL_KINDS}

# CPU-heavy mix (opt-in via ``WorkloadSpec.tool_mix``): the tool-dominated
# agentic profile where host cores, not the GPU, become the bottleneck —
# builds and test suites (test_runner) plus dense shell activity (terminal)
# with little of the near-free bookkeeping kinds. The cpu_contention
# benchmark drives the shared core pool into queueing with this mix; the
# default uniform draw stays untouched (seeded baselines are byte-stable).
TOOL_HEAVY_MIX = {
    "test_runner": 4.0,
    "terminal": 3.0,
    "file_editor": 1.0,
    "task_tracker": 0.5,
}


@dataclass
class WorkloadSpec:
    regime: str = "ILR-1"
    arrival_rate: float = 0.2          # requests / second (Poisson)
    n_sessions: int = 48
    seed: int = 0
    rounds_lo: int = 3
    rounds_hi: int = 9
    decode_mean: int = 220             # output tokens per round
    slo_alpha: float = 3.0
    max_context: Optional[int] = None  # hard cap (model context limit)
    first_round_frac: float = 0.55     # share of prompt volume in round 1
    tool_time_scale: float = 1.0
    # shared-prefix session families (0 = legacy independent sessions)
    n_families: int = 0
    shared_frac: float = 0.7           # family-shared share of round-0 ctx
    dup_frac: float = 0.1              # P(member duplicates canonical round 0)
    chunk_tokens: int = 32             # prefix-hash granularity (= block size)
    # tool-kind mix: {kind: weight} over ALL_TOOL_KINDS (long-idle kinds
    # included). None keeps the legacy uniform draw over TOOL_KINDS —
    # byte-identical RNG consumption for existing seeded workloads.
    tool_mix: Optional[Dict[str, float]] = None
    # SLO class name stamped onto session.meta["slo_class"] (declared in
    # repro.obs.slo.DEFAULT_SLO_CLASSES or supplied to the SloTracker).
    # None leaves sessions in the tracker's default class; no RNG draws,
    # so seeded workloads stay byte-identical.
    slo_class: Optional[str] = None


def _lognormal(rng, mean: float, sigma: float) -> float:
    mu = math.log(mean) - sigma ** 2 / 2
    return float(rng.lognormal(mu, sigma))


def _chunk_keys(wl, fid: int, useed, shared_len: int, total_len: int,
                chunk: int) -> List:
    """(key, n_tokens) per consecutive token chunk of a round-0 stream.
    Chunks fully inside the family-shared region key on the family; any
    chunk touching member-unique tokens keys on ``useed`` — identical
    streams therefore produce identical key sequences, and the boundary
    chunk never false-matches across members. ``wl`` is the full workload
    spec identity: family ids restart at 0 in every generate() call, so
    sessions from two different workloads fed to one engine must not
    false-match each other's radix blocks."""
    out = []
    pos, i = 0, 0
    while pos < total_len:
        n = min(chunk, total_len - pos)
        key = (("fam", wl, fid, i) if pos + n <= shared_len
               else ("u", wl, useed, i))
        out.append((key, n))
        pos += n
        i += 1
    return out


def generate(spec: WorkloadSpec, cfg: ModelConfig, hw: pm.HardwareSpec,
             tp: int = 1) -> List[Session]:
    rng = np.random.default_rng(spec.seed)
    # workload identity baked into prefix-hash keys; dict fields flatten to
    # sorted item tuples so the identity stays hashable
    wl = tuple(tuple(sorted(x.items())) if isinstance(x, dict) else x
               for x in astuple(spec))
    mix_kinds = mix_probs = None
    if spec.tool_mix:
        unknown = set(spec.tool_mix) - set(ALL_TOOL_KINDS)
        assert not unknown, f"unknown tool kinds in tool_mix: {unknown}"
        assert all(w >= 0 for w in spec.tool_mix.values()), \
            f"negative tool_mix weights: {spec.tool_mix}"
        mix_kinds = sorted(spec.tool_mix)
        total_w = sum(spec.tool_mix[k] for k in mix_kinds)
        assert total_w > 0, f"tool_mix weights sum to zero: {spec.tool_mix}"
        mix_probs = [spec.tool_mix[k] / total_w for k in mix_kinds]
    mean_prompt = ILR_MEAN_PROMPT[spec.regime]
    sessions: List[Session] = []
    # family-level canonical draws: shared repository-context size and the
    # canonical round-0 length (first member + duplicates use it verbatim)
    fam_shared: Dict[int, int] = {}
    fam_canon_first: Dict[int, int] = {}
    t = 0.0
    for i in range(spec.n_sessions):
        t += rng.exponential(1.0 / spec.arrival_rate)
        total_prompt = _lognormal(rng, mean_prompt, 0.45)
        if spec.max_context:
            total_prompt = min(total_prompt, 0.85 * spec.max_context)
        total_prompt = max(2_000.0, total_prompt)
        n_rounds = int(rng.integers(spec.rounds_lo, spec.rounds_hi + 1))
        first = spec.first_round_frac * total_prompt
        fid = useed = None
        if spec.n_families > 0:
            fid = i % spec.n_families
            if fid not in fam_shared:           # first member: canonical
                fam_shared[fid] = max(spec.chunk_tokens,
                                      int(spec.shared_frac * first))
                fam_canon_first[fid] = max(1, int(first))
                first = fam_canon_first[fid]
                useed = ("c", fid)
            elif rng.random() < spec.dup_frac:  # task retry: exact duplicate
                first = fam_canon_first[fid]
                useed = ("c", fid)
            else:                               # shared prefix + unique tail
                first = max(fam_shared[fid] + spec.chunk_tokens, int(first))
                useed = i
        rest = max(0.0, total_prompt - first)
        if n_rounds > 1:
            w = rng.dirichlet(np.ones(n_rounds - 1) * 2.0)
            appends = [first] + list(rest * w)
        else:
            appends = [total_prompt]
        rounds: List[Round] = []
        for r in range(n_rounds):
            dec = int(np.clip(_lognormal(rng, spec.decode_mean, 0.6), 24, 1200))
            if r < n_rounds - 1:
                if mix_kinds is not None:
                    kind = str(rng.choice(mix_kinds, p=mix_probs))
                else:
                    kind = str(rng.choice(list(TOOL_KINDS)))
                p_short, m_s, sg_s, m_l, sg_l = ALL_TOOL_KINDS[kind]
                if rng.random() < p_short:
                    dur = _lognormal(rng, m_s, sg_s)
                else:
                    dur = _lognormal(rng, m_l, sg_l)
                dur *= spec.tool_time_scale
            else:
                kind, dur = None, 0.0
            rounds.append(Round(new_input_tokens=max(1, int(appends[r])),
                                decode_tokens=dec, tool_kind=kind,
                                tool_seconds=dur))
        ideal = pm.ideal_session_time(
            cfg, hw, [(r.new_input_tokens, r.decode_tokens, r.tool_seconds)
                      for r in rounds], tp)
        s = make_session(t, rounds, slo_alpha=spec.slo_alpha,
                         ideal_time=ideal)
        if spec.slo_class is not None:
            s.meta["slo_class"] = spec.slo_class
        if fid is not None:
            s.meta["family"] = fid
            s.meta["prefix_hashes"] = _chunk_keys(
                wl, fid, useed, fam_shared[fid],
                rounds[0].new_input_tokens, spec.chunk_tokens)
            # wire-format anchor (first chunk key, hashed once here): the
            # cluster router matches this against heartbeat radix digests
            # to pull family members toward their repository context's home
            s.meta["prefix_anchor"] = chunk_key_digest(
                s.meta["prefix_hashes"][0][0])
        sessions.append(s)
    return sessions


def describe(sessions: Sequence[Session]) -> Dict[str, float]:
    prompts = [s.total_prompt_tokens for s in sessions]
    ideals = [s.ideal_time for s in sessions]
    return {
        "n": len(sessions),
        "mean_prompt_tokens": float(np.mean(prompts)),
        "p90_prompt_tokens": float(np.percentile(prompts, 90)),
        "mean_ideal_s": float(np.mean(ideals)),
        "mean_rounds": float(np.mean([len(s.rounds) for s in sessions])),
    }
