"""Distributed step builders for the multi-pod dry-run and the launchers.

For every (architecture x shape) cell this module produces:
  * ``input_specs(cfg, shape, mesh)`` — sharded ShapeDtypeStruct stand-ins
    for every input (weak-type-correct, no device allocation), and
  * the step function to ``jax.jit(...).lower(**specs).compile()``:
      - train_4k      -> train_step(params, opt_state, batch)
      - prefill_32k   -> prefill_step(params, batch)
      - decode_32k /
        long_500k     -> serve_step(params, cache, tokens, positions)

Sharding: params via ``sharding.param_specs`` (TP/EP), batch over DP axes,
KV caches over (batch | sequence for B=1 long-context) + head/dim TP.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.distributed import sharding as sh
from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, OptState, adamw_update

F32 = jnp.float32
BF16 = jnp.bfloat16


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _param_sds(cfg: ModelConfig, mesh, dtype=BF16):
    params = jax.eval_shape(
        lambda k: model_zoo.init(cfg, k, dtype), jax.random.PRNGKey(0))
    specs = sh.param_specs(cfg, mesh, params)
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), params, specs)


def _opt_sds(param_sds, cfg=None, mesh=None):
    def moment(s):
        sharding = s.sharding
        if mesh is not None:
            spec = sh.opt_moment_spec(sharding.spec, s.shape, mesh)
            sharding = NamedSharding(mesh, spec)
        return jax.ShapeDtypeStruct(s.shape, F32, sharding=sharding)

    mu = jax.tree.map(moment, param_sds)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return OptState(step, mu, jax.tree.map(lambda s: s, mu))


def _batch_sds(cfg: ModelConfig, spec: ShapeSpec, mesh,
               with_targets: bool) -> Dict[str, Any]:
    dp = P(tuple(a for a in mesh.axis_names if a in ("pod", "data")))
    B, S = spec.global_batch, spec.seq_len
    bsp = P(dp[0] if dp else None, None)
    batch: Dict[str, Any] = {}
    if cfg.family == "whisper":
        enc_len = S // 4                      # conv-stub downsampling
        dec_len = min(cfg.max_target_len, S)
        batch["frames"] = _sds((B, enc_len, cfg.d_model), BF16,
                               mesh, P(bsp[0], None, None))
        batch["tokens"] = _sds((B, dec_len), jnp.int32, mesh, bsp)
        if with_targets:
            batch["targets"] = _sds((B, dec_len), jnp.int32, mesh, bsp)
        return batch
    n_text = S
    if cfg.frontend == "image_patches":
        n_text = S - cfg.n_frontend_tokens
        batch["embeds"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model), BF16,
                               mesh, P(bsp[0], None, None))
    batch["tokens"] = _sds((B, n_text), jnp.int32, mesh, bsp)
    if with_targets:
        batch["targets"] = _sds((B, S), jnp.int32, mesh, bsp)
    return batch


def _cache_sds(cfg: ModelConfig, spec: ShapeSpec, mesh):
    B, S = spec.global_batch, spec.seq_len
    seq_shard = B < sh._dp_size(mesh)
    enc_len = S // 4 if cfg.family == "whisper" else 0
    max_len = min(cfg.max_target_len, S) if cfg.family == "whisper" else S
    shapes = model_zoo.cache_specs(cfg, B, max_len, BF16, enc_len=enc_len)
    specs = sh.cache_specs(cfg, mesh, B, seq_shard=seq_shard)
    return jax.tree.map(lambda s, p: _sds(s.shape, s.dtype, mesh, p),
                        shapes, specs)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def cross_entropy(logits, targets):
    """Vocab-sharding-friendly CE: the target logit is extracted with an
    iota-compare masked reduce (elementwise on the sharded vocab dim + psum)
    instead of take_along_axis, which GSPMD would all-gather."""
    lf = logits.astype(F32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    tgt = jnp.sum(jnp.where(vocab_iota == targets[..., None], lf, 0.0), axis=-1)
    return jnp.mean(lse - tgt)


def chunked_cross_entropy(cfg: ModelConfig, params, hidden, targets,
                          chunk: int = 512):
    """Beyond-paper memory optimization (§Perf iteration 1): compute the CE
    loss by scanning sequence chunks of the final hidden states through the
    unembedding, so the (B, S, V) f32 logits tensor — the single largest
    temp of every train cell — never materializes. Exact same math."""
    from jax import lax as _lax
    from repro.models.transformer import _unembed
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        return cross_entropy(_unembed(cfg, params, hidden), targets)
    nb = S // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nb, chunk, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nb, chunk), 1, 0)

    def body(acc, xs):
        h, t = xs
        lg = _unembed(cfg, params, h).astype(F32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
        tgt = jnp.sum(jnp.where(iota == t[..., None], lg, 0.0), axis=-1)
        return acc + jnp.sum(lse - tgt), None

    total, _ = _lax.scan(body, jnp.zeros((), F32), (hs, ts))
    return total / (B * S)


def build_train_step(cfg: ModelConfig, mesh, *, opt: Optional[OptConfig] = None,
                     remat: bool = True, chunked_ce: bool = False):
    opt = opt or OptConfig()
    pctx = sh.make_pctx(cfg, mesh)

    def loss_fn(params, batch):
        if chunked_ce and cfg.family in ("dense", "moe"):
            from repro.models.transformer import lm_forward
            hidden = lm_forward(cfg, params, batch["tokens"], pctx=pctx,
                                embeds=batch.get("embeds"), remat=remat,
                                return_hidden=True)
            tgt = batch["targets"]
            if hidden.shape[1] != tgt.shape[1]:
                tgt = jnp.pad(tgt, ((0, 0), (hidden.shape[1] - tgt.shape[1], 0)))
            return chunked_cross_entropy(cfg, params, hidden, tgt)
        logits = model_zoo.forward(cfg, params, batch, pctx=pctx, remat=remat)
        tgt = batch["targets"]
        if logits.shape[1] != tgt.shape[1]:      # VLM: frontend tokens prepended
            tgt = jnp.pad(tgt, ((0, 0), (logits.shape[1] - tgt.shape[1], 0)))
        return cross_entropy(logits, tgt)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(opt, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, mesh):
    pctx = sh.make_pctx(cfg, mesh)

    def prefill_step(params, batch):
        last_logits, cache = model_zoo.prefill(cfg, params, batch, pctx=pctx)
        return jnp.argmax(last_logits, axis=-1).astype(jnp.int32), cache

    return prefill_step


def build_decode_step(cfg: ModelConfig, mesh, *, windowed: bool = False):
    pctx = sh.make_pctx(cfg, mesh)
    if windowed:
        from repro.models.transformer import lm_decode_windowed

        def serve_step_w(params, cache, tokens, positions):
            logits, cache = lm_decode_windowed(cfg, params, cache, tokens,
                                               positions, pctx=pctx)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        return serve_step_w

    def serve_step(params, cache, tokens, positions):
        logits, cache = model_zoo.decode(cfg, params, cache, tokens, positions,
                                         pctx=pctx)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


# ---------------------------------------------------------------------------
# cell assembly: (step_fn, example_args_as_SDS, donate)
# ---------------------------------------------------------------------------

def perf_opts() -> set:
    """Beyond-paper perf-iteration toggles (see EXPERIMENTS.md §Perf):
    REPRO_OPT=chunked_ce,moe_replicated,windowed_kv (comma-separated)."""
    return set(filter(None, os.environ.get("REPRO_OPT", "").split(",")))


def build_cell(cfg: ModelConfig, spec: ShapeSpec, mesh,
               ) -> Tuple[Any, Tuple, Dict[str, int]]:
    """Returns (step_fn, sds_args, jit_kwargs) for one dry-run cell."""
    opts = perf_opts()
    if spec.kind == "train":
        params = _param_sds(cfg, mesh)
        opt_state = _opt_sds(params, cfg, mesh)
        batch = _batch_sds(cfg, spec, mesh, with_targets=True)
        fn = build_train_step(cfg, mesh, chunked_ce="chunked_ce" in opts)
        return fn, (params, opt_state, batch), dict(donate_argnums=(0, 1))
    if spec.kind == "prefill":
        params = _param_sds(cfg, mesh)
        batch = _batch_sds(cfg, spec, mesh, with_targets=False)
        fn = build_prefill_step(cfg, mesh)
        return fn, (params, batch), {}
    if spec.kind == "decode":
        params = _param_sds(cfg, mesh)
        windowed = ("windowed_kv" in opts
                    and cfg.layer_pattern == ("local", "global")
                    and cfg.family == "dense")
        if windowed:
            cache = _windowed_cache_sds(cfg, spec, mesh)
        else:
            cache = _cache_sds(cfg, spec, mesh)
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        B = spec.global_batch
        b_ax = dp if B % sh._dp_size(mesh) == 0 and B >= sh._dp_size(mesh) else None
        tokens = _sds((B,), jnp.int32, mesh, P(b_ax))
        positions = _sds((B,), jnp.int32, mesh, P(b_ax))
        fn = build_decode_step(cfg, mesh, windowed=windowed)
        return fn, (params, cache, tokens, positions), dict(donate_argnums=(1,))
    raise ValueError(spec.kind)


def _windowed_cache_sds(cfg: ModelConfig, spec: ShapeSpec, mesh):
    from repro.models.transformer import WindowedKVCache
    B, S = spec.global_batch, spec.seq_len
    shapes = WindowedKVCache.specs(cfg, B, S, BF16)
    h_ax, d_ax = sh.kv_head_axis(cfg, mesh)
    seq_shard = B < sh._dp_size(mesh)
    b_ax: Any = tuple(a for a in mesh.axis_names if a in ("pod", "data")) \
        if not seq_shard else None
    loc = P(None, b_ax, None, h_ax, d_ax)              # ring stays unsharded in W
    glob = P(None, b_ax, "data" if seq_shard else None, h_ax, d_ax)
    specs = WindowedKVCache(loc, loc, glob, glob)
    return jax.tree.map(lambda s, p: _sds(s.shape, s.dtype, mesh, p),
                        shapes, specs)
