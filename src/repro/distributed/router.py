"""Cluster-level control plane (paper §7 "Scalability to Multi-GPU Systems").

The external control plane generalizes to a fleet gateway: engine replicas
register, export their dual-pressure telemetry (KV pool, tool backlog, AIMD
window, EMA step latency), and the router

  * places sessions on the replica with the best (pressure, affinity) score —
    KV locality first: a session returns to the replica that served it last
    (warm state), unless that replica is overloaded or degraded;
  * accumulates session *families* (shared repository contexts) on the
    replica whose radix index already holds their prefix, instead of every
    replica paying the same cold prefill (cross-replica prefix reuse);
  * detects failures by heartbeat timeout and re-queues the victim's sessions
    (they resume by prefix recompute — see checkpoint.snapshot_engine);
  * mitigates stragglers: replicas whose EMA step latency exceeds
    ``straggler_factor`` x fleet median get drained (no new placements);
  * supports elastic join/leave at any time.

**Radix-digest wire format.** Family placement is driven by a compact
radix-root digest each replica exports in its heartbeat
(``RadixIndex.digest(top_k)``, O(k) not O(tree)) — a JSON-serializable dict:

    {"v": <monotone version, bumped on insert/evict>,
     "indexed_blocks": <total blocks in the index>,
     "queries"/"hits"/"hit_tokens": <index-wide prefix stats>,
     "anchors": {<anchor hex>: {"blocks":  # indexed blocks in the subtree
                                "depth":   # longest indexed chunk chain
                                "hits"/"queries"/"hit_rate"}, ...}}

An *anchor* is a direct child of the radix root — the first chunk key of an
indexed prefix stream, identifying one session family / repository context.
Anchor hex keys are ``chunk_key_digest`` values (blake2b of the chunk key's
repr, process-independent), so the incoming session's own chunk-key prefix
(``meta["prefix_hashes"]`` from workloads.generator) can be matched against
any replica's digest without sharing a process. ``_score`` turns the match
into a longest-indexed-prefix bonus with a load-spill guard (a hot family
still overflows to other replicas instead of melting its home); digests are
heartbeat-carried soft state — cleared on failure, gone with the replica on
``leave``, and absent on a re-registered replica until its first beat.

This layer is transport-agnostic: replicas here are in-process Engine objects
(tests/examples drive thousands of simulated nodes); a deployment would put
the same logic behind an RPC server.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.session import KVState, Phase, Session
from repro.kvcache.radix import chunk_key_digest, estimate_digest_match


def _reset_kv_accounting(s: Session, engine=None, now: float = 0.0) -> None:
    """A session leaving a replica loses its device-resident state; it will
    resume elsewhere by prefix recompute. Without this reset the next
    placement inherits phantom block accounting from the old replica.

    When the old replica's engine is handed in, the session is detached
    engine-side too (device lease, pin counters, host-tier entry, live
    backend host copy, membership lists) — a reused or heartbeat-recovered
    engine would otherwise trip its invariants and leak capacity."""
    detach = getattr(engine, "detach_session", None)
    if detach is not None:
        detach(s, now)
    if s.phase == Phase.TOOL:
        # evacuated mid-tool: the in-flight tool was cancelled with the old
        # replica, so the new home re-decodes this round and re-runs the
        # tool at the usual boundary. Without the reset, a session whose
        # decode quantum had completed carries decoded == decode_tokens
        # into DECODING on the new replica — a 0-token quantum that no
        # batch ever picks up and no timer ever finishes (livelock).
        s.decoded = 0
        s.first_token_seen = False
        # the re-decoded round re-records its TTFT on the new home; keep
        # the per-round list aligned (one entry per round) by dropping the
        # stale entry measured on the dead replica
        del s.ttfts[s.cur_round:]
        for k in ("tool_kind_running", "tool_duration"):
            s.meta.pop(k, None)
    s.kv_blocks = 0
    s.resident_len = 0
    s.kv_state = KVState.NONE
    s.meta.pop("swapped_len", None)
    s.meta.pop("host_tier", None)
    s.meta.pop("kv_tier", None)
    # radix bookkeeping is per-replica: the new home's index knows nothing
    # of the chunks this session indexed (or attached to) on the old one
    # (prefix_anchor survives — it is workload identity, not replica state)
    for k in ("prefix_chunks_indexed", "radix_inserted", "radix_hit",
              "radix_queried", "radix_stale_at", "radix_admission_est"):
        s.meta.pop(k, None)


@dataclass
class ReplicaState:
    rid: str
    engine: object = None
    last_heartbeat: float = 0.0
    kv_utilization: float = 0.0
    tool_backlog: int = 0
    active_sessions: int = 0
    step_latency_ema: float = 0.0
    alive: bool = True
    draining: bool = False
    placed: Dict[int, float] = field(default_factory=dict)   # sid -> t
    radix_digest: Optional[dict] = None     # heartbeat-carried soft state


@dataclass
class RouterConfig:
    heartbeat_timeout: float = 10.0
    straggler_factor: float = 2.5
    ema_alpha: float = 0.2
    overload_kv: float = 0.95
    affinity_bonus: float = 0.35
    # cross-replica prefix reuse: score bonus scale for a full-prefix digest
    # match (scaled by matched fraction), and the load-spill guard — above
    # this KV utilization a replica stops *attracting* its family (members
    # overflow by plain pressure score) though per-session affinity stands
    prefix_bonus: float = 0.5
    prefix_spill_kv: float = 0.85
    # bound on the straggler score penalty: an unbounded ema/median ratio
    # lets one slow-tick heartbeat (a big prefill batch, a GC pause) drown
    # every affinity term; sustained stragglers are drained by
    # update_stragglers anyway, so the *score* penalty only needs to break
    # ties away from slow replicas, not to dominate
    straggler_penalty_cap: float = 2.0


class ClusterRouter:
    def __init__(self, cfg: RouterConfig = None):
        self.cfg = cfg or RouterConfig()
        self.replicas: Dict[str, ReplicaState] = {}
        self.session_home: Dict[int, str] = {}     # sid -> last replica
        self.requeued: List[Session] = []
        self.events: List[dict] = []

    # --- membership -----------------------------------------------------
    def register(self, rid: str, engine=None, now: float = None) -> None:
        now = time.monotonic() if now is None else now
        self.replicas[rid] = ReplicaState(rid, engine, last_heartbeat=now)
        self.events.append({"t": now, "ev": "join", "rid": rid})

    def leave(self, rid: str, now: float = None) -> List[Session]:
        """Graceful drain: returns sessions to re-place elsewhere."""
        now = time.monotonic() if now is None else now
        r = self.replicas.pop(rid, None)
        out: List[Session] = []
        if r is not None and r.engine is not None:
            out = list(r.engine.waiting) + list(r.engine.active)
            for s in out:
                _reset_kv_accounting(s, r.engine, now)
        self.events.append({"t": now, "ev": "leave", "rid": rid})
        return out

    # --- telemetry -----------------------------------------------------------
    def heartbeat(self, rid: str, *, kv_utilization: float, tool_backlog: int,
                  active_sessions: int, step_latency: float,
                  radix_digest: Optional[dict] = None,
                  now: float = None) -> None:
        """``radix_digest`` is the replica's radix-root export (see module
        docstring); it is refreshed wholesale each beat — a digest-blind
        replica (no radix index, or an older heartbeat sender) simply never
        attracts family placements."""
        r = self.replicas.get(rid)
        if r is None:
            return
        now = time.monotonic() if now is None else now
        r.last_heartbeat = now
        r.kv_utilization = kv_utilization
        r.tool_backlog = tool_backlog
        r.active_sessions = active_sessions
        r.radix_digest = radix_digest
        a = self.cfg.ema_alpha
        r.step_latency_ema = step_latency if r.step_latency_ema == 0 else \
            (1 - a) * r.step_latency_ema + a * step_latency
        if not r.alive:
            r.alive = True
            self.events.append({"t": now, "ev": "recovered", "rid": rid})

    def check_failures(self, now: float = None) -> List[str]:
        """Heartbeat-timeout detection; re-queues victims' sessions."""
        now = time.monotonic() if now is None else now
        failed = []
        for r in self.replicas.values():
            if r.alive and now - r.last_heartbeat > self.cfg.heartbeat_timeout:
                r.alive = False
                # the advertised prefix state died with the replica's pool;
                # a recovered replica re-advertises on its next heartbeat
                r.radix_digest = None
                failed.append(r.rid)
                self.events.append({"t": now, "ev": "failed", "rid": r.rid})
                if r.engine is not None:
                    victims = list(r.engine.waiting) + list(r.engine.active)
                    for s in victims:
                        _reset_kv_accounting(s, r.engine, now)
                        self.requeued.append(s)
        return failed

    # --- straggler mitigation ---------------------------------------------------
    def _median_latency(self) -> float:
        xs = [r.step_latency_ema for r in self.replicas.values()
              if r.alive and r.step_latency_ema > 0]
        return float(np.median(xs)) if xs else 0.0

    def update_stragglers(self, now: float = None) -> List[str]:
        med = self._median_latency()
        out = []
        for r in self.replicas.values():
            was = r.draining
            r.draining = bool(
                med > 0 and r.step_latency_ema > self.cfg.straggler_factor * med)
            if r.draining and not was:
                out.append(r.rid)
                self.events.append({"t": now or time.monotonic(),
                                    "ev": "straggler_drain", "rid": r.rid})
        return out

    # --- placement -----------------------------------------------------------
    def _prefix_match_frac(self, r: ReplicaState, s: Session) -> float:
        """Fraction of the session's chunk-key prefix already indexed on
        ``r``, estimated from its heartbeat digest (0.0 when either side is
        digest-blind — an empty digest scores exactly neutrally)."""
        hashes = s.meta.get("prefix_hashes")
        if not hashes or not r.radix_digest:
            return 0.0
        anchor = s.meta.get("prefix_anchor")
        if anchor is None:
            anchor = chunk_key_digest(hashes[0][0])
            s.meta["prefix_anchor"] = anchor     # hash once per session
        matched = estimate_digest_match(r.radix_digest, hashes, anchor)
        return matched / len(hashes)

    def _score(self, r: ReplicaState, s: Session) -> float:
        """Lower is better: dual-pressure load + straggler penalty -
        KV-locality affinity - family (longest-indexed-prefix) affinity."""
        load = r.kv_utilization + 0.05 * r.tool_backlog \
            + 0.02 * r.active_sessions
        med = self._median_latency()
        if med > 0 and r.step_latency_ema > 0:
            load += min(self.cfg.straggler_penalty_cap,
                        max(0.0, r.step_latency_ema / med - 1.0))
        if self.session_home.get(s.sid) == r.rid:
            load -= self.cfg.affinity_bonus      # warm KV / state locality
        if r.kv_utilization < self.cfg.prefix_spill_kv:
            # family locality: pull the session toward the replica whose
            # radix index holds the longest slice of its prefix, so one
            # replica accumulates each repository context. The spill guard
            # lets a hot family overflow instead of stacking onto an
            # already-pressured home.
            load -= self.cfg.prefix_bonus * self._prefix_match_frac(r, s)
        return load

    def place(self, s: Session, now: float = None) -> Optional[str]:
        now = time.monotonic() if now is None else now
        cands = [r for r in self.replicas.values()
                 if r.alive and not r.draining
                 and r.kv_utilization < self.cfg.overload_kv]
        if not cands:
            cands = [r for r in self.replicas.values() if r.alive]
        if not cands:
            return None
        best = min(cands, key=lambda r: self._score(r, s))
        best.placed[s.sid] = now
        self.session_home[s.sid] = best.rid
        if best.engine is not None:
            best.engine.submit(s)
        return best.rid

    def dispatch_requeued(self, now: float = None) -> int:
        n = 0
        while self.requeued:
            s = self.requeued.pop(0)
            if self.place(s, now) is None:
                self.requeued.insert(0, s)
                break
            n += 1
        return n

    # --- cluster telemetry ----------------------------------------------------
    def cluster_prefix_stats(self) -> dict:
        """Fleet-wide prefix-reuse view from the heartbeat digests (alive
        replicas only): per-replica digest stats plus the cluster hit rate —
        the fraction of index-consulting sessions, anywhere, that attached
        to an already-built prefix. This is the number family-aware
        placement moves: co-locating a family turns its N-1 cold prefills
        into hits on one replica instead of N-1 misses on N-1 replicas."""
        per_replica = {}
        queries = hits = hit_tokens = blocks = 0
        for r in self.replicas.values():
            if not r.alive or not r.radix_digest:
                continue
            d = r.radix_digest
            per_replica[r.rid] = {
                "anchors": len(d.get("anchors") or {}),
                "indexed_blocks": d.get("indexed_blocks", 0),
                "queries": d.get("queries", 0),
                "hits": d.get("hits", 0),
                "hit_tokens": d.get("hit_tokens", 0),
            }
            queries += d.get("queries", 0)
            hits += d.get("hits", 0)
            hit_tokens += d.get("hit_tokens", 0)
            blocks += d.get("indexed_blocks", 0)
        return {"replicas": per_replica,
                "cluster_prefix_queries": queries,
                "cluster_prefix_hits": hits,
                "cluster_prefix_hit_tokens": hit_tokens,
                "cluster_indexed_blocks": blocks,
                "cluster_prefix_hit_rate": hits / max(1, queries)}

    def stats(self) -> dict:
        """Control-plane counter snapshot for the metrics registry: fleet
        membership, placement totals, the requeue backlog, and the event
        tally (join/leave/failed/recovered/straggler_drain)."""
        by_kind: Dict[str, int] = {}
        for e in self.events:
            by_kind[e["ev"]] = by_kind.get(e["ev"], 0) + 1
        return {
            "replicas": len(self.replicas),
            "alive": sum(1 for r in self.replicas.values() if r.alive),
            "draining": sum(1 for r in self.replicas.values() if r.draining),
            "placements": sum(len(r.placed) for r in self.replicas.values()),
            "sessions_homed": len(self.session_home),
            "requeue_depth": len(self.requeued),
            "events": by_kind,
        }
