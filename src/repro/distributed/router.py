"""Cluster-level control plane (paper §7 "Scalability to Multi-GPU Systems").

The external control plane generalizes to a fleet gateway: engine replicas
register, export their dual-pressure telemetry (KV pool, tool backlog, AIMD
window, EMA step latency), and the router

  * places sessions on the replica with the best (pressure, affinity) score —
    KV locality first: a session returns to the replica that served it last
    (warm state), unless that replica is overloaded or degraded;
  * detects failures by heartbeat timeout and re-queues the victim's sessions
    (they resume by prefix recompute — see checkpoint.snapshot_engine);
  * mitigates stragglers: replicas whose EMA step latency exceeds
    ``straggler_factor`` x fleet median get drained (no new placements);
  * supports elastic join/leave at any time.

This layer is transport-agnostic: replicas here are in-process Engine objects
(tests/examples drive thousands of simulated nodes); a deployment would put
the same logic behind an RPC server.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.session import KVState, Session


def _reset_kv_accounting(s: Session, engine=None, now: float = 0.0) -> None:
    """A session leaving a replica loses its device-resident state; it will
    resume elsewhere by prefix recompute. Without this reset the next
    placement inherits phantom block accounting from the old replica.

    When the old replica's engine is handed in, the session is detached
    engine-side too (device lease, pin counters, host-tier entry, live
    backend host copy, membership lists) — a reused or heartbeat-recovered
    engine would otherwise trip its invariants and leak capacity."""
    detach = getattr(engine, "detach_session", None)
    if detach is not None:
        detach(s, now)
    s.kv_blocks = 0
    s.resident_len = 0
    s.kv_state = KVState.NONE
    s.meta.pop("swapped_len", None)
    s.meta.pop("host_tier", None)
    # radix bookkeeping is per-replica: the new home's index knows nothing
    # of the chunks this session indexed (or attached to) on the old one
    for k in ("prefix_chunks_indexed", "radix_inserted", "radix_hit",
              "radix_queried", "radix_stale_at"):
        s.meta.pop(k, None)


@dataclass
class ReplicaState:
    rid: str
    engine: object = None
    last_heartbeat: float = 0.0
    kv_utilization: float = 0.0
    tool_backlog: int = 0
    active_sessions: int = 0
    step_latency_ema: float = 0.0
    alive: bool = True
    draining: bool = False
    placed: Dict[int, float] = field(default_factory=dict)   # sid -> t


@dataclass
class RouterConfig:
    heartbeat_timeout: float = 10.0
    straggler_factor: float = 2.5
    ema_alpha: float = 0.2
    overload_kv: float = 0.95
    affinity_bonus: float = 0.35


class ClusterRouter:
    def __init__(self, cfg: RouterConfig = None):
        self.cfg = cfg or RouterConfig()
        self.replicas: Dict[str, ReplicaState] = {}
        self.session_home: Dict[int, str] = {}     # sid -> last replica
        self.requeued: List[Session] = []
        self.events: List[dict] = []

    # --- membership -----------------------------------------------------
    def register(self, rid: str, engine=None, now: float = None) -> None:
        now = time.monotonic() if now is None else now
        self.replicas[rid] = ReplicaState(rid, engine, last_heartbeat=now)
        self.events.append({"t": now, "ev": "join", "rid": rid})

    def leave(self, rid: str, now: float = None) -> List[Session]:
        """Graceful drain: returns sessions to re-place elsewhere."""
        now = time.monotonic() if now is None else now
        r = self.replicas.pop(rid, None)
        out: List[Session] = []
        if r is not None and r.engine is not None:
            out = list(r.engine.waiting) + list(r.engine.active)
            for s in out:
                _reset_kv_accounting(s, r.engine, now)
        self.events.append({"t": now, "ev": "leave", "rid": rid})
        return out

    # --- telemetry -----------------------------------------------------------
    def heartbeat(self, rid: str, *, kv_utilization: float, tool_backlog: int,
                  active_sessions: int, step_latency: float,
                  now: float = None) -> None:
        r = self.replicas.get(rid)
        if r is None:
            return
        now = time.monotonic() if now is None else now
        r.last_heartbeat = now
        r.kv_utilization = kv_utilization
        r.tool_backlog = tool_backlog
        r.active_sessions = active_sessions
        a = self.cfg.ema_alpha
        r.step_latency_ema = step_latency if r.step_latency_ema == 0 else \
            (1 - a) * r.step_latency_ema + a * step_latency
        if not r.alive:
            r.alive = True
            self.events.append({"t": now, "ev": "recovered", "rid": rid})

    def check_failures(self, now: float = None) -> List[str]:
        """Heartbeat-timeout detection; re-queues victims' sessions."""
        now = time.monotonic() if now is None else now
        failed = []
        for r in self.replicas.values():
            if r.alive and now - r.last_heartbeat > self.cfg.heartbeat_timeout:
                r.alive = False
                failed.append(r.rid)
                self.events.append({"t": now, "ev": "failed", "rid": r.rid})
                if r.engine is not None:
                    victims = list(r.engine.waiting) + list(r.engine.active)
                    for s in victims:
                        _reset_kv_accounting(s, r.engine, now)
                        self.requeued.append(s)
        return failed

    # --- straggler mitigation ---------------------------------------------------
    def _median_latency(self) -> float:
        xs = [r.step_latency_ema for r in self.replicas.values()
              if r.alive and r.step_latency_ema > 0]
        return float(np.median(xs)) if xs else 0.0

    def update_stragglers(self, now: float = None) -> List[str]:
        med = self._median_latency()
        out = []
        for r in self.replicas.values():
            was = r.draining
            r.draining = bool(
                med > 0 and r.step_latency_ema > self.cfg.straggler_factor * med)
            if r.draining and not was:
                out.append(r.rid)
                self.events.append({"t": now or time.monotonic(),
                                    "ev": "straggler_drain", "rid": r.rid})
        return out

    # --- placement -----------------------------------------------------------
    def _score(self, r: ReplicaState, s: Session) -> float:
        """Lower is better: dual-pressure load + straggler penalty -
        KV-locality affinity."""
        load = r.kv_utilization + 0.05 * r.tool_backlog \
            + 0.02 * r.active_sessions
        med = self._median_latency()
        if med > 0 and r.step_latency_ema > 0:
            load += max(0.0, r.step_latency_ema / med - 1.0)
        if self.session_home.get(s.sid) == r.rid:
            load -= self.cfg.affinity_bonus      # warm KV / state locality
        return load

    def place(self, s: Session, now: float = None) -> Optional[str]:
        now = time.monotonic() if now is None else now
        cands = [r for r in self.replicas.values()
                 if r.alive and not r.draining
                 and r.kv_utilization < self.cfg.overload_kv]
        if not cands:
            cands = [r for r in self.replicas.values() if r.alive]
        if not cands:
            return None
        best = min(cands, key=lambda r: self._score(r, s))
        best.placed[s.sid] = now
        self.session_home[s.sid] = best.rid
        if best.engine is not None:
            best.engine.submit(s)
        return best.rid

    def dispatch_requeued(self, now: float = None) -> int:
        n = 0
        while self.requeued:
            s = self.requeued.pop(0)
            if self.place(s, now) is None:
                self.requeued.insert(0, s)
                break
            n += 1
        return n
