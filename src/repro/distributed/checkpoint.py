"""Fault-tolerant checkpointing.

Model/optimizer checkpoints: per-leaf ``.npy`` shards + a manifest with
integrity hashes, written to a temp dir and atomically renamed (a crashed
writer never corrupts the latest checkpoint). ``save`` can run async on a
background thread (training overlaps the host write). On restore, leaves are
device_put against the target shardings — the restore mesh may differ from
the save mesh (elastic resharding for scale-up/down restarts).

Engine checkpoints: agent sessions are *restartable by construction* (their
context is re-derivable), so the engine snapshot stores only session progress
+ queue state as JSON; KV is rebuilt by prefix recompute on restart.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            str(p.key) if hasattr(p, "key") else
            (str(p.idx) if hasattr(p, "idx") else
             str(p.name) if hasattr(p, "name") else str(p))
            for p in path)
        out.append((name or "leaf", leaf))
    return out


def save(path: str, tree, *, step: int = 0, async_: bool = False,
         keep: int = 3) -> Optional[threading.Thread]:
    """Write a checkpoint at ``path``/step_<step>. Returns the writer thread
    when async."""
    leaves = [(n, np.asarray(l)) for n, l in _flatten(tree)]

    def _write():
        final = os.path.join(path, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for name, arr in leaves:
            fn = os.path.join(tmp, name + ".npy")
            np.save(fn, arr)
            with open(fn, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            manifest["leaves"][name] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256_16": digest}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        _gc(path, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(path: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, target_tree, *, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of ``target_tree`` (leaves may be
    ShapeDtypeStructs). ``shardings``: optional matching pytree — leaves are
    device_put to them (cross-mesh elastic restore)."""
    if step is None:
        step = latest_step(path)
        assert step is not None, f"no checkpoint under {path}"
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _flatten(target_tree)]
    sh_leaves = [s for _, s in _flatten(shardings)] if shardings is not None \
        else [None] * len(names)
    loaded = []
    for name, sh in zip(names, sh_leaves):
        fn = os.path.join(d, name + ".npy")
        arr = np.load(fn)
        if verify:
            meta = manifest["leaves"][name]
            assert list(arr.shape) == meta["shape"], name
        loaded.append(jax.device_put(arr, sh) if sh is not None else arr)
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, loaded), step


# ---------------------------------------------------------------------------
# engine session snapshot (serving-side fault tolerance)
# ---------------------------------------------------------------------------

def snapshot_engine(engine) -> Dict:
    """Serializable progress snapshot: sessions resume via prefix recompute."""
    def sess(s):
        return {"sid": s.sid, "arrival_time": s.arrival_time,
                "cur_round": s.cur_round, "decoded": s.decoded,
                "context_len": s.context_len, "phase": s.phase.value,
                "slo_alpha": s.slo_alpha, "ideal_time": s.ideal_time,
                "service_tokens": s.service_tokens,
                "rounds": [{"new_input_tokens": r.new_input_tokens,
                            "decode_tokens": r.decode_tokens,
                            "tool_kind": r.tool_kind,
                            "tool_seconds": r.tool_seconds}
                           for r in s.rounds]}
    return {"waiting": [sess(s) for s in engine.waiting],
            "active": [sess(s) for s in engine.active],
            "finished_sids": [s.sid for s in engine.finished]}


def restore_engine(engine, snap: Dict) -> int:
    """Re-enqueue unfinished sessions (cold KV, prefix recompute); returns
    the number of recovered sessions."""
    from repro.core.session import Phase, Round, Session
    n = 0
    for rec in snap["waiting"] + snap["active"]:
        rounds = [Round(**r) for r in rec["rounds"]]
        s = Session(sid=rec["sid"], arrival_time=rec["arrival_time"],
                    rounds=rounds, slo_alpha=rec["slo_alpha"],
                    ideal_time=rec["ideal_time"])
        s.cur_round = rec["cur_round"]
        # a session snapshotted mid-tool has decoded == the round's full
        # target; redo the last token (and hence the tool) on recovery —
        # agentic rounds are re-derivable, tool side effects re-run.
        s.decoded = max(0, min(rec["decoded"],
                               rounds[s.cur_round].decode_tokens - 1))
        s.service_tokens = rec["service_tokens"]
        engine.submit(s)
        n += 1
    return n
