"""Sharding rules: DP / TP / EP / SP mapping for every assigned architecture.

Conventions (see DESIGN.md §4):
  * 'model' axis (TP=16): attention heads / d_ff / vocab; GQA KV tensors
    shard on kv-heads when divisible, else on head_dim.
  * 'data' (+ 'pod') axes: batch DP; for dbrx-style MoE the 'data' axis
    doubles as the EP axis (experts sharded, all_to_all dispatch).
  * long-context decode with global_batch=1 shards the KV *sequence* over
    'data' (SP) — XLA partitions the softmax reductions with psums.

``param_specs`` walks the param pytree by path; unknown leaves replicate.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ParallelCtx

MODEL_AXIS = "model"


def make_pctx(cfg: ModelConfig, mesh) -> ParallelCtx:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    ep = "data" if (cfg.moe is not None and cfg.moe.shard_mode == "ep"
                    and "data" in mesh.axis_names) else None
    return ParallelCtx(mesh=mesh, dp_axes=dp, tp_axis=MODEL_AXIS, ep_axis=ep)


def _divisible(n: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


# --- per-leaf rules ---------------------------------------------------------

def _sanitize(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop shardings whose dimension doesn't divide by the axis size."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def _rule(cfg: ModelConfig, mesh, path: Tuple[str, ...], ndim: int,
          shape: Tuple[int, ...]) -> P:
    name = path[-1]
    joined = "/".join(path)
    ep = cfg.moe is not None and cfg.moe.shard_mode == "ep" \
        and "data" in mesh.axis_names and \
        cfg.moe.num_experts % mesh.shape["data"] == 0
    E_AX = "data" if ep else None
    tp = mesh.shape.get(MODEL_AXIS, 1)

    def pad(spec: Tuple) -> P:
        """Left-pad with None for stacked-layer leading dims."""
        return P(*((None,) * (ndim - len(spec)) + spec))

    # embeddings / heads: vocab-shard when divisible, else d_model-shard
    # (whisper 51865 / granite 49155 vocabs don't divide by 16)
    if name == "embed":
        return pad((MODEL_AXIS, None)) if cfg.vocab_size % tp == 0 \
            else pad((None, MODEL_AXIS))
    if name == "lm_head":
        return pad((None, MODEL_AXIS)) if cfg.vocab_size % tp == 0 \
            else pad((MODEL_AXIS, None))
    if name == "dec_pos":
        return pad((None, None))
    # attention
    if name in ("wq", "wk", "wv") and "attn" in joined:
        return pad((None, MODEL_AXIS))
    if name == "wo" and "attn" in joined:
        return pad((MODEL_AXIS, None))
    if name in ("bq", "bk", "bv"):
        return pad((MODEL_AXIS,))
    # dense MLP
    if name in ("w_gate", "w_up") and "moe" not in joined:
        return pad((None, MODEL_AXIS))
    if name == "w_down" and "moe" not in joined:
        return pad((MODEL_AXIS, None))
    # MoE experts
    if name == "router":
        return pad((None, None))
    # perf-iteration toggle (EXPERIMENTS.md §Perf): fine-grained tiny experts
    # (granite, d_ff=512 -> 32/shard under TP) pay a per-layer (E,C,D) psum
    # that dominates the collective term; replicating them removes it at a
    # modest weight-memory cost.
    moe_replicated = cfg.moe is not None and cfg.moe.shard_mode == "tp" and \
        "moe_replicated" in os.environ.get("REPRO_OPT", "")
    if name in ("w_gate", "w_up") and "moe" in joined:
        return pad((E_AX, None, None if moe_replicated else MODEL_AXIS))
    if name == "w_down" and "moe" in joined:
        return pad((E_AX, None if moe_replicated else MODEL_AXIS, None))
    # RWKV time/channel mix
    if name in ("wr", "wk", "wv", "wg", "cm_wk", "cm_wr"):
        return pad((None, MODEL_AXIS))
    if name in ("wo", "cm_wv") and cfg.family == "rwkv6":
        return pad((MODEL_AXIS, None))
    # Mamba2
    if name in ("w_z", "w_x"):
        return pad((None, MODEL_AXIS))
    if name in ("conv_x_w",):
        return pad((MODEL_AXIS, None))
    if name in ("conv_x_b", "gn_scale"):
        return pad((MODEL_AXIS,))
    if name == "w_out" and cfg.family == "zamba2":
        return pad((MODEL_AXIS, None))
    # zamba shared-block input projection
    if name == "in_proj":
        return pad((None, None))
    return P(*((None,) * ndim))


def param_specs(cfg: ModelConfig, mesh, params_or_specs) -> Any:
    """Pytree of PartitionSpec matching the params tree."""

    def spec_of(path, leaf) -> P:
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        shape = tuple(leaf.shape)
        spec = _rule(cfg, mesh, names, len(shape), shape)
        return _sanitize(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_of, params_or_specs)


def param_shardings(cfg: ModelConfig, mesh, params_or_specs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, params_or_specs))


def opt_moment_spec(pspec: P, shape: Tuple[int, ...], mesh) -> P:
    """ZeRO-1-style distributed optimizer: Adam moments additionally shard
    their largest dim over 'data' (f32 moments are 4x the bf16 params — for
    34B-class dense models TP-16 alone cannot fit them on a 16 GB chip).
    The update stays elementwise; GSPMD re-gathers params after the step."""
    if "data" not in mesh.shape:
        return pspec
    used = set()
    for ax in pspec:
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a is not None:
                used.add(a)
    if "data" in used:               # EP weights already consume the data axis
        return pspec
    dsize = mesh.shape["data"]
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    best, best_dim = -1, None
    for i, (dim, ax) in enumerate(zip(shape, spec)):
        if ax is None and dim % dsize == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim is not None and best >= dsize:
        spec[best_dim] = "data"
    return P(*spec)


# --- activation / cache specs ------------------------------------------------

def batch_spec(mesh) -> P:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return P(dp, None)


def kv_head_axis(cfg: ModelConfig, mesh) -> Tuple[Optional[str], Optional[str]]:
    """(spec axis for kv-heads dim, spec axis for head_dim dim)."""
    if _divisible(cfg.n_kv_heads, mesh, MODEL_AXIS):
        return MODEL_AXIS, None
    if _divisible(cfg.head_dim_, mesh, MODEL_AXIS):
        return None, MODEL_AXIS
    return None, None


def cache_specs(cfg: ModelConfig, mesh, batch: int, *,
                seq_shard: bool = False) -> Any:
    """PartitionSpec pytree matching model_zoo.cache_specs structure.

    ``seq_shard=True`` (long-context, batch=1): shard the KV sequence over
    'data' (SP) instead of the batch.
    """
    h_ax, d_ax = kv_head_axis(cfg, mesh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b_ax: Any = dp if not seq_shard and batch % _dp_size(mesh) == 0 else None
    s_ax = "data" if seq_shard else None
    kv = P(None, b_ax, s_ax, h_ax, d_ax)
    if cfg.family in ("dense", "moe"):
        from repro.models.transformer import KVCache
        return KVCache(kv, kv)
    if cfg.family == "whisper":
        from repro.models.whisper import EncDecCache
        return EncDecCache(kv, kv, kv, kv)
    if cfg.family == "rwkv6":
        from repro.models.rwkv6 import RWKVState
        hx = MODEL_AXIS if _divisible(cfg.d_model // cfg.rwkv.head_size, mesh,
                                      MODEL_AXIS) else None
        return RWKVState(P(None, b_ax, None), P(None, b_ax, None),
                         P(None, b_ax, hx, None, None))
    if cfg.family == "zamba2":
        from repro.models.mamba2 import MambaState
        from repro.models.zamba2 import ZambaCache
        hm = MODEL_AXIS if _divisible(cfg.ssm.n_heads(cfg.d_model), mesh,
                                      MODEL_AXIS) else None
        mamba = MambaState(P(None, b_ax, None, None),
                           P(None, b_ax, hm, None, None))
        return ZambaCache(mamba, kv, kv)
    raise ValueError(cfg.family)


def _dp_size(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            n *= mesh.shape[a]
    return n
