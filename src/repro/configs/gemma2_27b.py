"""gemma2-27b — dense, local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    layer_pattern=("local", "global"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_sublayer_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu",
    rope_theta=10_000.0,
)
