"""Architecture registry: ``get_config(arch_id)`` / ``ARCH_IDS``.

Each assigned architecture lives in its own module exposing ``CONFIG``.
``cell_is_supported`` encodes the assignment's skip rules (long_500k only for
sub-quadratic archs) — skips are documented in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec

ARCH_IDS: Tuple[str, ...] = (
    "gemma2-27b",
    "internlm2-20b",
    "qwen2.5-3b",
    "llama3.2-1b",
    "whisper-tiny",
    "llava-next-34b",
    "rwkv6-1.6b",
    "dbrx-132b",
    "granite-moe-3b-a800m",
    "zamba2-1.2b",
)

_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "internlm2-20b": "internlm2_20b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llama3.2-1b": "llama3_2_1b",
    "whisper-tiny": "whisper_tiny",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "dbrx-132b": "dbrx_132b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "zamba2-1.2b": "zamba2_1b2",
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cell_is_supported(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    """(supported, reason-if-skipped) for an (arch x shape) cell."""
    cfg = get_config(arch_id)
    sp = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.is_subquadratic():
        return False, ("pure full-attention arch: 500K-token decode KV is "
                       "quadratic-history; skipped per assignment rules")
    if cfg.family == "whisper" and sp.kind == "decode" and sp.seq_len > 4 * cfg.max_target_len:
        # decoder caches stay at max_target_len; seq_len maps to encoder frames.
        pass
    return True, ""
