"""Assigned input-shape set (identical across the 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``); ``train_4k`` lowers ``train_step``; ``prefill_32k``
lowers the serving prefill step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_NAMES: Tuple[str, ...] = tuple(SHAPES)


def shape(name: str) -> ShapeSpec:
    return SHAPES[name]
