"""whisper-tiny — enc-dec audio backbone; conv frontend is a stub that
consumes precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="whisper",
    n_layers=4,            # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    norm_type="layernorm",
    act="gelu",
    gated_mlp=False,
    max_target_len=448,
    frontend="audio_frames",
)
