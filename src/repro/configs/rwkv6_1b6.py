"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv6",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # = d_model / head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65_536,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
)
