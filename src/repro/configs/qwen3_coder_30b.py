"""Qwen3-Coder-30B-A3B-Instruct — the paper\'s primary evaluation model
(262K context). Used by the reproduction benchmarks, not an assigned arch.
[arXiv:2505.09388]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-coder-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151_936,
    rope_theta=10_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768, shard_mode="tp"),
)
CONTEXT_LIMIT = 262_144
