"""dbrx-132b — MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,            # == d_ff_expert (kept for param accounting)
    vocab_size=100_352,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752, shard_mode="ep"),
)
