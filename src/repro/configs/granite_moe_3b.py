"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8, d_ff=512.
Experts are TP-sharded (not EP): with 512-wide experts the EP all_to_all
volume exceeds expert FLOPs — see DESIGN.md. [hf:ibm-granite/granite-3.0]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512, shard_mode="tp"),
)
