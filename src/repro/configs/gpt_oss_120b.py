"""GPT-OSS-120B — the paper\'s larger evaluation model (131K context).
Used by the reproduction benchmarks, not an assigned arch. [OpenAI model card]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="gpt-oss-120b",
    family="moe",
    n_layers=36,
    d_model=2880,
    n_heads=64,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2880,
    vocab_size=201_088,
    layer_pattern=("local", "global"),
    sliding_window=128,
    moe=MoEConfig(num_experts=128, top_k=4, d_ff_expert=2880, shard_mode="ep"),
)
CONTEXT_LIMIT = 131_072
