"""llava-next-34b — VLM; anyres patch tiling is a stub that provides
precomputed patch embeddings prepended to the text sequence.
[hf:llava-hf/llava-v1.6; backbone per assignment]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    frontend="image_patches",
    n_frontend_tokens=2880,     # anyres 5-tile x 576 patches
)
