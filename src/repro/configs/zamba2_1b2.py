"""zamba2-1.2b — Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="zamba2",
    n_layers=38,           # mamba2 layers
    d_model=2048,
    n_heads=32,            # shared attention block (MHA, kv=32)
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=64),
)
