"""Shared host-CPU core pool: the contended resource under tools, swap
staging, and NVMe spool I/O.

MARS's thesis is *coupled* GPU-CPU pressure, but a per-item latency model
(every tool completes after its nominal duration, transfers consume zero
CPU) cannot express the coupling: a tool burst must visibly delay swap
drains and staged NVMe restores, and vice versa. ``CpuPool`` is the single
bounded pool every CPU consumer leases from:

* ``SimToolExecutor`` / ``RealToolExecutor`` tool invocations,
* the swap path's D2H/H2D staging copies (``TieredStore`` / ``SwapStream``),
* ``DiskTier`` spool writes and fill reads.

Queueing model (modeled / sim path)
-----------------------------------
``cores`` identical, non-preemptive cores. Work beyond capacity queues
FIFO **per priority class**: class 0 (transfer staging — small, latency-
critical, on the KV restore path) is placed before any waiting class-1
work (tools), but never preempts a running lease. Placement is *eager*:
``submit`` assigns each lease a deterministic ``(start, end)`` against the
earliest-free core immediately, so tier code can compute delayed ready
times synchronously (the same pattern as ``DiskTier``'s queue slots) and
the sim driver can jump the clock to the exact next completion. A later
priority-0 submit or a ``cancel`` re-places only not-yet-started leases
(LIFO-undo of their placements, then FIFO re-placement per class), so
announced starts never move.

Interference model
------------------
Co-running work contends for shared caches/memory bandwidth: a lease that
starts while ``b`` of the *other* ``cores`` are busy runs stretched by

    stretch = 1 + interference * b / cores        (fixed at start)

i.e. up to ``1 + interference`` when every other core is occupied. The
factor is fixed at lease start (not re-evaluated as neighbours come and
go) — a documented first-order approximation that keeps the sim schedule
deterministic and eager.

Memory is tracked, not enforced: leases may declare ``mem_gb`` and the
pool reports peak usage, but cores are the binding resource of the model
(matching the CPU-centric agentic-execution study this reproduces, where
core oversubscription — not RSS — drives the collapse).

Live (wall-clock) path
----------------------
Real executors size their thread pools from ``cores`` and use the
accounting API (``acquire``/``release``/``note_wait``) so occupancy and
queue-wait gauges stay live without a modeled schedule.

``queue_wait_estimate`` is the admission/retention pressure signal: the
projected delay before ``cost_s`` of new work could start, optionally
with ``extra_backlog_s`` of work hypothetically admitted ahead of it
(spread across cores — an M/G/c-style backlog/c approximation).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class CpuPoolConfig:
    cores: int = 16
    # service-time stretch slope under full co-occupancy (see module doc)
    interference: float = 0.25
    # CPU seconds consumed per second of transfer for staging copies
    # (D2H/H2D bounce buffers, spool write/read pumps)
    transfer_cpu_frac: float = 0.15
    mem_gb: float = 0.0                # 0 => untracked


@dataclass
class CpuLease:
    """One unit of placed CPU work. ``start``/``end`` are modeled seconds;
    ``queue_wait = start - requested_at`` is the time spent waiting for a
    core. Immutable once its start has been reported by ``advance``."""
    seq: int
    sid: int
    kind: str                           # "tool" | "swap" | "spool"
    tag: str                            # consumer detail (e.g. tool kind)
    priority: int                       # 0 = transfers, 1 = tools
    cost_s: float                       # nominal (unstretched) service time
    requested_at: float
    mem_gb: float = 0.0
    start: float = 0.0
    end: float = 0.0
    stretch: float = 1.0
    popped_slot: float = 0.0            # free-time value this lease consumed
    reported_start: bool = False
    reported_end: bool = False
    cancelled: bool = False

    @property
    def queue_wait(self) -> float:
        return max(0.0, self.start - self.requested_at)


class CpuPool:
    def __init__(self, cfg: Optional[CpuPoolConfig] = None):
        self.cfg = cfg or CpuPoolConfig()
        self.cores = max(1, int(self.cfg.cores))
        # sorted multiset of per-core free times under the current schedule
        self._slots: List[float] = [0.0] * self.cores
        self._active: List[CpuLease] = []
        self._seq = 0
        self._t = 0.0                   # high-water advance() time
        # live accounting (wall-clock executors)
        self._live_busy = 0
        self._live_pending = 0
        self._live_mem_gb = 0.0
        # stats
        self.n_leases: Dict[str, int] = {}
        self.busy_s: Dict[str, float] = {}
        self.queue_wait_s: Dict[str, float] = {}
        self.max_backlog = 0
        self.max_stretch = 1.0
        self.peak_mem_gb = 0.0
        self._live_tokens: Dict[int, Tuple[float, str, float]] = {}
        self._live_tok_seq = 0

    # --- modeled scheduling (sim path) ---------------------------------
    def submit(self, now: float, cost_s: float, *, sid: int = -1,
               kind: str = "tool", tag: str = "", priority: int = 1,
               mem_gb: float = 0.0) -> CpuLease:
        """Place ``cost_s`` of CPU work; returns the lease with its
        deterministic (start, end) already assigned. Priority 0 is placed
        ahead of any not-yet-started priority-1 work (FIFO within class)."""
        self._seq += 1
        lease = CpuLease(seq=self._seq, sid=sid, kind=kind, tag=tag,
                         priority=int(priority), cost_s=max(0.0, cost_s),
                         requested_at=now, mem_gb=mem_gb)
        waiting = self._unstarted(now)
        self._active.append(lease)
        if lease.priority == 0 and any(w.priority > 0 for w in waiting):
            # class-0 work goes ahead of every waiting class-1 lease:
            # undo the waiting placements and re-place with the new lease
            # slotted into its class position
            self._undo(waiting)
            for l in sorted(waiting + [lease],
                            key=lambda l: (l.priority, l.seq)):
                self._place(l, now)
        else:
            self._place(lease, now)
        self.n_leases[kind] = self.n_leases.get(kind, 0) + 1
        backlog = sum(1 for l in self._active if l.start > now)
        self.max_backlog = max(self.max_backlog, backlog)
        if self.cfg.mem_gb:
            in_use = sum(l.mem_gb for l in self._active
                         if l.start <= now < l.end) + self._live_mem_gb
            self.peak_mem_gb = max(self.peak_mem_gb, in_use)
        return lease

    def _place(self, lease: CpuLease, not_before: float) -> None:
        v = self._slots.pop(0)
        lease.popped_slot = v
        lease.start = max(not_before, lease.requested_at, v)
        busy_others = sum(1 for t in self._slots if t > lease.start)
        lease.stretch = 1.0 + self.cfg.interference * busy_others / self.cores
        lease.end = lease.start + lease.cost_s * lease.stretch
        bisect.insort(self._slots, lease.end)
        self.max_stretch = max(self.max_stretch, lease.stretch)

    def _unstarted(self, now: float) -> List[CpuLease]:
        """Leases whose placement may still move: scheduled start in the
        future and start not yet announced via ``advance``."""
        return [l for l in self._active
                if not l.reported_start and l.start > max(now, self._t)]

    def _undo(self, leases: List[CpuLease]) -> None:
        """Withdraw placements, LIFO — exact, because a later placement can
        only have consumed an earlier one's end slot, so undoing newest-
        first always finds each lease's end still in the multiset."""
        for l in sorted(leases, key=lambda l: -l.seq):
            i = bisect.bisect_left(self._slots, l.end)
            if i < len(self._slots) and self._slots[i] == l.end:
                self._slots.pop(i)
                bisect.insort(self._slots, l.popped_slot)

    def advance(self, now: float) -> Tuple[List[CpuLease], List[CpuLease]]:
        """Report (started, completed) leases with start/end <= ``now``,
        each exactly once, in time order. Completed leases leave the active
        set; their core free times persist in the schedule."""
        started = [l for l in self._active
                   if not l.reported_start and l.start <= now]
        started.sort(key=lambda l: (l.start, l.seq))
        for l in started:
            l.reported_start = True
            self.queue_wait_s[l.kind] = (self.queue_wait_s.get(l.kind, 0.0)
                                         + l.queue_wait)
        completed = [l for l in self._active
                     if not l.reported_end and l.end <= now]
        completed.sort(key=lambda l: (l.end, l.seq))
        for l in completed:
            l.reported_end = True
            self.busy_s[l.kind] = (self.busy_s.get(l.kind, 0.0)
                                   + (l.end - l.start))
        self._active = [l for l in self._active if not l.reported_end]
        self._t = max(self._t, now)
        return started, completed

    def cancel(self, lease: CpuLease, now: float) -> None:
        """Withdraw a lease: a queued one releases its (future) core slot
        and later waiting work backfills earlier; a running one frees its
        core at ``now``. Reported-complete leases are left alone."""
        if lease.cancelled or lease.reported_end:
            return
        lease.cancelled = True
        if lease not in self._active:
            return
        self._active.remove(lease)
        waiting = self._unstarted(now)
        self._undo(waiting)
        i = bisect.bisect_left(self._slots, lease.end)
        if i < len(self._slots) and self._slots[i] == lease.end:
            self._slots.pop(i)
            # a queued lease gives back the slot it consumed; a running
            # one frees its core the moment it is cancelled
            freed = lease.popped_slot if lease.start > now else now
            bisect.insort(self._slots, freed)
        for l in sorted(waiting, key=lambda l: (l.priority, l.seq)):
            self._place(l, now)

    def next_event_time(self, kind: Optional[str] = None) -> Optional[float]:
        """Earliest unreported lease completion (optionally of one kind) —
        queued work is already eagerly scheduled, so this accounts for
        queueing delay, not just running leases."""
        ends = [l.end for l in self._active
                if not l.reported_end and (kind is None or l.kind == kind)]
        return min(ends) if ends else None

    def queue_wait_estimate(self, now: float, cost_s: float = 0.0,
                            extra_backlog_s: float = 0.0) -> float:
        """Projected seconds *one* new lease would wait for a core: the
        earliest core-free time under the current schedule, pushed out by
        ``extra_backlog_s`` of hypothetical work spread across cores. This
        is the per-transfer pricing signal (retention decisions)."""
        if not self._slots:
            return 0.0
        v = self._slots[0] + extra_backlog_s / self.cores
        return max(0.0, v - now)

    def horizon_wait(self, now: float, extra_backlog_s: float = 0.0) -> float:
        """Sustained-oversubscription signal: scheduled work-in-system
        (plus ``extra_backlog_s`` hypothetical seconds) divided by cores —
        the expected core-queueing delay a *steady* new CPU consumer
        experiences, not the one-lease best case above. Near zero on a
        quiet pool; grows with every long tool parked on a core. This is
        what the control plane's ``cpu_queue_bound_s`` admission term
        compares against."""
        work = sum(max(0.0, t - now) for t in self._slots)
        return (work + max(0.0, extra_backlog_s)) / self.cores

    # --- live accounting (wall-clock path) ------------------------------
    def acquire(self, now: float, kind: str = "tool",
                mem_gb: float = 0.0) -> int:
        self._live_tok_seq += 1
        tok = self._live_tok_seq
        self._live_tokens[tok] = (now, kind, mem_gb)
        self._live_busy += 1
        self._live_mem_gb += mem_gb
        self.n_leases[kind] = self.n_leases.get(kind, 0) + 1
        self.peak_mem_gb = max(self.peak_mem_gb, self._live_mem_gb)
        return tok

    def release(self, now: float, tok: int) -> None:
        t0, kind, mem = self._live_tokens.pop(tok, (now, "tool", 0.0))
        self._live_busy = max(0, self._live_busy - 1)
        self._live_mem_gb = max(0.0, self._live_mem_gb - mem)
        self.busy_s[kind] = self.busy_s.get(kind, 0.0) + max(0.0, now - t0)

    def note_wait(self, kind: str, wait_s: float) -> None:
        self.queue_wait_s[kind] = (self.queue_wait_s.get(kind, 0.0)
                                   + max(0.0, wait_s))

    def pending_inc(self) -> None:
        self._live_pending += 1
        self.max_backlog = max(self.max_backlog, self._live_pending)

    def pending_dec(self) -> None:
        self._live_pending = max(0, self._live_pending - 1)

    # --- gauges ----------------------------------------------------------
    def busy_cores(self, now: float) -> int:
        modeled = sum(1 for l in self._active if l.start <= now < l.end)
        return modeled + self._live_busy

    def backlog(self, now: float) -> int:
        modeled = sum(1 for l in self._active if l.start > now)
        return modeled + self._live_pending

    def stats(self) -> dict:
        return {
            "cores": self.cores,
            "interference": self.cfg.interference,
            "n_leases": dict(self.n_leases),
            "busy_s": {k: round(v, 6) for k, v in self.busy_s.items()},
            "queue_wait_s": {k: round(v, 6)
                             for k, v in self.queue_wait_s.items()},
            "queue_wait_total_s": round(sum(self.queue_wait_s.values()), 6),
            "max_backlog": self.max_backlog,
            "max_stretch": round(self.max_stretch, 6),
            "peak_mem_gb": round(self.peak_mem_gb, 6),
        }
