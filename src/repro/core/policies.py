"""Scheduling policies: MARS + the paper's baselines (§6.2), all pluggable
into the same engine (identical tool stacks, batching, KV accounting — the
paper's fairness requirement).

    FCFS          vLLM default: arrival order, no admission, KV freed at tool
    Autellix      PLAS: program-level accumulated-service priority; resource-
                  agnostic (no admission control, no KV management)
    InferCept     one-shot min-cost {preserve | swap | discard} at tool time,
                  from per-tool-type EMA duration estimates
    Continuum     pin with fixed TTL at tool start
    Continuum-Dy  pin with TTL = EMA(tool kind) * factor
    MARS          external control plane (AIMD admission + queue packing) +
                  MLFQ coordinator + opportunistic co-scheduler (adaptive,
                  re-evaluated retention; priority-aligned eviction)
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Sequence, Tuple

from repro.core.admission import ControlPlaneConfig, ExternalControlPlane
from repro.core.coscheduler import CoSchedulerConfig, OpportunisticCoScheduler
from repro.core.events import EventBus
from repro.core.mlfq import MLFQConfig, PriorityCoordinator
from repro.core.session import KVAction, KVState, Session  # noqa: F401
from repro.core.telemetry import Telemetry


class PerfOracle(Protocol):
    def recompute_time(self, n_tokens: int) -> float: ...
    def swap_time(self, n_tokens: int) -> float: ...
    def prefill_rate(self) -> float: ...   # sustainable prefill tokens/s


@dataclass
class Services:
    """Engine-owned services handed to the policy once, after construction
    (the policy-binding API — replaces the ``bind_services`` kwarg sprawl).

    * ``host_tier`` — the engine's ``TieredStore`` (host DRAM + NVMe
      orchestration; wears the same capacity/cost surface as a bare tier).
    * ``swap_size_fn`` — session -> (tokens, blocks) that would *actually*
      cross PCIe on offload (radix-shared blocks stay on device).
    * ``async_swap`` — the backend runs a background swap stream, so
      swap-in prefetches overlap other sessions' compute.
    * ``prefix_lookup`` — session -> blocks of its chunk-key prefix already
      indexed here (radix-aware admission sizing).
    * ``disk_tier`` — NVMe cold tier (None => three-way retention).
    * ``cpu_pool`` — the shared host-CPU core pool tools/swap/spool lease
      from: admission prices projected core-queueing delay, retention
      prices the CPU-side transfer delay into PIN/OFFLOAD/OFFLOAD_DISK.

    Baselines ignore what they don't price."""
    host_tier: Optional[object] = None
    swap_size_fn: Optional[Callable[[Session], Tuple[int, int]]] = None
    async_swap: bool = False
    prefix_lookup: Optional[Callable[[Session], int]] = None
    disk_tier: Optional[object] = None
    cpu_pool: Optional[object] = None


class Policy:
    """Engine hook points. The base class is the FCFS/throughput-centric
    engine: admit everything, serve in arrival order, drop KV at tool
    boundaries, preempt most-recent-first."""

    name = "fcfs"

    def __init__(self, telem: Telemetry, bus: EventBus, oracle: PerfOracle):
        self.telem = telem
        self.bus = bus
        self.oracle = oracle
        self.host_tier = None          # bound by the engine when tiered
        self.disk_tier = None          # NVMe cold tier (four-way retention)
        self.swap_size_fn = None       # session -> (tokens, blocks) moved
        self.async_swap = False        # backend runs a background swap stream
        self.prefix_lookup = None      # session -> indexed prefix blocks
        self.cpu_pool = None           # shared host-CPU core pool

    def bind(self, services: Services) -> None:
        """Bind the engine-owned ``Services`` bundle (see its docstring).
        Subclasses extend this — not ``bind_services``, which is only a
        deprecation shim around it."""
        self.host_tier = services.host_tier
        self.disk_tier = services.disk_tier
        self.swap_size_fn = services.swap_size_fn
        self.async_swap = services.async_swap
        self.prefix_lookup = services.prefix_lookup
        self.cpu_pool = services.cpu_pool

    def bind_services(self, host_tier=None, swap_size_fn=None,
                      async_swap=False, prefix_lookup=None,
                      disk_tier=None, cpu_pool=None) -> None:
        """Deprecated kwarg form of :meth:`bind` — kept one release for
        out-of-tree callers; routes through ``bind(Services(...))`` so
        subclass extensions of ``bind`` still run."""
        warnings.warn(
            "Policy.bind_services(**kwargs) is deprecated; pass a single "
            "Services dataclass to Policy.bind() instead",
            DeprecationWarning, stacklevel=2)
        self.bind(Services(host_tier=host_tier, swap_size_fn=swap_size_fn,
                           async_swap=async_swap, prefix_lookup=prefix_lookup,
                           disk_tier=disk_tier, cpu_pool=cpu_pool))

    # --- admission (external) ----------------------------------------------
    def admit(self, queue: List[Session], now: float) -> List[Session]:
        return list(queue)

    # --- intra-engine ordering ----------------------------------------------
    def order(self, ready: Sequence[Session], now: float) -> List[Session]:
        return sorted(ready, key=lambda s: s.arrival_time)

    # --- iteration-level batching hooks -------------------------------------
    def prefill_budget(self, token_budget: int, decode_tokens: int) -> int:
        """Prefill token budget for one mixed iteration, given the tokens
        the decode lanes already claimed. Baselines: whatever the decodes
        left (no split — prefill waves may inflate the iteration)."""
        return max(0, token_budget - decode_tokens)

    def charge_service(self, s: Session, tokens: int, now: float) -> None:
        """Charge ``tokens`` of GPU service dispatched this iteration.
        Baselines: plain accumulation. MARS routes this through the MLFQ's
        quantum-by-token accounting."""
        s.service_tokens += tokens

    # --- tool boundary --------------------------------------------------------
    def on_tool_yield(self, s: Session, now: float) -> Tuple[KVAction, float]:
        return KVAction.FREE, 0.0

    def tick_pinned(self, pinned: Sequence[Session], now: float) -> List[Session]:
        """Pins to revoke this tick (TTL expiry / re-evaluation)."""
        return []

    def revoke_actions(self, pinned: Sequence[Session], now: float
                       ) -> List[Tuple[Session, KVAction]]:
        """Three-way revocation: (session, FREE | OFFLOAD) per revoked pin.
        Baselines drop; MARS may demote to the host tier instead."""
        return [(s, KVAction.FREE) for s in self.tick_pinned(pinned, now)]

    def reclaim_order(self, pinned: Sequence[Session], now: float) -> List[Session]:
        return sorted(pinned, key=lambda s: s.pinned_since)

    def reclaim_action(self, s: Session, now: float) -> KVAction:
        """What to do with a pin reclaimed under allocation pressure."""
        return KVAction.FREE

    # --- eviction/preemption ---------------------------------------------------
    def eviction_order(self, victims: Sequence[Session], now: float,
                       requester: Optional[Session] = None) -> List[Session]:
        """Victims the ``requester`` may preempt, best-first. vLLM default:
        LIFO by arrival, and a requester never preempts sessions that arrived
        before it (stability: the eviction order is the reverse of the
        service order, so mutual-eviction livelock is impossible)."""
        if requester is not None:
            victims = [v for v in victims
                       if v.arrival_time > requester.arrival_time]
        return sorted(victims, key=lambda s: -s.arrival_time)

    # --- prefill chunking --------------------------------------------------------
    def prefill_chunk(self, want_tokens: int, free_blocks: int,
                      block_size: int) -> int:
        """Baselines: fixed-granularity chunked prefill, no shrinking."""
        if free_blocks <= 0:
            return 0
        grantable = free_blocks * block_size
        return want_tokens if want_tokens <= grantable else 0


class AutellixPolicy(Policy):
    """Program-Level Aware Scheduling: cumulative *program* service-time
    priority, snapshotted at call submission (non-preemptive at the call
    level — a call's priority does not decay while it runs)."""

    name = "autellix"

    def order(self, ready, now):
        for s in ready:
            if "plas_key" not in s.meta or s.meta.get("plas_round") != s.cur_round:
                s.meta["plas_key"] = s.service_seconds
                s.meta["plas_round"] = s.cur_round
        return sorted(ready, key=lambda s: (s.meta["plas_key"], s.arrival_time))


class InferCeptPolicy(Policy):
    """Min-cost one-shot {preserve, swap, discard} at the tool boundary.

    Costs in byte-seconds (memory waste x duration), following InferCept's
    formulation, with EMA tool-duration estimates."""

    name = "infercept"

    def on_tool_yield(self, s, now):
        est = self.telem.tool_estimate(s.cur.tool_kind)
        kv = max(1, s.kv_blocks)
        preserve = kv * est
        swap = kv * 2.0 * self.oracle.swap_time(s.resident_len)
        discard = 0.5 * kv * self.oracle.recompute_time(s.resident_len)
        best = min((preserve, KVAction.PIN), (swap, KVAction.SWAP),
                   (discard, KVAction.FREE), key=lambda x: x[0])
        return best[1], float("inf")   # one-shot: no TTL re-evaluation


class ContinuumPolicy(Policy):
    """Fixed KV time-to-live at tool start."""

    name = "continuum"
    fixed_ttl = 30.0

    def on_tool_yield(self, s, now):
        return KVAction.PIN, self.fixed_ttl

    def tick_pinned(self, pinned, now):
        return [s for s in pinned if now - s.pinned_since > s.pin_ttl]

    def reclaim_order(self, pinned, now):
        # closest-to-expiry first
        return sorted(pinned, key=lambda s: s.pinned_since + s.pin_ttl - now)


class ContinuumDynPolicy(ContinuumPolicy):
    """TTL = per-tool-kind EMA estimate x factor (official dynamic heuristic)."""

    name = "continuum-dy"
    ttl_factor = 1.25

    def on_tool_yield(self, s, now):
        est = self.telem.tool_estimate(s.cur.tool_kind)
        return KVAction.PIN, max(1.0, self.ttl_factor * est)


@dataclass
class MARSConfig:
    control: ControlPlaneConfig = field(default_factory=ControlPlaneConfig)
    mlfq: MLFQConfig = field(default_factory=MLFQConfig)
    cosched: CoSchedulerConfig = field(default_factory=CoSchedulerConfig)
    # ablations (paper Fig. 13)
    disable_control_plane: bool = False
    disable_coordinator: bool = False
    disable_coscheduler: bool = False


class MARSPolicy(Policy):
    name = "mars"

    def __init__(self, telem, bus, oracle, cfg: Optional[MARSConfig] = None):
        super().__init__(telem, bus, oracle)
        self.cfg = cfg or MARSConfig()
        self.control = ExternalControlPlane(self.cfg.control, telem, bus)
        self.coord = PriorityCoordinator(self.cfg.mlfq)
        self.cosched = OpportunisticCoScheduler(
            self.cfg.cosched, telem, oracle.recompute_time,
            getattr(oracle, "prefill_rate", None))
        if self.cfg.disable_control_plane:
            self.name = "mars-no-ctrl"
        if self.cfg.disable_coordinator:
            self.name = "mars-no-coord"
        if self.cfg.disable_coscheduler:
            self.name = "mars-no-cosched"

    def bind(self, services: Services) -> None:
        super().bind(services)
        host_tier, disk_tier = services.host_tier, services.disk_tier
        swap_size_fn = services.swap_size_fn
        # radix-aware admission (Alg. 1 ext.): queue packing estimates
        # footprint net of the already-indexed shared prefix
        self.control.prefix_lookup = services.prefix_lookup
        # CPU-oversubscription admission term: the control plane defers
        # admits whose tool profile would push core-queueing delay past
        # its bound, the way it already prices HBM blocks
        self.control.cpu_pool = services.cpu_pool
        self.cosched.swap_seconds = \
            host_tier.swap_seconds if host_tier is not None else None
        # price the PCIe leg by what per-block offload actually moves
        self.cosched.swap_tokens = \
            (lambda s: swap_size_fn(s)[0]) if swap_size_fn else None
        # async stream: prefetched swap-ins overlap other sessions'
        # compute, so the restore no longer serializes a GPU tick
        self.cosched.swap_in_overlapped = bool(services.async_swap)
        # NVMe cold tier: staged-restore pricing for the fourth outcome
        self.cosched.disk_read_seconds = \
            disk_tier.read_seconds if disk_tier is not None else None
        self.cosched.disk_write_seconds = \
            disk_tier.write_seconds if disk_tier is not None else None
        # CPU-side transfer delay: staging copies lease from the shared
        # core pool, so a warm resume is only worth choosing when the CPU
        # side can deliver it — retention subtracts the projected core
        # wait from the offload/disk nets
        pool = services.cpu_pool
        if pool is not None and pool.cfg.transfer_cpu_frac > 0.0:
            frac = pool.cfg.transfer_cpu_frac
            self.cosched.cpu_wait = (
                lambda cost_s, now: pool.queue_wait_estimate(
                    now, frac * cost_s))
        else:
            self.cosched.cpu_wait = None

    def _sized_blocks(self, s: Session) -> int:
        if self.swap_size_fn is not None:
            # per-block offload: only private (non-shared) blocks occupy
            # the tier — same sizing _offload_kv's can_store will apply
            return self.swap_size_fn(s)[1]
        # size with the tier's own block size (= engine block size), not
        # cosched.block_size — they are configured independently and a
        # drifted precheck would disagree with _offload_kv's can_store
        return -(-s.resident_len // self.host_tier.block_size)

    def _host_can_take(self, s: Session) -> bool:
        if self.host_tier is None:
            return False
        return self.host_tier.can_store(self._sized_blocks(s))

    def _disk_can_take(self, s: Session) -> bool:
        if self.host_tier is None or self.disk_tier is None:
            return False
        return self.disk_tier.can_store(self._sized_blocks(s))

    def _offload_fallback(self, s: Session, now: float,
                          action: KVAction) -> KVAction:
        """Capacity-checked tier choice: the preferred off-device tier
        falls back to the other when full — but only if the other tier's
        own net benefit is positive — and to FREE when neither works."""
        if action == KVAction.OFFLOAD_DISK:
            if self._disk_can_take(s):
                return KVAction.OFFLOAD_DISK
            if self.cosched.offload_net(s, now) > 0.0 \
                    and self._host_can_take(s):
                return KVAction.OFFLOAD        # warm tier as second choice
        elif action == KVAction.OFFLOAD:
            if self._host_can_take(s):
                return KVAction.OFFLOAD
            if self.cosched.disk_net(s, now) > 0.0 \
                    and self._disk_can_take(s):
                return KVAction.OFFLOAD_DISK   # DRAM full: cold tier still
        return KVAction.FREE                   # beats recompute

    # external control plane
    def admit(self, queue, now):
        if self.cfg.disable_control_plane:
            return list(queue)
        return self.control.balance_and_admit(queue, now)

    # priority-aware coordinator
    def order(self, ready, now):
        if self.cfg.disable_coordinator:
            return sorted(ready, key=lambda s: s.arrival_time)
        return self.coord.order(ready, now)

    def charge_service(self, s, tokens, now):
        if self.cfg.disable_coordinator:
            super().charge_service(s, tokens, now)
            return
        self.coord.charge(s, tokens)

    # opportunistic co-scheduler: prefill/decode budget split per iteration
    def prefill_budget(self, token_budget, decode_tokens):
        if self.cfg.disable_coscheduler:
            return super().prefill_budget(token_budget, decode_tokens)
        return self.cosched.split_budget(token_budget, decode_tokens)

    def eviction_order(self, victims, now, requester=None):
        if self.cfg.disable_coordinator:
            return super().eviction_order(victims, now, requester)
        if requester is not None:
            # preemption authority is arrival-stable (no cycles, FCFS-grade
            # churn bounds); *among* the allowed victims the choice is
            # priority-aligned (lowest MLFQ priority first, largest KV first)
            # as per §4.3.
            victims = [v for v in victims
                       if v.arrival_time > requester.arrival_time]
        return self.coord.eviction_order(victims, now)

    # opportunistic co-scheduler (four-way adaptive retention, §4.3 ext.)
    def retention_audit(self, s, now):
        """Priced alternatives behind the retention decision, for the
        observability layer's audit records (repro.obs): the three net
        benefits ``retention_decision`` compared (read from its stash —
        re-pricing would double the swap-sizing cost on every tool yield),
        plus the recompute cost a FREE would re-pay. Infinite sentinels
        (disabled tiers) are reported as None so the record stays
        JSON-serializable."""
        if self.cfg.disable_coscheduler:
            return {}

        def _fin(x):
            if x is None or x in (float("inf"), float("-inf")):
                return None
            return round(x, 6)

        p = self.cosched.last_prices
        return {
            "pin_net": _fin(p.get("pin_net")),
            "offload_net": _fin(p.get("offload_net")),
            "disk_net": _fin(p.get("disk_net")),
            "recompute_s": round(self.cosched.recompute_time(s.resident_len),
                                 6),
        }

    def on_tool_yield(self, s, now):
        if self.cfg.disable_coscheduler:
            return KVAction.FREE, 0.0
        action = self.cosched.retention_decision(s, now)
        if action == KVAction.PIN:
            return KVAction.PIN, float("inf")   # adaptive: revoked by ticks
        if action in (KVAction.OFFLOAD, KVAction.OFFLOAD_DISK):
            return self._offload_fallback(s, now, action), 0.0
        return KVAction.FREE, 0.0

    def revoke_actions(self, pinned, now):
        if self.cfg.disable_coscheduler:
            return [(s, KVAction.FREE) for s in pinned]
        out = []
        for s, action in self.cosched.revoke_actions(pinned, now):
            if action in (KVAction.OFFLOAD, KVAction.OFFLOAD_DISK):
                action = self._offload_fallback(s, now, action)
            out.append((s, action))
        return out

    def reclaim_order(self, pinned, now):
        if self.cfg.disable_coscheduler:
            return super().reclaim_order(pinned, now)
        return self.cosched.reclaim_order(pinned, now)

    def reclaim_action(self, s, now):
        """A pin reclaimed under pressure demotes to host DRAM (or the
        NVMe cold tier when DRAM is full or the idle window is long) when
        the restore still beats the recompute it would otherwise cause."""
        if self.cfg.disable_coscheduler:
            return KVAction.FREE
        action = self.cosched.retention_decision(s, now)
        if action in (KVAction.OFFLOAD, KVAction.OFFLOAD_DISK):
            return self._offload_fallback(s, now, action)
        if self.cosched.offload_net(s, now) > 0.0 and self._host_can_take(s):
            return KVAction.OFFLOAD
        return KVAction.FREE

    def prefill_chunk(self, want_tokens, free_blocks, block_size):
        if self.cfg.disable_coscheduler:
            return super().prefill_chunk(want_tokens, free_blocks, block_size)
        return self.cosched.shrink_chunk(want_tokens, free_blocks)


POLICY_CLASSES = {
    "fcfs": Policy,
    "autellix": AutellixPolicy,
    "infercept": InferCeptPolicy,
    "continuum": ContinuumPolicy,
    "continuum-dy": ContinuumDynPolicy,
    "mars": MARSPolicy,
}


def make_policy(name: str, telem: Telemetry, bus: EventBus, oracle: PerfOracle,
                mars_cfg: Optional[MARSConfig] = None) -> Policy:
    if name.startswith("mars"):
        cfg = mars_cfg or MARSConfig(
            disable_control_plane=(name == "mars-no-ctrl"),
            disable_coordinator=(name == "mars-no-coord"),
            disable_coscheduler=(name == "mars-no-cosched"))
        return MARSPolicy(telem, bus, oracle, cfg)
    return POLICY_CLASSES[name](telem, bus, oracle)
