"""External Control Plane (paper §4.2, Algorithm 1).

Global Load Balancer  — ``pack_queue``: pressure-aware admission ordering
    * normal        -> ascending by estimated KV blocks (favor interactive)
    * CPU overload  -> descending (favor GPU-heavy, throttle new tool work)
    * all-long queue-> first-fit under the available KV budget
External Admission Controller — ``update_window``: AIMD window W_adm with
hysteresis (in Telemetry), clamped by CPU- and KV-derived limits.
``balance_and_admit`` composes both into one control step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.session import Session
from repro.core.telemetry import Telemetry


@dataclass
class ControlPlaneConfig:
    w_init: float = 8.0
    w_min: float = 1.0
    w_max: float = 512.0
    additive_alpha: float = 1.0        # const alpha > 0   (Alg.1 l.17)
    multiplicative_beta: float = 0.7   # const beta < 1    (Alg.1 l.15)
    control_interval: float = 2.0      # seconds between AIMD updates
    long_session_blocks: int = 1024    # "long" threshold for first-fit mode
    block_size: int = 32
    # CPU-oversubscription admission term: defer an admit whose tool
    # profile (per-kind EMA CPU seconds) would push the shared core pool's
    # projected queueing delay past this bound — the CPU analogue of the
    # KV-blocks sizing above. inf disables the term (CPU-naive admission).
    cpu_queue_bound_s: float = float("inf")


class ExternalControlPlane:
    def __init__(self, cfg: ControlPlaneConfig, telem: Telemetry, bus: EventBus):
        self.cfg = cfg
        self.telem = telem
        self.bus = bus
        self.w_adm = cfg.w_init
        self._last_update = -1e18
        # radix-aware admission: session -> blocks of its chunk-key prefix
        # already indexed on this replica (exact ``RadixIndex.match`` when
        # bound in-process by the engine; a remote control plane can bind
        # ``kvcache.radix.estimate_digest_match`` over the heartbeat digest)
        self.prefix_lookup = None
        # shared host-CPU core pool (bound via Services when the engine has
        # one): its work-in-system horizon is the pressure signal the
        # cpu_queue_bound_s term prices. None => term inactive.
        self.cpu_pool = None
        self.cpu_deferred = 0          # admits deferred on projected CPU wait
        # standing per-round tool-CPU commitments of admitted, unfinished
        # sessions. The pool's instantaneous schedule lags admission by a
        # whole prefill phase (a session puts nothing on cores until its
        # first tool), so the projection must count admitted demand that
        # has not reached the pool yet or every arrival wave sails in
        # before the cores heat up.
        self._cpu_commit: Dict[int, float] = {}
        bus.subscribe(ev.FINISH, self._on_finish)

    def _on_finish(self, e) -> None:
        self._cpu_commit.pop(e.sid, None)

    # --- helpers -------------------------------------------------------------
    def estimate_blocks(self, s: Session) -> int:
        """Lightweight per-session KV-block estimate from prefill length
        (proxy for both compute demand and spatial footprint), minus the
        shared prefix this replica's radix index already holds — a family
        member attaching to an existing repository context neither computes
        nor (physically) allocates those blocks, so under pressure it may
        admit earlier than its raw prompt size suggests. Never estimates
        below one chunk: even a full-duplicate session recomputes/holds at
        least its tail block."""
        est = -(-s.pending_prefill // self.cfg.block_size)
        if self.prefix_lookup is not None:
            est -= max(0, int(self.prefix_lookup(s)))
        return max(1, est)

    def estimate_tool_cpu(self, s: Session) -> float:
        """Per-session tool CPU profile: mean EMA-estimated seconds over
        the session's tool-bearing rounds — what one admitted session is
        expected to put on the shared core pool per tool yield. 0.0 for
        tool-free sessions (they never contend for cores)."""
        ests = [self.telem.tool_estimate(r.tool_kind)
                for r in s.rounds if r.tool_kind is not None]
        return (sum(ests) / len(ests)) if ests else 0.0

    # --- Alg.1 PackQueue ------------------------------------------------------
    def pack_queue(self, queue: List[Session]) -> List[Session]:
        t = self.telem
        est = {s.sid: self.estimate_blocks(s) for s in queue}
        if not queue:
            return queue
        if t.cpu_overloaded:
            return sorted(queue, key=lambda s: -est[s.sid])
        if all(e >= self.cfg.long_session_blocks for e in est.values()):
            return self._first_fit(queue, est, t.free_blocks)
        return sorted(queue, key=lambda s: est[s.sid])

    @staticmethod
    def _first_fit(queue: List[Session], est, available: int) -> List[Session]:
        """Assemble a feasible admission set under the current KV budget,
        then append the rest (largest-last) — oversized heads no longer block
        admissible sessions behind them."""
        fits, rest, budget = [], [], available
        for s in sorted(queue, key=lambda s: est[s.sid]):
            if est[s.sid] <= budget:
                fits.append(s)
                budget -= est[s.sid]
            else:
                rest.append(s)
        return fits + rest

    # --- Alg.1 UpdateWindow ---------------------------------------------------
    def update_window(self, now: float, avg_blocks_per_session: float) -> int:
        c, t = self.cfg, self.telem
        w_cpu = t.calc_cpu_limit()
        w_kv = t.calc_kv_limit(avg_blocks_per_session)
        if now - self._last_update >= c.control_interval:
            if t.cpu_overloaded or t.kv_overloaded:
                self.w_adm = max(c.w_min, self.w_adm * c.multiplicative_beta)
            elif not t.cpu_overloaded and t.has_kv_slack():
                self.w_adm = min(c.w_max, self.w_adm + c.additive_alpha)
            self._last_update = now
            self.bus.emit(ev.WINDOW_UPDATE, now, w_adm=self.w_adm,
                          w_cpu=w_cpu, w_kv=w_kv,
                          cpu_overloaded=t.cpu_overloaded,
                          kv_overloaded=t.kv_overloaded)
        return int(min(self.w_adm, w_cpu, w_kv))

    # --- Alg.1 BalanceAndAdmit -------------------------------------------------
    def balance_and_admit(self, queue: List[Session], now: float) -> List[Session]:
        if not queue:
            return []
        ordered = self.pack_queue(queue)
        avg_blocks = (sum(self.estimate_blocks(s) for s in queue) / len(queue))
        limit = self.update_window(now, avg_blocks)
        slots = limit - self.telem.active_sessions
        if slots <= 0:
            return []
        # CPU-oversubscription term: walk the packed order keeping a
        # running backlog of the tool CPU this cycle's admits would add;
        # defer (skip, not reject) any session whose profile would push
        # the pool's projected core-queueing delay past the bound —
        # tool-light sessions behind it still pass.
        bound = self.cfg.cpu_queue_bound_s
        price_cpu = bound != float("inf") and self.cpu_pool is not None
        admitted: List[Session] = []
        # hypothetical backlog this cycle's admits stack on top of the
        # standing commitments of every admitted-but-unfinished session
        extra_cpu_s = sum(self._cpu_commit.values()) if price_cpu else 0.0
        for s in ordered:
            if len(admitted) >= slots:
                break
            if price_cpu:
                est = self.estimate_tool_cpu(s)
                if est > 0.0:
                    # the candidate waits behind scheduled + committed
                    # work and this cycle's earlier admits — never behind
                    # itself (else a session with est > bound*cores
                    # starves on an idle pool); its own est joins the
                    # backlog only once it passes, pricing the admits
                    # after it
                    wait = max(self.cpu_pool.horizon_wait(now),
                               extra_cpu_s / self.cpu_pool.cores)
                    if wait > bound:
                        self.cpu_deferred += 1
                        continue
                    extra_cpu_s += est
                    self._cpu_commit[s.sid] = est
            admitted.append(s)
        for s in admitted:
            self.bus.emit(ev.ADMIT, now, s.sid,
                          est_blocks=self.estimate_blocks(s))
        return admitted
