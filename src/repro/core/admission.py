"""External Control Plane (paper §4.2, Algorithm 1).

Global Load Balancer  — ``pack_queue``: pressure-aware admission ordering
    * normal        -> ascending by estimated KV blocks (favor interactive)
    * CPU overload  -> descending (favor GPU-heavy, throttle new tool work)
    * all-long queue-> first-fit under the available KV budget
External Admission Controller — ``update_window``: AIMD window W_adm with
hysteresis (in Telemetry), clamped by CPU- and KV-derived limits.
``balance_and_admit`` composes both into one control step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.session import Session
from repro.core.telemetry import Telemetry


@dataclass
class ControlPlaneConfig:
    w_init: float = 8.0
    w_min: float = 1.0
    w_max: float = 512.0
    additive_alpha: float = 1.0        # const alpha > 0   (Alg.1 l.17)
    multiplicative_beta: float = 0.7   # const beta < 1    (Alg.1 l.15)
    control_interval: float = 2.0      # seconds between AIMD updates
    long_session_blocks: int = 1024    # "long" threshold for first-fit mode
    block_size: int = 32


class ExternalControlPlane:
    def __init__(self, cfg: ControlPlaneConfig, telem: Telemetry, bus: EventBus):
        self.cfg = cfg
        self.telem = telem
        self.bus = bus
        self.w_adm = cfg.w_init
        self._last_update = -1e18
        # radix-aware admission: session -> blocks of its chunk-key prefix
        # already indexed on this replica (exact ``RadixIndex.match`` when
        # bound in-process by the engine; a remote control plane can bind
        # ``kvcache.radix.estimate_digest_match`` over the heartbeat digest)
        self.prefix_lookup = None

    # --- helpers -------------------------------------------------------------
    def estimate_blocks(self, s: Session) -> int:
        """Lightweight per-session KV-block estimate from prefill length
        (proxy for both compute demand and spatial footprint), minus the
        shared prefix this replica's radix index already holds — a family
        member attaching to an existing repository context neither computes
        nor (physically) allocates those blocks, so under pressure it may
        admit earlier than its raw prompt size suggests. Never estimates
        below one chunk: even a full-duplicate session recomputes/holds at
        least its tail block."""
        est = -(-s.pending_prefill // self.cfg.block_size)
        if self.prefix_lookup is not None:
            est -= max(0, int(self.prefix_lookup(s)))
        return max(1, est)

    # --- Alg.1 PackQueue ------------------------------------------------------
    def pack_queue(self, queue: List[Session]) -> List[Session]:
        t = self.telem
        est = {s.sid: self.estimate_blocks(s) for s in queue}
        if not queue:
            return queue
        if t.cpu_overloaded:
            return sorted(queue, key=lambda s: -est[s.sid])
        if all(e >= self.cfg.long_session_blocks for e in est.values()):
            return self._first_fit(queue, est, t.free_blocks)
        return sorted(queue, key=lambda s: est[s.sid])

    @staticmethod
    def _first_fit(queue: List[Session], est, available: int) -> List[Session]:
        """Assemble a feasible admission set under the current KV budget,
        then append the rest (largest-last) — oversized heads no longer block
        admissible sessions behind them."""
        fits, rest, budget = [], [], available
        for s in sorted(queue, key=lambda s: est[s.sid]):
            if est[s.sid] <= budget:
                fits.append(s)
                budget -= est[s.sid]
            else:
                rest.append(s)
        return fits + rest

    # --- Alg.1 UpdateWindow ---------------------------------------------------
    def update_window(self, now: float, avg_blocks_per_session: float) -> int:
        c, t = self.cfg, self.telem
        w_cpu = t.calc_cpu_limit()
        w_kv = t.calc_kv_limit(avg_blocks_per_session)
        if now - self._last_update >= c.control_interval:
            if t.cpu_overloaded or t.kv_overloaded:
                self.w_adm = max(c.w_min, self.w_adm * c.multiplicative_beta)
            elif not t.cpu_overloaded and t.has_kv_slack():
                self.w_adm = min(c.w_max, self.w_adm + c.additive_alpha)
            self._last_update = now
            self.bus.emit(ev.WINDOW_UPDATE, now, w_adm=self.w_adm,
                          w_cpu=w_cpu, w_kv=w_kv,
                          cpu_overloaded=t.cpu_overloaded,
                          kv_overloaded=t.kv_overloaded)
        return int(min(self.w_adm, w_cpu, w_kv))

    # --- Alg.1 BalanceAndAdmit -------------------------------------------------
    def balance_and_admit(self, queue: List[Session], now: float) -> List[Session]:
        if not queue:
            return []
        ordered = self.pack_queue(queue)
        avg_blocks = (sum(self.estimate_blocks(s) for s in queue) / len(queue))
        limit = self.update_window(now, avg_blocks)
        slots = limit - self.telem.active_sessions
        if slots <= 0:
            return []
        admitted = ordered[:slots]
        for s in admitted:
            self.bus.emit(ev.ADMIT, now, s.sid,
                          est_blocks=self.estimate_blocks(s))
        return admitted
