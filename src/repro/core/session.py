"""Agent session / round state machine.

A *session* is the serving-layer view of one agentic task: a sequence of
rounds, each ``LLM call (prefill of appended context + decode) -> tool
execution``, sharing one logical context whose KV is the suspended state the
scheduler manages (paper §2.2 "temporal shift").
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


class Phase(enum.Enum):
    WAITING_ADMIT = "waiting_admit"   # in external queue, not yet admitted
    READY_PREFILL = "ready_prefill"   # admitted, needs (more) prefill
    DECODING = "decoding"
    TOOL = "tool"                     # yielded to host-side tool execution
    FINISHED = "finished"


class KVState(enum.Enum):
    NONE = "none"            # no resident KV (cold)
    RESIDENT = "resident"    # KV resident, session active on GPU
    PINNED = "pinned"        # KV retained across a tool phase
    SWAPPED = "swapped"      # KV in host DRAM (legacy swap or host tier)


class KVAction(enum.Enum):
    """Retention outcome at a tool boundary (four-way under MARS)."""
    FREE = "free"            # drop: rebuild by prefix recompute on resume
    PIN = "pin"              # retain in HBM across the tool phase
    SWAP = "swap"            # legacy host swap (InferCept's stock-vLLM path)
    OFFLOAD = "offload"      # tiered host-DRAM offload (kvcache.host_tier)
    OFFLOAD_DISK = "offload_disk"  # cold NVMe tier (kvcache.disk_tier),
    #                                staged two-hop restore via host DRAM


@dataclass
class Round:
    new_input_tokens: int            # context appended before this LLM call
    decode_tokens: int               # output tokens this call produces
    tool_kind: Optional[str] = None  # None for the final round
    tool_seconds: float = 0.0        # ground-truth duration (sim / synthetic)


_session_counter = itertools.count()


@dataclass
class Session:
    sid: int
    arrival_time: float
    rounds: List[Round]
    slo_alpha: float = 3.0
    ideal_time: float = 0.0          # isolated execution time (for goodput)

    # --- live state --------------------------------------------------------
    phase: Phase = Phase.WAITING_ADMIT
    cur_round: int = 0
    context_len: int = 0             # logical tokens accumulated so far
    resident_len: int = 0            # tokens with KV currently resident
    prefill_done: int = 0            # tokens of current round's target prefilled
    decoded: int = 0                 # tokens decoded in current round
    kv_state: KVState = KVState.NONE
    kv_blocks: int = 0               # blocks currently held
    pinned_since: float = 0.0
    pin_ttl: float = 0.0             # Continuum-style TTL (0 = policy default)
    tool_started: float = 0.0
    tool_deadline: float = 0.0

    # --- accounting ---------------------------------------------------------
    service_seconds: float = 0.0     # accumulated GPU service (PLAS/MLFQ)
    service_tokens: int = 0
    last_service: float = 0.0
    admitted_at: float = -1.0
    round_submit: float = 0.0        # gpu_submit of current round
    ttfts: List[float] = field(default_factory=list)
    first_token_seen: bool = False
    finish_time: float = -1.0
    preemptions: int = 0
    recomputed_tokens: int = 0
    swap_in_pending: float = 0.0     # seconds of swap-in left before resume
    meta: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------
    @property
    def cur(self) -> Round:
        return self.rounds[self.cur_round]

    @property
    def total_prompt_tokens(self) -> int:
        return sum(r.new_input_tokens for r in self.rounds)

    @property
    def prefill_target(self) -> int:
        """Tokens that must be resident before this round can decode."""
        return self.context_len_at_round_start() + self.cur.new_input_tokens

    def context_len_at_round_start(self) -> int:
        return sum(r.new_input_tokens + r.decode_tokens
                   for r in self.rounds[: self.cur_round])

    @property
    def pending_prefill(self) -> int:
        """Tokens still to prefill now (includes rebuild after eviction)."""
        return max(0, self.prefill_target - self.resident_len)

    @property
    def e2e_latency(self) -> float:
        assert self.finish_time >= 0
        return self.finish_time - self.arrival_time

    @property
    def slo_met(self) -> bool:
        return self.e2e_latency <= self.slo_alpha * self.ideal_time

    def is_long(self, long_threshold_tokens: int) -> bool:
        return self.pending_prefill >= long_threshold_tokens

    def __hash__(self):
        return self.sid

    def __repr__(self):
        return (f"Session({self.sid}, {self.phase.value}, r{self.cur_round}/"
                f"{len(self.rounds)}, ctx={self.context_len}, kv={self.kv_state.value})")


def make_session(arrival_time: float, rounds: List[Round], *, slo_alpha: float = 3.0,
                 ideal_time: float = 0.0, sid: Optional[int] = None) -> Session:
    return Session(sid=next(_session_counter) if sid is None else sid,
                   arrival_time=arrival_time, rounds=rounds,
                   slo_alpha=slo_alpha, ideal_time=ideal_time)
