"""Priority-Aware Coordinator (paper §4.3): windowed Multi-Level Feedback
Queue whose priority is a compact summary of three factors —

    (1) initial KV footprint  -> base level (smaller context = higher prio)
    (2) accumulated GPU service -> demotion through level quanta
    (3) waiting time           -> bounded promotion (liveness)

The same structure governs eviction: lowest-priority calls are the primary
eviction candidates; among equals, larger KV footprints are preferred
(release more memory immediately).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

from repro.core.session import Session


@dataclass
class MLFQConfig:
    n_levels: int = 6
    # base-level thresholds on the pending-work footprint (tokens):
    # decodes/warm continuations -> 0-1, chat-scale cold builds -> 2,
    # repository-scale cold builds -> 3.
    footprint_thresholds: Tuple[int, ...] = (1_024, 24_576, 98_304)
    # geometric service quanta (Autellix-style): demotion level =
    # floor(log2(1 + service_tokens / quantum)), bounded by max_demotion.
    level_quantum_tokens: int = 49_152
    max_demotion: int = 2
    # bounded promotion: one level per `promote_after` seconds of starvation,
    # at most `max_promotion` levels
    promote_after: float = 30.0
    max_promotion: int = 2


class PriorityCoordinator:
    def __init__(self, cfg: MLFQConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    def base_level(self, s: Session) -> int:
        """Base level from the *pending* work footprint: a warm continuation
        (KV resident, only the new round's tokens to prefill) is
        latency-sensitive and lands in a high-priority level; a cold
        repository-scale (re)build lands low. Decode-phase sessions have zero
        pending prefill -> top priority (the paper's 'latency-sensitive
        continuations')."""
        fp = s.pending_prefill
        for lvl, thr in enumerate(self.cfg.footprint_thresholds):
            if fp < thr:
                return min(lvl, self.cfg.n_levels - 1)
        return self.cfg.n_levels - 1

    def level(self, s: Session, now: float) -> int:
        """Effective MLFQ level (lower = higher priority): base footprint
        level + bounded geometric service demotion - bounded wait promotion."""
        c = self.cfg
        lvl = self.base_level(s)
        lvl += self._demotion(s.service_tokens)
        waited = max(0.0, now - max(s.last_service, s.admitted_at))
        promo = min(c.max_promotion, int(waited / c.promote_after))
        return max(0, min(c.n_levels - 1, lvl - promo))

    def _demotion(self, service_tokens: float) -> int:
        """Bounded geometric demotion for an accumulated service total."""
        c = self.cfg
        demote = int(math.log2(1.0 + service_tokens / c.level_quantum_tokens))
        return min(c.max_demotion, demote)

    def charge(self, s: Session, tokens: int) -> int:
        """Quantum-by-token accounting: charge the *actual* tokens
        dispatched this iteration against the session's service quanta and
        return the bounded demotion level after the charge. Under
        iteration-level batching this runs once per token per lane, so a
        session demotes at the exact iteration its cumulative service
        crosses a quantum boundary — round-granular charging (one lump of
        ``decode_granularity`` tokens) could overshoot the boundary by up
        to g-1 tokens before the demotion lands."""
        s.service_tokens += tokens
        return self._demotion(s.service_tokens)

    def priority_key(self, s: Session, now: float):
        """Sort key: (level, FIFO-within-level). Short or lightly-served
        continuations first; historically expensive calls don't leapfrog
        interactive work. The within-level order is STABLE (round submission
        time) — starvation relief comes from bounded level promotion, never
        from reshuffling within a level (a time-varying tiebreak would
        round-robin cold builds and fill the pool with partial prefixes)."""
        return (self.level(s, now), s.round_submit, s.sid)

    def order(self, ready: Sequence[Session], now: float) -> List[Session]:
        return sorted(ready, key=lambda s: self.priority_key(s, now))

    # ------------------------------------------------------------------
    def eviction_order(self, candidates: Sequence[Session], now: float
                       ) -> List[Session]:
        """First to evict = lowest priority (highest level); ties broken by
        largest resident KV. Aligned with queue priority by construction —
        no separate, potentially conflicting eviction rules."""
        return sorted(candidates,
                      key=lambda s: (-self.level(s, now), -s.kv_blocks))
