"""MARS core: unified info stream, external control plane (AIMD admission +
pressure-aware queue packing), internal agent-centric scheduler (windowed
MLFQ + opportunistic co-scheduler), and the baseline policies."""

from repro.core.admission import ControlPlaneConfig, ExternalControlPlane
from repro.core.coscheduler import CoSchedulerConfig, OpportunisticCoScheduler
from repro.core.events import Event, EventBus
from repro.core.mlfq import MLFQConfig, PriorityCoordinator
from repro.core.policies import (KVAction, MARSConfig, MARSPolicy, Policy,
                                 make_policy)
from repro.core.session import KVState, Phase, Round, Session, make_session
from repro.core.telemetry import Telemetry, TelemetryConfig
