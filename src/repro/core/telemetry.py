"""Dual-pressure telemetry (paper §4.1): the consistent cross-plane view that
admission control and the internal scheduler both consume.

GPU-plane pressure is reported in the allocator's *native unit* — KV blocks —
via an O(1) probe of the block pool (never byte counters; paper argues bytes
obscure allocator granularity). CPU-plane pressure is characterized without
hardware instrumentation by (a) the number of in-flight tool invocations and
(b) per-kind EMA of observed tool durations.

``cpu_overloaded`` / ``kv_overloaded`` carry hysteresis: a plane must stay
past its threshold for ``hysteresis_checks`` consecutive ``tick()`` calls to
flip, and below it for the same count to clear, preventing admit/stop
oscillation. Probes (``probe_gpu`` etc.) only refresh raw readings; the
hysteresis counters and the churn-EMA decay advance on the explicit
``tick()`` the engine loop calls exactly once per iteration — flag cadence
is the engine's, not whatever cadence the GPU probe happens to run at.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core import events as ev
from repro.core.events import EventBus


@dataclass
class TelemetryConfig:
    cpu_slots: int = 16                 # host cores available for tools
    cpu_overload_factor: float = 1.0    # overloaded if active >= slots*factor
    kv_overload_frac: float = 0.92      # pool utilization threshold (soft cap)
    kv_slack_frac: float = 0.80         # below this there is "slack"
    kv_churn_frac: float = 0.02         # churn EMA > frac*pool => overloaded
    hysteresis_checks: int = 3
    tool_ema_alpha: float = 0.3
    default_tool_seconds: float = 8.0


class Telemetry:
    """Aggregates the unified info stream into the dual-pressure snapshot."""

    def __init__(self, cfg: TelemetryConfig, bus: EventBus):
        self.cfg = cfg
        self.bus = bus
        # GPU plane (updated by the engine's O(1) block-pool probe)
        self.total_blocks = 1
        self.free_blocks = 1
        self.pinned_blocks = 0
        self.active_sessions = 0
        self.running_decodes = 0
        self.waiting_prefill_blocks = 0   # projected demand of admitted queue
        # CPU plane
        self.active_tools = 0
        self.tool_ema: Dict[str, float] = {}
        self._cpu_hot = 0
        self._cpu_cold = 0
        self._kv_hot = 0
        self._kv_cold = 0
        self.cpu_overloaded = False
        self.kv_overloaded = False
        self.last_window_update = -1e18
        # KV churn (preemption loss) EMA, in blocks — the congestion signal
        self.churn_ema = 0.0
        self._churn_accum = 0.0
        # host-DRAM offload tier (kvcache.host_tier)
        self.host_capacity_blocks = 0
        self.host_used_blocks = 0
        self.offload_stores = 0
        self.offload_hits = 0
        # tiered-store breakdown (kvcache.tiers): per-tier occupancy /
        # hit-rate snapshot plus migration counters, None until probed
        self.tier_stats: Optional[Dict] = None
        # cross-session prefix sharing (kvcache.radix)
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        # radix-root digest summary (what this replica advertises to the
        # cluster router for family-aware placement)
        self.digest_anchors = 0
        self.digest_indexed_blocks = 0
        self.digest_version = 0
        bus.subscribe(ev.TOOL_START, self._on_tool_start)
        bus.subscribe(ev.TOOL_END, self._on_tool_end)
        bus.subscribe(ev.PREEMPT, self._on_preempt)

    # --- event consumers ---------------------------------------------------
    def _on_tool_start(self, e) -> None:
        self.active_tools += 1

    def _on_tool_end(self, e) -> None:
        self.active_tools = max(0, self.active_tools - 1)
        kind = e.data.get("kind", "default")
        dur = float(e.data.get("duration", self.cfg.default_tool_seconds))
        a = self.cfg.tool_ema_alpha
        prev = self.tool_ema.get(kind)
        self.tool_ema[kind] = dur if prev is None else (1 - a) * prev + a * dur

    def _on_preempt(self, e) -> None:
        self._churn_accum += e.data.get("blocks", 0)

    # --- probes --------------------------------------------------------------
    def probe_gpu(self, total: int, free: int, pinned: int, active_sessions: int,
                  running_decodes: int, waiting_blocks: int) -> None:
        self.total_blocks = max(1, total)
        self.free_blocks = free
        self.pinned_blocks = pinned
        self.active_sessions = active_sessions
        self.running_decodes = running_decodes
        self.waiting_prefill_blocks = waiting_blocks

    def tick(self) -> None:
        """Advance hysteresis counters and decay the churn EMA — called by
        the engine once per tick (probes may run any number of times in
        between without skewing the flag cadence)."""
        self._update_flags()

    def _update_flags(self) -> None:
        c = self.cfg
        cpu_hot = self.active_tools >= c.cpu_slots * c.cpu_overload_factor
        # KV overload = sustained preemption churn (loss-based congestion
        # signal, like TCP): merely-full pools are healthy, thrashing is not.
        self.churn_ema = 0.9 * self.churn_ema + 0.1 * self._churn_accum
        self._churn_accum = 0.0
        kv_hot = self.churn_ema > c.kv_churn_frac * self.total_blocks
        self._cpu_hot = self._cpu_hot + 1 if cpu_hot else 0
        self._cpu_cold = self._cpu_cold + 1 if not cpu_hot else 0
        self._kv_hot = self._kv_hot + 1 if kv_hot else 0
        self._kv_cold = self._kv_cold + 1 if not kv_hot else 0
        if self._cpu_hot >= c.hysteresis_checks:
            self.cpu_overloaded = True
        if self._cpu_cold >= c.hysteresis_checks:
            self.cpu_overloaded = False
        if self._kv_hot >= c.hysteresis_checks:
            self.kv_overloaded = True
        if self._kv_cold >= c.hysteresis_checks:
            self.kv_overloaded = False

    def probe_host(self, used_blocks: int, capacity_blocks: int,
                   stores: int, hits: int) -> None:
        """Host-tier occupancy + hit-rate snapshot (same O(1) discipline as
        the GPU probe: counters only, no byte math)."""
        self.host_used_blocks = used_blocks
        self.host_capacity_blocks = capacity_blocks
        self.offload_stores = stores
        self.offload_hits = hits

    def probe_tiers(self, stats: Optional[Dict]) -> None:
        """Snapshot of the TieredStore breakdown (see ``kvcache.tiers``):
        per-tier occupancy, hit rates, demotions, staged restores."""
        self.tier_stats = stats

    def kv_tier_stats(self) -> Dict:
        """Per-tier KV-state breakdown for dashboards and benchmarks:
        occupancy, hit rate, demotions and staged restores per tier. Falls
        back to the flat host-tier counters when no TieredStore probe has
        landed (host-only or legacy configurations)."""
        if self.tier_stats is not None:
            return self.tier_stats
        return {
            "host": {
                "used_blocks": self.host_used_blocks,
                "capacity_blocks": self.host_capacity_blocks,
                "occupancy": self.host_occupancy,
                "stores": self.offload_stores,
                "hits": self.offload_hits,
                "hit_rate": round(self.offload_hit_rate, 4),
                "drops": 0,
            },
            "disk": None,
            "demotions": 0,
            "staged_restores": 0,
            "direct_to_disk": 0,
        }

    def probe_prefix(self, queries: int, hits: int, hit_tokens: int) -> None:
        self.prefix_queries = queries
        self.prefix_hits = hits
        self.prefix_hit_tokens = hit_tokens

    def probe_digest(self, digest: Optional[dict]) -> None:
        """Snapshot of the exported radix-root digest (see kvcache.radix):
        how much shareable state this replica advertises cluster-wide."""
        if not digest:
            self.digest_anchors = 0
            self.digest_indexed_blocks = 0
            self.digest_version = 0
            return
        self.digest_anchors = len(digest.get("anchors") or {})
        self.digest_indexed_blocks = digest.get("indexed_blocks", 0)
        self.digest_version = digest.get("v", 0)

    # --- derived -------------------------------------------------------------
    @property
    def kv_utilization(self) -> float:
        return 1.0 - self.free_blocks / self.total_blocks

    @property
    def prefix_hit_rate(self) -> float:
        """Sharing sessions per index-consulting session (≤ 1 by the
        record_query/record_hit discipline in kvcache.radix)."""
        return self.prefix_hits / max(1, self.prefix_queries)

    @property
    def host_occupancy(self) -> float:
        return self.host_used_blocks / max(1, self.host_capacity_blocks)

    @property
    def offload_hit_rate(self) -> float:
        return self.offload_hits / max(1, self.offload_stores)

    def has_kv_slack(self) -> bool:
        """Healthy = low churn (a full-but-stable pool is slack for AIMD
        purposes; actual capacity gating is the soft cap in calc_kv_limit)."""
        return self.churn_ema < 0.5 * self.cfg.kv_churn_frac * self.total_blocks

    def tool_estimate(self, kind: Optional[str]) -> float:
        if kind is None:
            return 0.0
        return self.tool_ema.get(kind, self.cfg.default_tool_seconds)

    def calc_cpu_limit(self) -> int:
        """Admission cap derived from host tool capacity: sessions spend a
        fraction of wall time in tools; the host sustains ~slots concurrent
        tools, so cap concurrent sessions at slots / duty + headroom."""
        c = self.cfg
        free_tool_slots = max(0, c.cpu_slots - self.active_tools)
        return self.active_sessions + free_tool_slots + c.cpu_slots

    def calc_kv_limit(self, avg_blocks_per_session: float) -> int:
        """Soft KV cap (paper: progressive, not binary): admission headroom
        shrinks smoothly as pool utilization approaches the slack target.
        Sessions interleave GPU and tool phases, so the cap is *not*
        sum-of-full-footprints — it grants concurrency proportional to the
        remaining headroom fraction and lets AIMD react to actual overload."""
        c = self.cfg
        # capacity guard-rail with bounded overcommit: sessions alternate GPU
        # and tool phases, so pool-capacity concurrency alone would idle the
        # GPU during tools (x4 covers typical tool duty cycles). The
        # *adaptive* actuator is the AIMD window reacting to churn — this cap
        # only bounds worst-case oversubscription against huge sessions.
        cap_sessions = 4.0 * self.total_blocks / max(1.0, avg_blocks_per_session)
        headroom = max(0.0, c.kv_overload_frac - self.kv_utilization) \
            / c.kv_overload_frac
        extra = headroom * self.total_blocks / max(1.0, avg_blocks_per_session)
        return max(1, int(round(cap_sessions + extra)))
