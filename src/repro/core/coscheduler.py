"""Opportunistic Co-Scheduler (paper §4.3).

Two mechanisms:

* **Chunk shrinking** — when a selected prefill cannot be placed, the
  requested chunk is halved until the allocator can fit it (down to a single
  block) instead of jumping to destructive eviction: transient fragmentation
  becomes a temporary reduction in service granularity.

* **Adaptive KV retention** — at a tool boundary, KV is pinned only while

      warm_resume_benefit  >  residency_cost

  where benefit = prefix recompute time avoided, and cost = the opportunity
  cost of the held blocks over the tool's (EMA-estimated) remaining duration,
  priced by current demand pressure. Unlike InferCept/Continuum this is NOT a
  one-shot decision at invocation time: it is re-evaluated every tick, so a
  pin made under slack is revoked when pressure arrives. Pinned contexts are
  reclaimed (lowest retention score first) before any running victim is
  preempted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.session import KVAction, Session
from repro.core.telemetry import Telemetry


@dataclass
class CoSchedulerConfig:
    token_budget: int = 8_192          # per-tick token budget (prefill+decode)
    max_decode_batch: int = 256
    decode_granularity: int = 8        # decode tokens per scheduling quantum
    min_chunk_tokens: int = 32         # = one KV block
    # iteration-level (mixed) batching: cap on the prefill share of one
    # iteration's token budget. Decode lanes are formed first and always
    # fit (1 token each); prefill chunks then fill min(what the decodes
    # left, prefill_budget_frac * budget) — a prefill-heavy arrival wave
    # can at worst double the iteration's token count, never monopolize it
    # (Sarathi-Serve's stall-free chunked-prefill split).
    prefill_budget_frac: float = 0.5
    # retention price scale: the per-session stall attribution double-counts
    # when several sessions pin concurrently (each gets blamed for the same
    # shortfall); 0.25 was calibrated by sweep — mean latency -28% on H200 /
    # -6% on H100 at ILR-2 with unchanged TTFT (EXPERIMENTS.md §Reproduction).
    pin_price_scale: float = 0.25
    block_size: int = 32
    # three-way retention (host-DRAM tier). Offload pays one PCIe round trip
    # but holds zero HBM: it wins exactly when recompute is expensive while
    # pressure makes pinning too costly.
    enable_offload: bool = True
    offload_price: float = 0.5       # swap-out fraction charged (DMA/PCIe use)
    offload_min_tokens: int = 4_096  # tiny contexts: recompute is cheaper
    # four-way retention (NVMe cold tier). Disk holds neither HBM nor DRAM
    # but restores in two staged hops (NVMe read gates readiness, then the
    # PCIe swap-in): it wins on long idle windows — CI runs, human waits —
    # where parking the bytes in DRAM wastes the warmer tier's capacity.
    enable_disk: bool = True
    disk_price: float = 0.5          # staged write path fraction charged
    disk_min_tokens: int = 8_192     # NVMe op latency: small contexts recompute
    disk_idle_min_s: float = 45.0    # expected tool time beyond which DRAM
    #                                  parking is wasteful and disk preferred


class OpportunisticCoScheduler:
    def __init__(self, cfg: CoSchedulerConfig, telem: Telemetry,
                 recompute_time_fn: Callable[[int], float],
                 prefill_rate_fn: Optional[Callable[[], float]] = None):
        """``recompute_time_fn(n_tokens)`` -> seconds to rebuild that prefix;
        ``prefill_rate_fn()`` -> sustainable prefill tokens/s (both supplied
        by the execution backend's perf oracle)."""
        self.cfg = cfg
        self.telem = telem
        self.recompute_time = recompute_time_fn
        self.prefill_rate = prefill_rate_fn or (lambda: 10_000.0)
        # host-tier PCIe cost model, bound by the engine once the tier
        # exists (None => no offload tier => binary pin/drop retention)
        self.swap_seconds: Optional[Callable[[int], float]] = None
        # per-block offload sizing: session -> tokens that actually cross
        # PCIe (private blocks only; radix-shared prefix stays on device).
        # None => whole-context pricing (pre-paged swapper semantics).
        self.swap_tokens: Optional[Callable] = None
        # async swap stream: when the backend prefetches H2D swap-ins on a
        # background worker, the restore overlaps the other sessions'
        # compute and stops serializing a GPU tick — only the priced
        # DMA/PCIe occupancy share of the transfer remains a cost.
        self.swap_in_overlapped: bool = False
        # NVMe cold-tier cost model, bound by the engine when the disk tier
        # exists (None => three-way retention, no OFFLOAD_DISK outcome)
        self.disk_read_seconds: Optional[Callable[[int], float]] = None
        self.disk_write_seconds: Optional[Callable[[int], float]] = None
        # CPU-side transfer delay, bound when a shared core pool exists:
        # ``cpu_wait(transfer_s, now)`` -> projected seconds the restore's
        # staging copy would queue for a host core right now. Warm
        # resumption is only chosen when the CPU side can deliver it — the
        # projected wait is subtracted from the offload/disk nets.
        self.cpu_wait: Optional[Callable[[float, float], float]] = None
        # the three nets behind the most recent retention_decision — the
        # observability audit reads this stash instead of re-running the
        # (swap-sizing, hence expensive) pricing a second time
        self.last_prices: dict = {}

    # --- chunk shrinking ------------------------------------------------------
    def shrink_chunk(self, want_tokens: int, free_blocks: int) -> int:
        """Largest admissible prefill chunk <= want under current free blocks;
        halves down to single-block granularity; 0 if not even one block."""
        bs = self.cfg.block_size
        if want_tokens <= 0 or free_blocks <= 0:
            return 0
        chunk = want_tokens
        while chunk >= self.cfg.min_chunk_tokens:
            if -(-chunk // bs) <= free_blocks:
                return chunk
            chunk //= 2
        return min(bs, want_tokens)   # single-block granularity floor

    def split_budget(self, token_budget: int, decode_tokens: int) -> int:
        """Prefill token budget for one mixed iteration: what the decode
        lanes left of the budget, capped at ``prefill_budget_frac`` of the
        whole — decode continuations are never starved by a prefill wave,
        and a wave can never inflate the iteration beyond the frac cap."""
        left = max(0, token_budget - decode_tokens)
        return min(left, int(token_budget * self.cfg.prefill_budget_frac))

    # --- retention ------------------------------------------------------------
    def retention_score(self, s: Session, now: float) -> float:
        """benefit - cost, in seconds of GPU work. Positive => keep pinned.

        benefit = prefix recompute time avoided on warm resume.
        cost    = prefill stall inflicted on waiting work while the blocks are
                  held: waiting builders are *rate-limited* (they can consume
                  at most prefill_rate tokens/s), so holding blocks only hurts
                  to the extent demand-within-the-tool-window exceeds what
                  stays free. shortfall_blocks * block_size / prefill_rate is
                  exactly the stall time those blocks' absence causes.
        """
        t = self.telem
        benefit = self.recompute_time(s.resident_len)
        est = t.tool_estimate(s.cur.tool_kind)
        elapsed = max(0.0, now - s.tool_started)
        # hazard-aware residual: agentic tool durations are heavy-tailed, so
        # once a tool has overrun its estimate, the expected residual grows
        # with elapsed time (lognormal hazard) rather than shrinking to zero.
        # This is what makes the per-tick re-evaluation meaningful: a pin made
        # expecting a short tool is revoked as the tool reveals itself long.
        remaining = (est - elapsed) if elapsed <= est else 0.6 * elapsed
        rate = max(1.0, self.prefill_rate())            # tokens / s
        consumable = remaining * rate / self.cfg.block_size
        demand = min(float(t.waiting_prefill_blocks), consumable)
        shortfall = max(0.0, demand - float(t.free_blocks))
        # Holding b blocks denies them to the blocked share of demand for the
        # whole residual tool duration: stall inflicted ~= remaining x
        # (blocks this pin withholds / rate-limited demand). Under slack
        # (shortfall 0) the cost vanishes; across a long tool it grows
        # linearly with the residual, which is what makes the per-tick
        # re-evaluation revoke pins on overrunning tools.
        inflicted = min(shortfall, float(s.kv_blocks))
        cost = self.cfg.pin_price_scale * remaining * inflicted \
            / max(demand, 1.0)
        return benefit - cost

    def should_pin(self, s: Session, now: float) -> bool:
        return self.retention_score(s, now) > 0.0

    # --- three-way retention --------------------------------------------------
    def offload_net(self, s: Session, now: float) -> float:
        """Net benefit (seconds) of parking this KV in host DRAM instead of
        dropping it: warm restore avoids the prefix recompute but pays one
        synchronous PCIe swap-in, plus a priced share of the (asynchronous)
        swap-out for DMA/PCIe occupancy. Residency cost in HBM is zero —
        that is the whole point of the tier."""
        if (not self.cfg.enable_offload or self.swap_seconds is None
                or s.resident_len < self.cfg.offload_min_tokens):
            return float("-inf")
        # the restore avoids recomputing the WHOLE prefix, but per-block
        # offload only pays PCIe for the private suffix — shared blocks
        # are re-referenced on device for free
        moved = (self.swap_tokens(s) if self.swap_tokens is not None
                 else s.resident_len)
        t_swap = self.swap_seconds(moved)
        # serialized swapper: the restore blocks a GPU tick for t_swap.
        # async stream: the H2D prefetch overlaps other sessions' compute,
        # so no GPU time is lost to the restore itself.
        serialized = 0.0 if self.swap_in_overlapped else t_swap
        # CPU contention: the restore's staging copy queues for a shared
        # host core — under a tool burst that wait delays the warm resume
        # whether or not the DMA itself is overlapped
        cpu_delay = self.cpu_wait(t_swap, now) if self.cpu_wait else 0.0
        benefit = self.recompute_time(s.resident_len) - serialized - cpu_delay
        return benefit - self.cfg.offload_price * t_swap

    def disk_net(self, s: Session, now: float) -> float:
        """Net benefit (seconds) of parking this KV on the NVMe cold tier:
        a warm (if slow) resume avoids the prefix recompute but pays the
        *staged* two-hop restore — the NVMe read gates readiness (the
        session waits it out, subtracted from the benefit) and the PCIe
        swap-in serializes a tick unless the async stream overlaps it —
        plus a priced share of the staged write path (D2H + NVMe write)
        for DMA/device occupancy. Residency cost in both HBM *and* DRAM is
        zero — that is what the cold tier buys."""
        if (not self.cfg.enable_disk or self.disk_read_seconds is None
                or self.swap_seconds is None
                or s.resident_len < self.cfg.disk_min_tokens):
            return float("-inf")
        moved = (self.swap_tokens(s) if self.swap_tokens is not None
                 else s.resident_len)
        t_up = self.swap_seconds(moved)          # hop 2: DRAM -> HBM
        t_read = self.disk_read_seconds(moved)   # hop 1: NVMe -> DRAM
        serialized = 0.0 if self.swap_in_overlapped else t_up
        # CPU contention: both staged hops (spool fill pump + H2D staging)
        # queue for shared host cores before the session can resume warm
        cpu_delay = (self.cpu_wait(t_read + t_up, now)
                     if self.cpu_wait else 0.0)
        benefit = (self.recompute_time(s.resident_len) - serialized - t_read
                   - cpu_delay)
        t_write = self.disk_write_seconds(moved) + t_up
        return benefit - self.cfg.disk_price * t_write

    def retention_decision(self, s: Session, now: float) -> KVAction:
        """PIN / OFFLOAD (host) / OFFLOAD_DISK / FREE by comparing
        recompute time, one-hop and staged two-hop restore time, and
        pressure-priced HBM residency (paper §4.3, extended). PIN wins
        ties: under slack its residency cost vanishes while any offload
        pays a transfer. Between the off-device tiers, host DRAM wins
        unless the expected idle window is long (``disk_idle_min_s`` of
        EMA-estimated tool time) — heavy-tailed agentic tools (CI runs,
        human-in-the-loop waits) are exactly where burning scarce DRAM on
        a multi-minute wait loses to the cold tier."""
        pin_net = self.retention_score(s, now)
        off_net = self.offload_net(s, now)
        dsk_net = self.disk_net(s, now)
        self.last_prices = {"pin_net": pin_net, "offload_net": off_net,
                            "disk_net": dsk_net}
        if pin_net > 0.0 and pin_net >= off_net and pin_net >= dsk_net:
            return KVAction.PIN
        if dsk_net > 0.0:
            long_idle = (self.telem is not None and
                         self.telem.tool_estimate(s.cur.tool_kind)
                         >= self.cfg.disk_idle_min_s)
            if long_idle or off_net <= 0.0:
                return KVAction.OFFLOAD_DISK
        if off_net > 0.0:
            return KVAction.OFFLOAD
        return KVAction.FREE

    def revoke_actions(self, pinned: Sequence[Session], now: float
                       ) -> List[Tuple[Session, KVAction]]:
        """Per-tick re-evaluation, four-way: pins whose retention score went
        negative are revoked — to host DRAM (or the NVMe cold tier, on long
        idle windows) while retention still nets positive, to a drop
        otherwise."""
        out: List[Tuple[Session, KVAction]] = []
        for s in pinned:
            d = self.retention_decision(s, now)
            if d != KVAction.PIN:
                out.append((s, d))
        return out

    def reclaim_order(self, pinned: Sequence[Session], now: float) -> List[Session]:
        """Pinned sessions in reclaim order (lowest retention score first)."""
        return sorted(pinned, key=lambda s: self.retention_score(s, now))
