"""Dynamic SLO-Aware Goodput (paper Eq. 1-2).

    G(t) = (1/dt) * sum_{i in W_dt} 1[L_i <= tau_i],   tau_i = alpha * T_ideal(i)

T_ideal is the session's isolated (concurrency-1) execution time, computed by
the same analytic perf model the simulator uses (the paper measures it with
max-concurrency-1 vLLM runs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.core.session import Session


@dataclass
class LatencyStats:
    mean: float
    p50: float
    p90: float
    p95: float
    p99: float
    n: int

    @classmethod
    def of(cls, xs: Sequence[float]) -> "LatencyStats":
        if not xs:
            return cls(float("nan"), float("nan"), float("nan"),
                       float("nan"), float("nan"), 0)
        a = np.asarray(xs, np.float64)
        return cls(float(a.mean()), *(float(np.percentile(a, p))
                                      for p in (50, 90, 95, 99)), len(a))


def goodput(finished: Sequence[Session], horizon: float, alpha: float) -> float:
    """Completed-within-SLO requests per second over the run horizon."""
    ok = sum(1 for s in finished
             if s.e2e_latency <= alpha * s.ideal_time)
    return ok / max(horizon, 1e-9)


def token_throughput(finished: Sequence[Session], horizon: float) -> float:
    toks = sum(sum(r.decode_tokens for r in s.rounds) for s in finished)
    return toks / max(horizon, 1e-9)


def summarize(finished: Sequence[Session], horizon: float,
              alphas: Sequence[float] = (1.0, 2.0, 3.0)) -> Dict:
    lat = LatencyStats.of([s.e2e_latency for s in finished])
    ttfts: List[float] = []
    for s in finished:
        ttfts.extend(s.ttfts)
    return {
        "n_finished": len(finished),
        "latency": lat,
        "ttft": LatencyStats.of(ttfts),
        "goodput": {a: goodput(finished, horizon, a) for a in alphas},
        "token_throughput": token_throughput(finished, horizon),
        "completion_rate": len(finished),
    }
