"""Unified Information Stream (paper §4.1, Table 1).

Structured boundary events with stable session identifiers, emitted whenever
a session changes execution state on either plane:

    GPU plane:     gpu_submit / gpu_first_token / gpu_end
    CPU plane:     tool_enqueue / tool_start / tool_end
    Control plane: window_update / admit / evict / pin / unpin / preempt / swap

Both the external control plane and the internal scheduler consume the same
stream; consumers subscribe with callbacks and the full log is retained for
benchmarks (eviction-dynamics figures read it directly).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

GPU_SUBMIT = "gpu_submit"
GPU_FIRST_TOKEN = "gpu_first_token"
GPU_END = "gpu_end"
TOOL_ENQUEUE = "tool_enqueue"
TOOL_START = "tool_start"
TOOL_END = "tool_end"
WINDOW_UPDATE = "window_update"
ADMIT = "admit"
EVICT = "evict"
PIN = "pin"
UNPIN = "unpin"
PREEMPT = "preempt"
SWAP_OUT = "swap_out"
SWAP_IN = "swap_in"
DEMOTE = "demote"              # tiered store: host DRAM -> NVMe migration
PROMOTE = "promote"            # tiered store: NVMe -> host DRAM (staged restore)
PREFIX_HIT = "prefix_hit"      # cold prefill attached to shared radix blocks
FINISH = "finish"


@dataclass(frozen=True)
class Event:
    kind: str
    t: float
    sid: int = -1
    data: Dict[str, Any] = field(default_factory=dict)


class EventBus:
    """Low-overhead pub/sub + append log."""

    def __init__(self, keep_log: bool = True):
        self._subs: Dict[str, List[Callable[[Event], None]]] = {}
        self._all: List[Callable[[Event], None]] = []
        self.log: List[Event] = []
        self.keep_log = keep_log
        self.counts: Dict[str, int] = {}

    def subscribe(self, kind: Optional[str], fn: Callable[[Event], None]) -> None:
        if kind is None:
            self._all.append(fn)
        else:
            self._subs.setdefault(kind, []).append(fn)

    def emit(self, kind: str, t: float, sid: int = -1, /, **data) -> Event:
        ev = Event(kind, t, sid, data)
        self.counts[kind] = self.counts.get(kind, 0) + 1
        if self.keep_log:
            self.log.append(ev)
        for fn in self._subs.get(kind, ()):
            fn(ev)
        for fn in self._all:
            fn(ev)
        return ev

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self.log if e.kind == kind]
