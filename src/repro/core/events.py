"""Unified Information Stream (paper §4.1, Table 1).

Structured boundary events with stable session identifiers, emitted whenever
a session changes execution state on either plane:

    GPU plane:     gpu_submit / gpu_first_token / gpu_end
                   (+ per-tick attribution records: prefill_chunk /
                   decode_step, carrying the executed interval)
    CPU plane:     tool_enqueue / tool_start / tool_end
    Control plane: submit / reject / window_update / admit / evict / pin /
                   unpin / preempt / retention / tick / incident
    I/O plane:     swap_out / swap_in / demote / promote / swap_abandon

Both the external control plane and the internal scheduler consume the same
stream; consumers subscribe with callbacks and the log is retained for
benchmarks (eviction-dynamics figures read it directly) and for the
observability layer (``repro.obs``), which assembles the stream into
per-session span trees and exclusive critical-path segments.

Long soaks bound memory with ``max_log``: the log becomes a ring buffer and
``dropped`` counts evictions. ``of_kind`` answers from a per-kind index —
O(matches), not a full-log scan — capped at the same depth when a ring is
configured.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

GPU_SUBMIT = "gpu_submit"
GPU_FIRST_TOKEN = "gpu_first_token"
GPU_END = "gpu_end"
PREFILL_CHUNK = "prefill_chunk"  # one executed prefill chunk: data carries
DECODE_STEP = "decode_step"      # (start, end); one decode quantum likewise
TOOL_ENQUEUE = "tool_enqueue"
TOOL_START = "tool_start"
TOOL_END = "tool_end"
SUBMIT = "submit"              # session entered the external queue
REJECT = "reject"              # admission-rejected (can never fit the pool)
WINDOW_UPDATE = "window_update"
ADMIT = "admit"
EVICT = "evict"
PIN = "pin"
UNPIN = "unpin"
PREEMPT = "preempt"
RETENTION = "retention"        # audit: chosen action + priced alternatives
TICK = "tick"                  # one engine iteration (phase wall timings)
SWAP_OUT = "swap_out"
SWAP_IN = "swap_in"
SWAP_ABANDON = "swap_abandon"  # host copy given up: rebuild by recompute
DEMOTE = "demote"              # tiered store: host DRAM -> NVMe migration
PROMOTE = "promote"            # tiered store: NVMe -> host DRAM (staged restore)
PREFIX_HIT = "prefix_hit"      # cold prefill attached to shared radix blocks
FINISH = "finish"
INCIDENT = "incident"          # obs.detect: structured anomaly w/ evidence
TRACE_META = "trace_meta"      # JSONL dump header (dropped-event count)


@dataclass(frozen=True)
class Event:
    kind: str
    t: float
    sid: int = -1
    data: Dict[str, Any] = field(default_factory=dict)


class EventBus:
    """Low-overhead pub/sub + append log (optionally ring-buffered)."""

    def __init__(self, keep_log: bool = True, max_log: Optional[int] = None):
        self._subs: Dict[str, List[Callable[[Event], None]]] = {}
        self._all: List[Callable[[Event], None]] = []
        self.keep_log = keep_log
        self.max_log = max_log
        self.log: Deque[Event] = deque(maxlen=max_log)
        self._by_kind: Dict[str, Deque[Event]] = {}
        self.counts: Dict[str, int] = {}
        self.dropped = 0               # ring evictions (max_log exceeded)

    def subscribe(self, kind: Optional[str], fn: Callable[[Event], None]) -> None:
        if kind is None:
            self._all.append(fn)
        else:
            self._subs.setdefault(kind, []).append(fn)

    def emit(self, kind: str, t: float, sid: int = -1, /, **data) -> Event:
        ev = Event(kind, t, sid, data)
        self.counts[kind] = self.counts.get(kind, 0) + 1
        if self.keep_log:
            log = self.log
            if log.maxlen is not None and len(log) == log.maxlen:
                self.dropped += 1
            log.append(ev)
            idx = self._by_kind.get(kind)
            if idx is None:
                # per-kind ring at the same depth as the log: of_kind stays
                # O(matches) and total retention is bounded by kinds x cap
                idx = self._by_kind[kind] = deque(maxlen=self.max_log)
            idx.append(ev)
        for fn in self._subs.get(kind, ()):
            fn(ev)
        for fn in self._all:
            fn(ev)
        return ev

    def of_kind(self, kind: str) -> List[Event]:
        return list(self._by_kind.get(kind, ()))
