"""NVMe disk tier: the cold third layer of the KV-state hierarchy.

Where :mod:`repro.kvcache.host_tier` models an engineered batched-DMA PCIe
path, this tier models a local NVMe device the way serving systems actually
see one:

* **per-op latency** — every read/write pays a fixed device latency before
  any bytes move (NVMe ~100 us class, orders of magnitude above DRAM);
* **bandwidth asymmetry** — sequential read and write bandwidths are
  configured separately (consumer/datacenter NVMe writes meaningfully
  slower than it reads, and sustained writes slower still);
* **bounded queue depth** — the device serves at most ``queue_depth``
  modeled operations concurrently; further ops queue behind the earliest
  slot to free. A burst of demotions therefore *back-pressures itself*
  instead of completing at infinite aggregate bandwidth.

Entries are block-granular, like the host tier: only a session's private
blocks occupy capacity (the radix-shared prefix never leaves the device
pool). Readiness is future-aware with the same discipline as ``HostTier``:
the sim's "future" is the modeled completion time on the sim clock, a live
backend attaches the real :class:`~repro.kvcache.swap_stream.TransferFuture`
of the file write and ``ready`` gates on that instead.

Two backends share this accounting:

* **modeled** (default) — pure cost model, used by the discrete-event sim;
* **real-file** — :class:`DiskFileStore`, a spool directory of one
  ``.npz``-style file per session that the live runner's spill/fill jobs
  write and read through the background swap stream. The file store is the
  data plane only; capacity and readiness always live in :class:`DiskTier`.
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.kvcache.host_tier import IN_FLIGHT


@dataclass
class DiskTierConfig:
    capacity_blocks: int = 262_144
    read_bw: float = 3.5e9         # bytes/s, sequential read
    write_bw: float = 1.8e9        # bytes/s, sustained sequential write
    op_latency_s: float = 1e-4     # per-op device latency (NVMe ~100 us)
    queue_depth: int = 16          # concurrent modeled ops; more ops queue


@dataclass
class _Entry:
    tokens: int
    blocks: int
    ready_at: float                # modeled completion (the sim's "future")
    future: Optional[object] = None  # real transfer future (live path)


class DiskTier:
    """Capacity accounting + NVMe cost model for the cold tier.

    The API mirrors ``HostTier`` (store / load / drop / ready /
    time_to_ready / next_event_time / mark_in_flight / attach_future) so
    :class:`~repro.kvcache.tiers.TieredStore` can move entries between the
    two with symmetric bookkeeping.
    """

    def __init__(self, cfg: DiskTierConfig, bytes_per_token: float,
                 block_size: int):
        self.cfg = cfg
        self.bytes_per_token = max(1.0, float(bytes_per_token))
        self.block_size = block_size
        self._entries: Dict[int, _Entry] = {}
        self._used = 0          # running sum(e.blocks) — keeps probes O(1)
        # bounded queue depth: completion time of each modeled in-flight op
        # slot; a new op starts at the earliest slot to free (or now).
        self._q_free = [0.0] * max(1, cfg.queue_depth)
        # stats
        self.stores = 0
        self.hits = 0           # entries promoted/restored (cold tier paid off)
        self.drops = 0          # entries abandoned (recompute fallback / free)
        self.bytes_moved = 0.0

    # --- cost model ----------------------------------------------------
    def _service_seconds(self, n_tokens: int, bw: float) -> float:
        if n_tokens <= 0:
            return 0.0
        return self.cfg.op_latency_s + \
            n_tokens * self.bytes_per_token / bw

    def read_seconds(self, n_tokens: int) -> float:
        """Unqueued NVMe -> DRAM service time (the first hop of a staged
        restore); policy-facing — queueing is applied when an op is issued."""
        return self._service_seconds(n_tokens, self.cfg.read_bw)

    def write_seconds(self, n_tokens: int) -> float:
        """Unqueued DRAM -> NVMe service time (demotion / direct offload)."""
        return self._service_seconds(n_tokens, self.cfg.write_bw)

    def _issue(self, now: float, service_s: float) -> float:
        """Admit one modeled op through the bounded queue: it starts at the
        earliest free slot (>= now) and occupies it for ``service_s``.
        Returns the completion time."""
        if service_s <= 0.0:
            return now
        i = min(range(len(self._q_free)), key=self._q_free.__getitem__)
        start = max(now, self._q_free[i])
        done = start + service_s
        self._q_free[i] = done
        return done

    def issue_read(self, now: float, n_tokens: int) -> float:
        """Issue one modeled promotion read through the bounded queue;
        returns its completion time (>= now + read_seconds when the queue
        is backed up)."""
        return self._issue(now, self.read_seconds(n_tokens))

    # --- occupancy -----------------------------------------------------
    @property
    def capacity_blocks(self) -> int:
        return self.cfg.capacity_blocks

    @property
    def used_blocks(self) -> int:
        return self._used

    def can_store(self, blocks: int) -> bool:
        return self._used + blocks <= self.cfg.capacity_blocks

    def holds(self, sid: int) -> bool:
        return sid in self._entries

    # --- lifecycle -----------------------------------------------------
    def store(self, sid: int, tokens: int, blocks: int, now: float, *,
              extra_delay_s: float = 0.0) -> float:
        """Register a write into the cold tier; returns modeled seconds
        until the entry is durable (queue wait + op latency + bytes/bw).
        ``extra_delay_s`` front-loads an upstream staging leg (the PCIe D2H
        of a direct device->disk offload) before the NVMe op is issued."""
        assert sid not in self._entries, f"double disk store of sid {sid}"
        done = self._issue(now + extra_delay_s, self.write_seconds(tokens))
        self._entries[sid] = _Entry(tokens, blocks, done)
        self._used += blocks
        self.stores += 1
        self.bytes_moved += tokens * self.bytes_per_token
        return max(0.0, done - now)

    def mark_in_flight(self, sid: int) -> None:
        """Async backends: gate ``ready`` on a real transfer future from
        registration (same sentinel discipline as the host tier)."""
        e = self._entries.get(sid)
        if e is not None:
            e.future = IN_FLIGHT

    def attach_future(self, sid: int, future) -> None:
        e = self._entries.get(sid)
        if e is not None and future is not None:
            e.future = future

    def ready(self, sid: int, now: float) -> bool:
        """Durable on NVMe (promotable)? Future-gated entries answer from
        the real transfer; modeled entries from the sim clock."""
        e = self._entries.get(sid)
        if e is None:
            return False
        if e.future is not None:
            return e.future.done()
        return now >= e.ready_at

    def time_to_ready(self, sid: int, now: float) -> Optional[float]:
        e = self._entries.get(sid)
        if e is None:
            return None
        if e.future is not None:
            return 0.0 if e.future.done() else None
        return max(0.0, e.ready_at - now)

    def load(self, sid: int, now: float) -> Optional[int]:
        """Promotion consumed the entry: release capacity, count the hit.
        Unknown or still-in-flight sids return None (sentinel) — the entry
        is retained in flight, and never KeyErrors the caller."""
        e = self._entries.get(sid)
        if e is None:
            return None
        if e.future is not None and not e.future.done():
            return None
        del self._entries[sid]
        self._used -= e.blocks
        self.hits += 1
        self.bytes_moved += e.tokens * self.bytes_per_token
        return e.tokens

    def drop(self, sid: int) -> None:
        """Abandon an entry (recompute fallback / session finished)."""
        e = self._entries.pop(sid, None)
        if e is not None:
            self._used -= e.blocks
            self.drops += 1

    def peek(self, sid: int) -> Optional[Tuple[int, int]]:
        """(tokens, blocks) of an entry without consuming it; None when
        unknown."""
        e = self._entries.get(sid)
        return None if e is None else (e.tokens, e.blocks)

    def evacuate(self, sid: int) -> Optional[Tuple[int, int]]:
        """Remove an entry for tier migration *without* counting a drop;
        returns (tokens, blocks) or None for unknown sids."""
        e = self._entries.pop(sid, None)
        if e is None:
            return None
        self._used -= e.blocks
        return e.tokens, e.blocks

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest modeled in-flight completion after ``now`` (sim timer);
        future-gated entries resolve on the wall clock, not the sim clock."""
        ts = [e.ready_at for e in self._entries.values()
              if e.future is None and e.ready_at > now]
        return min(ts) if ts else None

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.stores)


class DiskFileStore:
    """Real-file backend: one spool file per session under a directory.

    This is the live runner's data plane for the cold tier — spill jobs
    write a session's private host KV blocks here (freeing the DRAM copy),
    fill jobs read them back ahead of promotion. Uses ``numpy.savez`` so a
    (k, v) pair round-trips bit-exact; all I/O is expected to run on the
    background swap stream, never the engine thread.
    """

    def __init__(self, root: Optional[str] = None):
        self._own = root is None
        self.root = root or tempfile.mkdtemp(prefix="mars_kv_spool_")
        os.makedirs(self.root, exist_ok=True)
        self.files_written = 0
        self.files_read = 0
        self.bytes_written = 0

    def _path(self, sid: int) -> str:
        return os.path.join(self.root, f"kv_{sid}.npz")

    def write(self, sid: int, k: np.ndarray, v: np.ndarray) -> str:
        path = self._path(sid)
        with open(path, "wb") as f:
            np.savez(f, k=k, v=v)
        self.files_written += 1
        self.bytes_written += k.nbytes + v.nbytes
        return path

    def read(self, sid: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        path = self._path(sid)
        if not os.path.exists(path):
            return None
        with np.load(path) as z:
            out = (z["k"], z["v"])
        self.files_read += 1
        return out

    def delete(self, sid: int) -> None:
        try:
            os.unlink(self._path(sid))
        except FileNotFoundError:
            pass

    def close(self) -> None:
        if not self._own:
            return
        try:
            for name in os.listdir(self.root):
                try:
                    os.unlink(os.path.join(self.root, name))
                except OSError:
                    pass
            os.rmdir(self.root)
        except OSError:
            pass
