"""Host-DRAM offload tier: the third retention outcome.

Capacity-accounted KV residency in host memory with a PCIe-bandwidth cost
model. Unlike the legacy swap path (InferCept's stock-vLLM swapper: per-
layer-per-block scattered DMAs, ~3 GB/s effective, serialized with the
engine step), this tier models an engineered batched-DMA path:

* swap-OUT is asynchronous — the copy overlaps tool execution on the DMA
  engine; the entry only becomes *restorable* once the transfer completes
  (``ready_at`` on the sim clock);
* swap-IN is synchronous — decode needs the KV, so restore time serializes
  with the engine step (the execution backend charges
  ``meta["swap_cost_s"]``).

On the live ``jax_runner`` path the same BatchWork swap entries are executed
with real ``jax.device_get`` / ``jax.device_put`` of the slot's cache region.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass
class HostTierConfig:
    capacity_blocks: int = 32_768
    pcie_bw: float = 24e9          # bytes/s, batched contiguous DMA
    base_latency_s: float = 5e-4   # per-transfer setup


@dataclass
class _Entry:
    tokens: int
    blocks: int
    ready_at: float


class HostTier:
    def __init__(self, cfg: HostTierConfig, bytes_per_token: float,
                 block_size: int):
        self.cfg = cfg
        self.bytes_per_token = max(1.0, float(bytes_per_token))
        self.block_size = block_size
        self._entries: Dict[int, _Entry] = {}
        self._used = 0          # running sum(e.blocks) — keeps probes O(1)
        # stats
        self.stores = 0
        self.hits = 0           # completed swap-ins (offload paid off)
        self.drops = 0          # entries abandoned (recompute fallback / free)
        self.bytes_moved = 0.0

    # --- cost model ----------------------------------------------------
    def swap_seconds(self, n_tokens: int) -> float:
        if n_tokens <= 0:
            return 0.0
        return self.cfg.base_latency_s + \
            n_tokens * self.bytes_per_token / self.cfg.pcie_bw

    # --- occupancy -----------------------------------------------------
    @property
    def capacity_blocks(self) -> int:
        return self.cfg.capacity_blocks

    @property
    def used_blocks(self) -> int:
        return self._used

    def can_store(self, blocks: int) -> bool:
        return self._used + blocks <= self.cfg.capacity_blocks

    def holds(self, sid: int) -> bool:
        return sid in self._entries

    # --- lifecycle -----------------------------------------------------
    def store(self, sid: int, tokens: int, blocks: int, now: float) -> float:
        """Register an offload; returns transfer seconds (DMA overlaps the
        tool phase; the entry is restorable from ``now + seconds``)."""
        assert sid not in self._entries, f"double offload of sid {sid}"
        sec = self.swap_seconds(tokens)
        self._entries[sid] = _Entry(tokens, blocks, now + sec)
        self._used += blocks
        self.stores += 1
        self.bytes_moved += tokens * self.bytes_per_token
        return sec

    def ready(self, sid: int, now: float) -> bool:
        e = self._entries.get(sid)
        return e is not None and now >= e.ready_at

    def load(self, sid: int, now: float) -> int:
        """Swap-in completed: release host capacity, count the hit."""
        e = self._entries.pop(sid)
        self._used -= e.blocks
        self.hits += 1
        self.bytes_moved += e.tokens * self.bytes_per_token
        return e.tokens

    def drop(self, sid: int) -> None:
        """Abandon an entry (session fell back to recompute or finished)."""
        e = self._entries.pop(sid, None)
        if e is not None:
            self._used -= e.blocks
            self.drops += 1

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest in-flight transfer completion after ``now`` — the sim
        driver must not jump the clock past it while a restore is gated."""
        ts = [e.ready_at for e in self._entries.values() if e.ready_at > now]
        return min(ts) if ts else None

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.stores)
