"""Host-DRAM offload tier: the third retention outcome.

Capacity-accounted KV residency in host memory with a PCIe-bandwidth cost
model. Unlike the legacy swap path (InferCept's stock-vLLM swapper: per-
layer-per-block scattered DMAs, ~3 GB/s effective, serialized with the
engine step), this tier models an engineered batched-DMA path:

* swap-OUT is asynchronous — the copy overlaps tool execution (and, on the
  live path, the other sessions' compute via the background swap stream);
  the entry only becomes *restorable* once the transfer completes. How
  completion is observed depends on the path: the sim keeps the cost model
  as its "future" (``ready_at`` on the sim clock), while the live runner
  attaches the real :class:`~repro.kvcache.swap_stream.TransferFuture` of
  the D2H drain and ``ready`` gates on that instead of the modeled time;
* swap-IN serializes only when it must: the sim charges the engineered
  restore time via ``meta["swap_cost_s"]``, and the live paged runner
  *prefetches* the H2D crossing on the swap stream so a restore whose
  future already resolved charges nothing (the engine stamps
  ``meta["swap_cost_s"] = 0.0`` for it).

On the live ``jax_runner`` path the same BatchWork swap entries are executed
with real ``jax.device_get`` / ``jax.device_put`` of the per-block page
regions, on the background stream when the backend runs one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass
class HostTierConfig:
    capacity_blocks: int = 32_768
    pcie_bw: float = 24e9          # bytes/s, batched contiguous DMA
    base_latency_s: float = 5e-4   # per-transfer setup


class _InFlight:
    """Sentinel "future" for a swap-out whose real transfer future has not
    been attached yet (the backend creates it inside ``run_batch``, one
    tick after the engine registers the entry): never done, so ``ready``
    cannot fall back to the modeled clock and restore pages that were
    never drained."""

    @staticmethod
    def done() -> bool:
        return False


IN_FLIGHT = _InFlight()


@dataclass
class _Entry:
    tokens: int
    blocks: int
    ready_at: float                # modeled completion (the sim's "future")
    future: Optional[object] = None  # real transfer future (live path)


class HostTier:
    def __init__(self, cfg: HostTierConfig, bytes_per_token: float,
                 block_size: int):
        self.cfg = cfg
        self.bytes_per_token = max(1.0, float(bytes_per_token))
        self.block_size = block_size
        self._entries: Dict[int, _Entry] = {}
        self._used = 0          # running sum(e.blocks) — keeps probes O(1)
        # stats
        self.stores = 0
        self.hits = 0           # completed swap-ins (offload paid off)
        self.drops = 0          # entries abandoned (recompute fallback / free)
        self.bytes_moved = 0.0

    # --- cost model ----------------------------------------------------
    def swap_seconds(self, n_tokens: int) -> float:
        if n_tokens <= 0:
            return 0.0
        return self.cfg.base_latency_s + \
            n_tokens * self.bytes_per_token / self.cfg.pcie_bw

    # --- occupancy -----------------------------------------------------
    @property
    def capacity_blocks(self) -> int:
        return self.cfg.capacity_blocks

    @property
    def used_blocks(self) -> int:
        return self._used

    def can_store(self, blocks: int) -> bool:
        return self._used + blocks <= self.cfg.capacity_blocks

    def holds(self, sid: int) -> bool:
        return sid in self._entries

    # --- lifecycle -----------------------------------------------------
    def store(self, sid: int, tokens: int, blocks: int, now: float, *,
              extra_delay_s: float = 0.0) -> float:
        """Register an offload; returns modeled transfer seconds. The entry
        starts on the modeled "future" (restorable from ``now + seconds``
        on the sim clock); a live backend replaces that with the real
        transfer future via ``mark_in_flight``/``attach_future``.
        ``extra_delay_s`` pushes restorability out beyond the DMA itself —
        the TieredStore charges the D2H staging copy's CPU-pool queueing
        delay through it."""
        assert sid not in self._entries, f"double offload of sid {sid}"
        sec = self.swap_seconds(tokens) + max(0.0, extra_delay_s)
        self._entries[sid] = _Entry(tokens, blocks, now + sec)
        self._used += blocks
        self.stores += 1
        self.bytes_moved += tokens * self.bytes_per_token
        return sec

    def mark_in_flight(self, sid: int) -> None:
        """Async backends: gate ``ready`` on a real transfer future from
        the moment of registration. Until ``attach_future`` delivers one,
        the entry is never ready (the D2H drain has not even started)."""
        e = self._entries.get(sid)
        if e is not None:
            e.future = IN_FLIGHT

    def attach_future(self, sid: int, future) -> None:
        """Swap-completion handshake: bind the backend's real transfer
        future (created inside ``run_batch``) to the entry. Tolerates a
        missing entry — the session may have been detached or dropped to
        recompute between batch formation and execution."""
        e = self._entries.get(sid)
        if e is not None and future is not None:
            e.future = future

    def ready(self, sid: int, now: float) -> bool:
        """Restorable? Future-gated entries answer from the *real* transfer
        (done == the bytes are in host memory); modeled entries answer from
        the sim clock (``now >= ready_at``) — the cost model is the sim
        path's future, bit-identical to the pre-stream behaviour."""
        e = self._entries.get(sid)
        if e is None:
            return False
        if e.future is not None:
            return e.future.done()
        return now >= e.ready_at

    def time_to_ready(self, sid: int, now: float) -> Optional[float]:
        """Seconds until the swap-out transfer makes ``sid`` restorable.
        Modeled entries answer exactly ``max(0, ready_at - now)``; future-
        gated entries answer 0.0 once the transfer resolved and None while
        it is in flight (the wall clock, not the model, decides). None for
        unknown sids."""
        e = self._entries.get(sid)
        if e is None:
            return None
        if e.future is not None:
            return 0.0 if e.future.done() else None
        return max(0.0, e.ready_at - now)

    def load(self, sid: int, now: float) -> Optional[int]:
        """Swap-in completed: release host capacity, count the hit.

        Hardened to match ``drop``'s tolerance: an unknown sid (entry
        already dropped/detached between batch formation and commit) or a
        still-in-flight one (future-gated transfer unresolved — the bytes
        are not in host memory) returns the None sentinel for the caller
        to handle instead of KeyError-ing the engine; the in-flight entry
        is retained so the transfer can still land."""
        e = self._entries.get(sid)
        if e is None:
            return None
        if e.future is not None and not e.future.done():
            return None
        del self._entries[sid]
        self._used -= e.blocks
        self.hits += 1
        self.bytes_moved += e.tokens * self.bytes_per_token
        return e.tokens

    def drop(self, sid: int) -> None:
        """Abandon an entry (session fell back to recompute or finished)."""
        e = self._entries.pop(sid, None)
        if e is not None:
            self._used -= e.blocks
            self.drops += 1

    # --- tier migration (TieredStore) -----------------------------------
    def peek(self, sid: int) -> Optional[Tuple[int, int]]:
        """(tokens, blocks) of an entry without consuming it; None when
        unknown."""
        e = self._entries.get(sid)
        return None if e is None else (e.tokens, e.blocks)

    def evacuate(self, sid: int) -> Optional[Tuple[int, int]]:
        """Remove an entry for tier migration *without* counting a drop or
        a hit (the bytes move tiers, the retention outcome is still open);
        returns (tokens, blocks) or None for unknown sids."""
        e = self._entries.pop(sid, None)
        if e is None:
            return None
        self._used -= e.blocks
        return e.tokens, e.blocks

    def admit_staged(self, sid: int, tokens: int, blocks: int, now: float,
                     *, transfer_s: float, future=None) -> None:
        """Register an entry arriving from another tier (NVMe promotion):
        restorable after ``transfer_s`` on the sim clock, or — live path —
        when ``future`` (the file-read job) resolves. Counts a store, so
        ``hit_rate`` stays entries-restored / entries-registered."""
        assert sid not in self._entries, f"double admit of sid {sid}"
        self._entries[sid] = _Entry(tokens, blocks, now + transfer_s, future)
        self._used += blocks
        self.stores += 1
        self.bytes_moved += tokens * self.bytes_per_token

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest in-flight *modeled* transfer completion after ``now`` —
        the sim driver must not jump the clock past it while a restore is
        gated. Future-gated entries resolve on the wall clock, not the sim
        clock, so they are not timer events."""
        ts = [e.ready_at for e in self._entries.values()
              if e.future is None and e.ready_at > now]
        return min(ts) if ts else None

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.stores)
