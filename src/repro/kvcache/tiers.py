"""TieredStore: host-DRAM + NVMe orchestration for offloaded KV state.

One object owns the two off-device tiers and every move between them, so
the engine talks to a single surface (``store`` / ``request`` / ``load`` /
``drop``) and capacity accounting can never double-count an entry: a sid
lives in **exactly one** tier at any instant.

Decision model
--------------

All placement follows the same net-benefit currency MARS retention uses —
seconds of GPU work saved vs. seconds of restore paid:

* **direct offload** (tool yield): the co-scheduler's four-way
  ``retention_decision`` picks PIN / OFFLOAD (host) / OFFLOAD_DISK / FREE.
  Disk wins when retention still nets positive under the *staged* restore
  cost but the expected idle window is long enough (or host DRAM full
  enough) that parking the bytes in DRAM wastes the warmer tier.

* **demotion** (``maintain``, every engine tick): a host entry is demoted
  to NVMe when it is *cold* (idle past ``demote_after_s`` while its
  session still sits in a tool), host occupancy is past
  ``demote_watermark``, the NVMe tier has room, and retention on disk
  still beats recompute::

      recompute_time(context_tokens)  >  staged_restore_seconds(tokens)

  Entries whose staged restore would cost more than rebuilding are *not*
  demoted (they stay in DRAM where the restore is still a win); the store
  never unilaterally drops an entry — only the engine decides to abandon.

* **promotion** (``request``, on access): when a session wants its KV back
  and the entry sits on NVMe, the store issues the staged first hop
  (NVMe -> DRAM read through the device's bounded queue) and re-registers
  the entry in the host tier gated on that read; the engine's normal
  swap-in path then pays only the second hop (DRAM -> device over PCIe),
  gen-certified against the block pool exactly like a host-only restore.

Staged-restore cost formula (what both the co-scheduler's ``disk_net`` and
the demotion gate price)::

    staged_restore_s(tokens) = disk.read_seconds(tokens)   # NVMe -> DRAM
                             + host.swap_seconds(tokens)   # DRAM -> HBM

The first hop gates *readiness* (the session waits, the GPU does not); the
second hop is the familiar PCIe swap-in, overlapped by the async swap
stream when the backend runs one.

Data plane
----------

The sim keeps the cost models as its futures (modeled ``ready_at`` on the
sim clock). A live backend binds ``spill``/``unspill`` callbacks
(:meth:`bind_backend`): demotion then submits a file write of the host KV
copy on the background swap stream (FIFO-ordered behind the D2H drain that
produces the bytes) and promotion submits the file read back; the returned
transfer futures gate the owning tier's ``ready`` instead of the model.
Transient staging during a direct device->NVMe offload is bounded by the
stream's double-buffered slots and is not charged to host-tier capacity.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.events import DEMOTE, PROMOTE
from repro.kvcache.disk_tier import DiskTier
from repro.kvcache.host_tier import HostTier


class _EntryMeta:
    __slots__ = ("context_tokens", "stored_at", "target")

    def __init__(self, context_tokens: int, stored_at: float, target: str):
        self.context_tokens = context_tokens   # full resident_len at offload
        self.stored_at = stored_at             # last placement change
        self.target = target                   # tier the entry was aimed at


class TieredStore:
    def __init__(self, host: HostTier, disk: Optional[DiskTier] = None, *,
                 recompute_time: Optional[Callable[[int], float]] = None,
                 demote_after_s: float = 30.0,
                 demote_watermark: float = 0.5,
                 bus=None, cpu_pool=None):
        self.host = host
        self.disk = disk
        self.recompute_time = recompute_time
        self.demote_after_s = demote_after_s
        self.demote_watermark = demote_watermark
        self.bus = bus
        # shared host-CPU core pool: every tier move stages through host
        # cores (D2H/H2D memcpy pumps, spool read/write syscalls), so each
        # transfer leases ``transfer_cpu_frac`` of its wire time from the
        # pool at transfer priority; the lease's queueing delay pushes the
        # entry's readiness out (a tool burst visibly delays swap drains
        # and staged NVMe restores). None => transfers are CPU-free.
        self.cpu_pool = cpu_pool
        self._meta: Dict[int, _EntryMeta] = {}
        # live data-plane callbacks (sid -> Optional[TransferFuture])
        self._spill = None
        self._unspill = None
        # per-tick demotability predicate (engine: session still in tool)
        self._demotable: Optional[Callable[[int], bool]] = None
        # stats
        self.demotions = 0
        self.staged_restores = 0       # promotions issued (disk -> host)
        self.direct_to_disk = 0
        self.cpu_wait_s = 0.0          # readiness delay added by core waits

    def _stage_cpu(self, now: float, sid: int, wire_s: float, kind: str,
                   tag: str) -> float:
        """Lease the staging-copy CPU for a ``wire_s``-second transfer from
        the shared pool; returns the extra seconds (queueing + interference
        beyond the wire time) the caller must add to the entry's readiness.
        0.0 when no pool is bound or the transfer is free."""
        if self.cpu_pool is None or wire_s <= 0.0:
            return 0.0
        frac = self.cpu_pool.cfg.transfer_cpu_frac
        if frac <= 0.0:
            return 0.0
        lease = self.cpu_pool.submit(now, frac * wire_s, sid=sid,
                                     kind=kind, tag=tag, priority=0)
        extra = max(0.0, lease.end - (now + wire_s))
        self.cpu_wait_s += extra
        return extra

    def bind_backend(self, spill=None, unspill=None) -> None:
        """Live path: ``spill(sid)`` writes the backend's host KV copy of
        ``sid`` to the NVMe spool (freeing the DRAM copy) and returns the
        transfer future; ``unspill(sid)`` reads it back ahead of a
        promotion. Either may return None (synchronous completion)."""
        self._spill = spill
        self._unspill = unspill

    # --- delegated surface (HostTier-compatible) ------------------------
    @property
    def block_size(self) -> int:
        return self.host.block_size

    def swap_seconds(self, n_tokens: int) -> float:
        """PCIe hop (DRAM <-> HBM) — the engine's swap-in stamp and the
        policies' offload pricing, unchanged from the host-only tier."""
        return self.host.swap_seconds(n_tokens)

    def staged_restore_seconds(self, n_tokens: int) -> float:
        """Both hops of a cold restore: NVMe read + PCIe up."""
        if self.disk is None:
            return self.host.swap_seconds(n_tokens)
        return self.disk.read_seconds(n_tokens) + \
            self.host.swap_seconds(n_tokens)

    def can_store(self, blocks: int) -> bool:
        return self.host.can_store(blocks)

    def can_store_disk(self, blocks: int) -> bool:
        return self.disk is not None and self.disk.can_store(blocks)

    def holds(self, sid: int) -> bool:
        return self.host.holds(sid) or \
            (self.disk is not None and self.disk.holds(sid))

    def tier_of(self, sid: int) -> Optional[str]:
        if self.host.holds(sid):
            return "host"
        if self.disk is not None and self.disk.holds(sid):
            return "disk"
        return None

    # --- lifecycle ------------------------------------------------------
    def store(self, sid: int, tokens: int, blocks: int, now: float, *,
              target: str = "host", context_tokens: Optional[int] = None
              ) -> float:
        """Register an offload into ``target``; returns modeled seconds to
        durability. ``target="disk"`` without a disk tier falls back to
        host (the policy's capacity precheck should prevent it)."""
        if target == "disk" and self.disk is None:
            target = "host"
        self._meta[sid] = _EntryMeta(
            context_tokens if context_tokens is not None else tokens,
            now, target)
        if target == "disk":
            self.direct_to_disk += 1
            # staged write: the D2H leg stages through bounded stream
            # buffers (not host-tier capacity), then the NVMe write lands;
            # the D2H pump's core wait stretches the staging leg
            d2h = self.host.swap_seconds(tokens)
            extra = self._stage_cpu(now, sid, d2h, "swap", "d2h")
            return self.disk.store(
                sid, tokens, blocks, now,
                extra_delay_s=d2h + extra)
        sec = self.host.swap_seconds(tokens)
        extra = self._stage_cpu(now, sid, sec, "swap", "d2h")
        return self.host.store(sid, tokens, blocks, now,
                               extra_delay_s=extra)

    def mark_in_flight(self, sid: int) -> None:
        if self.host.holds(sid):
            self.host.mark_in_flight(sid)
        elif self.disk is not None and self.disk.holds(sid):
            self.disk.mark_in_flight(sid)

    def attach_future(self, sid: int, future) -> None:
        """Swap-completion handshake, tier-routed. For a direct-to-disk
        entry the D2H drain only produces the DRAM staging copy — when a
        spill callback is bound, the file write is chained on the same
        FIFO stream (so it runs after the drain) and *its* future gates
        the disk entry instead."""
        if self.host.holds(sid):
            self.host.attach_future(sid, future)
            return
        if self.disk is None or not self.disk.holds(sid):
            return
        if self._spill is not None:
            chained = self._spill(sid)
            if chained is not None:
                self.disk.attach_future(sid, chained)
                return
        self.disk.attach_future(sid, future)

    def ready(self, sid: int, now: float) -> bool:
        """Pure probe: restorable over one PCIe hop right now? Disk-tier
        entries are never directly ready — ``request`` must promote."""
        return self.host.ready(sid, now)

    def time_to_ready(self, sid: int, now: float) -> Optional[float]:
        if self.host.holds(sid):
            return self.host.time_to_ready(sid, now)
        if self.disk is not None and self.disk.holds(sid):
            t = self.disk.time_to_ready(sid, now)
            if t is None:
                return None
            # durable + unqueued read estimate (queueing applies at issue)
            tokens, _blocks = self.disk.peek(sid)
            return t + self.disk.read_seconds(tokens)
        return None

    def request(self, sid: int, now: float, *,
                urgent: bool = False) -> Optional[bool]:
        """The session wants its KV back. Returns True when the entry is
        host-resident and ready (the engine may form the swap-in), False
        while a transfer gates it, and None when restore can never proceed
        (unknown sid, or — only when ``urgent`` — a promotion blocked on
        host capacity that displacement could not fix): the caller should
        abandon to recompute."""
        if self.host.holds(sid):
            return self.host.ready(sid, now)
        if self.disk is None or not self.disk.holds(sid):
            return None
        if not self.disk.ready(sid, now):
            return False               # demotion/offload write still landing
        _tokens, blocks = self.disk.peek(sid)
        if not self.host.can_store(blocks):
            self._make_room(blocks, now)
        if not self.host.can_store(blocks):
            return None if urgent else False
        self._promote(sid, now)
        return self.host.ready(sid, now)

    def _promote(self, sid: int, now: float) -> None:
        _tokens, blocks = self.disk.peek(sid)
        tokens = self.disk.load(sid, now)
        assert tokens is not None      # caller checked disk.ready
        read_done = self.disk.issue_read(now, tokens)
        # the fill pump (file read -> DRAM staging buffer) runs on shared
        # cores: its queueing delay extends the first hop
        extra = self._stage_cpu(now, sid, read_done - now, "spool", "fill")
        done = read_done + extra
        fut = self._unspill(sid) if self._unspill is not None else None
        self.host.admit_staged(sid, tokens, blocks, now,
                               transfer_s=done - now, future=fut)
        m = self._meta.get(sid)
        if m is not None:
            m.stored_at = now          # promoted == hot: reset cold clock
            m.target = "host"
        self.staged_restores += 1
        if self.bus is not None:
            # read_s: the NVMe read (plus any fill-pump core wait) gating
            # the staged restore's first hop — the tracer turns
            # [t, t + read_s] into an I/O span
            self.bus.emit(PROMOTE, now, sid, blocks=blocks, tokens=tokens,
                          read_s=done - now)

    def load(self, sid: int, now: float) -> Optional[int]:
        """Swap-in committed: consume the (host-resident) entry. Returns
        the restored token count, or None for unknown/in-flight sids (the
        hardened sentinel — never a KeyError into the engine)."""
        self._meta.pop(sid, None)
        return self.host.load(sid, now)

    def drop(self, sid: int) -> None:
        self._meta.pop(sid, None)
        if self.host.holds(sid):
            self.host.drop(sid)
        elif self.disk is not None:
            self.disk.drop(sid)

    def next_event_time(self, now: float) -> Optional[float]:
        ts = []
        t = self.host.next_event_time(now)
        if t is not None:
            ts.append(t)
        if self.disk is not None:
            t = self.disk.next_event_time(now)
            if t is not None:
                ts.append(t)
        return min(ts) if ts else None

    # --- demotion -------------------------------------------------------
    def maintain(self, now: float,
                 demotable: Optional[Callable[[int], bool]] = None) -> int:
        """Per-tick upkeep: demote cold host entries to NVMe (see module
        docstring for the gate). ``demotable(sid)`` lets the engine veto
        entries whose session is no longer idle (back from its tool and
        about to restore — demoting those would ping-pong). Returns the
        number of demotions issued this call."""
        self._demotable = demotable
        if self.disk is None:
            return 0
        cap = max(1, self.host.capacity_blocks)
        if self.host.used_blocks <= self.demote_watermark * cap:
            return 0               # below watermark: skip the cold scan
        n = 0
        for sid in self._cold_first():
            if self.host.used_blocks <= self.demote_watermark * cap:
                break
            m = self._meta.get(sid)
            if m is None or now - m.stored_at < self.demote_after_s:
                break                  # cold-first order: the rest are newer
            if self._demote_one(sid, now, m):
                n += 1
        return n

    def _cold_first(self):
        """Host-tier sids, oldest placement first."""
        sids = [sid for sid in self._meta if self.host.holds(sid)]
        sids.sort(key=lambda sid: self._meta[sid].stored_at)
        return sids

    def _demote_one(self, sid: int, now: float, m: _EntryMeta) -> bool:
        if self._demotable is not None and not self._demotable(sid):
            return False
        if not self.host.ready(sid, now):
            return False               # D2H still in flight: bytes not in DRAM
        tokens, blocks = self.host.peek(sid)
        if not self.disk.can_store(blocks):
            return False
        if self.recompute_time is not None and \
                self.recompute_time(m.context_tokens) <= \
                self.staged_restore_seconds(tokens):
            return False               # disk would not beat recompute: stay
        idle_s = now - m.stored_at
        tokens, blocks = self.host.evacuate(sid)
        # the spool-write pump leases cores too: its wait delays durability
        extra = self._stage_cpu(now, sid, self.disk.write_seconds(tokens),
                                "spool", "write")
        self.disk.store(sid, tokens, blocks, now, extra_delay_s=extra)
        if self._spill is not None:
            fut = self._spill(sid)
            if fut is not None:
                self.disk.attach_future(sid, fut)
        m.stored_at = now
        m.target = "disk"
        self.demotions += 1
        if self.bus is not None:
            # write_s: the modeled spool write behind this demotion (the
            # entry is unreadable until it lands) — an I/O span for tracing
            self.bus.emit(DEMOTE, now, sid, blocks=blocks, tokens=tokens,
                          write_s=self.disk.write_seconds(tokens),
                          idle_s=idle_s)
        return True

    def _make_room(self, blocks: int, now: float) -> None:
        """Promotion displacement: demote cold-est ready host entries (age
        gate waived — the promoting session is *hot* and outranks anything
        idle) until ``blocks`` fit or nothing more can move."""
        if self.disk is None:
            return
        for sid in self._cold_first():
            if self.host.can_store(blocks):
                return
            m = self._meta.get(sid)
            if m is not None:
                self._demote_one(sid, now, m)

    # --- telemetry ------------------------------------------------------
    def stats(self) -> Dict:
        """Per-tier occupancy / hit-rate / traffic breakdown (exported via
        ``Telemetry.kv_tier_stats``)."""
        def _tier(t):
            return {
                "used_blocks": t.used_blocks,
                "capacity_blocks": t.capacity_blocks,
                "occupancy": t.used_blocks / max(1, t.capacity_blocks),
                "stores": t.stores,
                "hits": t.hits,
                "hit_rate": round(t.hit_rate, 4),
                "drops": t.drops,
            }
        return {
            "host": _tier(self.host),
            "disk": _tier(self.disk) if self.disk is not None else None,
            "demotions": self.demotions,
            "staged_restores": self.staged_restores,
            "direct_to_disk": self.direct_to_disk,
            "cpu_wait_s": round(self.cpu_wait_s, 6),
        }
