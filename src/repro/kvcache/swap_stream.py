"""Background swap stream: KV page copies off the engine's critical path.

MARS's retention policy only pays off if offload is cheap relative to
recompute, yet a swapper that serializes every D2H/H2D page copy inside the
engine step inflates the very swap cost ``retention_decision`` prices. This
module provides the asynchronous alternative (InferCept-style swap-out, plus
prefetched swap-in):

* :class:`TransferFuture` — the completion handle for one host<->device
  transfer. ``HostTier.ready`` gates restorability on it (the sim path keeps
  the modeled ``ready_at`` as its "future"); the engine defers — never
  stalls on — a session whose swap-in future is unresolved.

* :class:`StagingBuffers` — double-buffered staging, keyed on the swap
  record's block list: at most ``n`` (default 2) transfers hold device-side
  staging snapshots at once. A further submit blocks until a buffer retires
  (backpressure bounds staging memory); while one buffer drains over PCIe
  the other fills — which is exactly the copy/compute overlap a dedicated
  DMA stream gives on real hardware. Slots are identities rather than
  preallocated byte ranges because swap records vary in page count; what
  the pair bounds is transfers in flight, not bytes.

* :class:`SwapStream` — a single worker thread executing transfer jobs in
  submission order. FIFO matters: a swap-out drain for a sid re-offloaded
  after a drop must land after the stale drain, and an H2D prefetch can
  never starve behind slot-holding D2H jobs submitted later (slot holders
  are always ahead of it in the queue).

The stream executes *host crossings* only. Device-side snapshot gathers
stay on the submitting thread, ordered by the JAX dispatch stream before
any subsequent cache writes — that ordering is what makes it safe for a
swapped-out block id to be re-leased and rewritten in the very tick whose
batch carries the swap-out.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional


class TransferFuture:
    """Completion handle for one host<->device KV transfer.

    ``done()`` is the only query the engine needs (deferral is polling, not
    blocking); ``result()`` blocks and re-raises the worker's exception, so
    a failed transfer surfaces at the consumer instead of vanishing on the
    worker thread.
    """

    __slots__ = ("sid", "direction", "_event", "_result", "_exc")

    def __init__(self, sid: int = -1, direction: str = "d2h"):
        self.sid = sid
        self.direction = direction
        self._event = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"transfer {self.direction} sid={self.sid} still in flight")
        if self._exc is not None:
            raise self._exc
        return self._result

    # worker-side
    def _resolve(self, value) -> None:
        self._result = value
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()


def resolved_future(sid: int = -1, direction: str = "d2h",
                    value=None) -> TransferFuture:
    """An already-completed transfer (e.g. a swap record with no private
    blocks: nothing crosses PCIe, but the handshake still wants a future)."""
    fut = TransferFuture(sid, direction)
    fut._resolve(value)
    return fut


class StagingBuffers:
    """Double-buffered staging slots with blocking backpressure.

    ``acquire`` is called by the submitter *before* it snapshots device
    pages (the snapshot is what occupies staging memory); ``release`` by
    the worker once the crossing retired the buffer. Stats are plain
    counters read by tests and the benchmark.
    """

    def __init__(self, n: int = 2):
        assert n >= 1
        self.n = n
        self._free: List[int] = list(range(n))
        self._cv = threading.Condition()
        self._used_once: set = set()
        self.acquires = 0
        self.reuses = 0            # slot handed out again after retiring
        self.blocked_waits = 0     # submits that hit backpressure
        self.max_in_flight = 0

    def acquire(self) -> int:
        with self._cv:
            if not self._free:
                self.blocked_waits += 1
            while not self._free:
                self._cv.wait()
            slot = self._free.pop()
            self.acquires += 1
            if slot in self._used_once:
                self.reuses += 1
            self._used_once.add(slot)
            in_flight = self.n - len(self._free)
            self.max_in_flight = max(self.max_in_flight, in_flight)
            return slot

    def release(self, slot: int) -> None:
        with self._cv:
            assert slot not in self._free, f"double release of slot {slot}"
            self._free.append(slot)
            self._cv.notify()


class SwapStream:
    """Single background worker executing transfer jobs in FIFO order."""

    def __init__(self, n_buffers: int = 2, name: str = "kv-swap-stream", *,
                 cpu_pool=None):
        self.staging = StagingBuffers(n_buffers)
        # shared host-CPU core pool (live accounting): the worker holds one
        # core while a crossing's copy pump executes, so pool gauges see
        # real transfer CPU alongside tool threads. None => untracked.
        self.cpu_pool = cpu_pool
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name=name)
        self._started = False
        self._closed = False
        self._lock = threading.Lock()
        # stats (benchmark / tests); h2n/n2h are the NVMe tier's
        # spill/fill crossings (host DRAM <-> disk spool)
        self.d2h_submitted = 0
        self.d2h_completed = 0
        self.h2d_submitted = 0
        self.h2d_completed = 0
        self.h2n_submitted = 0
        self.h2n_completed = 0
        self.n2h_submitted = 0
        self.n2h_completed = 0
        # per-direction transfer wall time (seconds executing on the
        # worker, queue wait excluded) — the observability layer's
        # MetricsRegistry snapshots these alongside queue_depth()
        self.xfer_seconds: Dict[str, float] = dict.fromkeys(
            ("d2h", "h2d", "h2n", "n2h"), 0.0)
        self.xfer_max_s: Dict[str, float] = dict.fromkeys(
            ("d2h", "h2d", "h2n", "n2h"), 0.0)

    DIRECTIONS = ("d2h", "h2d", "h2n", "n2h")

    def queue_depth(self) -> int:
        """Jobs submitted but not yet executed (approximate: the worker's
        in-progress job has already left the queue)."""
        return self._q.qsize()

    def stats(self) -> Dict[str, object]:
        """One-shot counter snapshot for metrics export."""
        out: Dict[str, object] = {"queue_depth": self.queue_depth()}
        for d in self.DIRECTIONS:
            out[f"{d}_submitted"] = getattr(self, f"{d}_submitted")
            out[f"{d}_completed"] = getattr(self, f"{d}_completed")
            out[f"{d}_seconds"] = self.xfer_seconds[d]
            out[f"{d}_max_s"] = self.xfer_max_s[d]
        st = self.staging
        out["staging"] = {"acquires": st.acquires, "reuses": st.reuses,
                          "blocked_waits": st.blocked_waits,
                          "max_in_flight": st.max_in_flight}
        return out

    def submit(self, fn: Callable[[], object], *, sid: int = -1,
               direction: str = "d2h") -> TransferFuture:
        """Enqueue ``fn`` on the worker; returns its completion future.
        ``fn`` owns releasing any staging slot it (or its submitter)
        acquired — the stream never sees slots, only jobs."""
        assert direction in self.DIRECTIONS
        fut = TransferFuture(sid, direction)
        with self._lock:
            assert not self._closed, "submit on a closed SwapStream"
            setattr(self, f"{direction}_submitted",
                    getattr(self, f"{direction}_submitted") + 1)
            if not self._started:
                self._thread.start()
                self._started = True
        self._q.put((fn, fut))
        return fut

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, fut = item
            pool, tok = self.cpu_pool, None
            if pool is not None:
                kind = "swap" if fut.direction in ("d2h", "h2d") else "spool"
                tok = pool.acquire(time.monotonic(), kind)
            try:
                t0 = time.monotonic()
                value = fn()
                # count before resolving: a consumer woken by result()
                # must never observe a stale completion counter
                if fut.direction in self.DIRECTIONS:
                    dt = time.monotonic() - t0
                    self.xfer_seconds[fut.direction] += dt
                    if dt > self.xfer_max_s[fut.direction]:
                        self.xfer_max_s[fut.direction] = dt
                    setattr(self, f"{fut.direction}_completed",
                            getattr(self, f"{fut.direction}_completed") + 1)
                fut._resolve(value)
            except BaseException as exc:          # surfaces at result()
                fut._fail(exc)
            finally:
                if tok is not None:
                    pool.release(time.monotonic(), tok)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted job has executed (tests/teardown)."""
        if not self._started:
            return
        done = TransferFuture(-1, "drain")       # not a transfer: uncounted
        self._q.put((lambda: None, done))
        done.result(timeout)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            started = self._started
        if started:
            self._q.put(None)
            self._thread.join(timeout=5.0)
