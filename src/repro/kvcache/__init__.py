"""Tiered KV-state subsystem.

Six layers that together replace the counter-only block manager:

* ``pool``        — block-identity pool: per-block refcounts, copy-on-write,
                    radix-cached (evictable) blocks, per-session leases.
* ``radix``       — prefix index over hashed token chunks: sessions sharing a
                    repository context share physical KV blocks.
* ``host_tier``   — host-DRAM offload tier with a PCIe-bandwidth cost model;
                    the third retention outcome (PIN / OFFLOAD / DROP).
* ``disk_tier``   — NVMe cold tier: per-op latency + asymmetric read/write
                    bandwidth + bounded queue depth; modeled and real-file
                    (``DiskFileStore``) backends.
* ``tiers``       — ``TieredStore``, the host+disk orchestrator: net-benefit
                    demotion of cold host entries, promote-on-access with the
                    staged two-hop restore, per-tier stats; the fourth
                    retention outcome (OFFLOAD_DISK).
* ``swap_stream`` — background worker + double-buffered staging that moves
                    every tier crossing (D2H/H2D page copies, NVMe
                    spill/fill) off the engine's critical path; tier
                    ``ready`` gates on its transfer futures.
"""
from repro.kvcache.disk_tier import DiskFileStore, DiskTier, DiskTierConfig
from repro.kvcache.host_tier import HostTier, HostTierConfig
from repro.kvcache.pool import BlockPool, DeviceBindingMap, TieredPoolProbe
from repro.kvcache.radix import (RadixIndex, chunk_key_digest,
                                 estimate_digest_match)
from repro.kvcache.swap_stream import (StagingBuffers, SwapStream,
                                       TransferFuture, resolved_future)
from repro.kvcache.tiers import TieredStore

__all__ = ["BlockPool", "DeviceBindingMap", "TieredPoolProbe", "RadixIndex",
           "HostTier", "HostTierConfig", "DiskTier", "DiskTierConfig",
           "DiskFileStore", "TieredStore", "SwapStream", "StagingBuffers",
           "TransferFuture", "resolved_future", "chunk_key_digest",
           "estimate_digest_match"]
