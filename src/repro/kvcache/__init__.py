"""Tiered KV-state subsystem.

Four layers that together replace the counter-only block manager:

* ``pool``        — block-identity pool: per-block refcounts, copy-on-write,
                    radix-cached (evictable) blocks, per-session leases.
* ``radix``       — prefix index over hashed token chunks: sessions sharing a
                    repository context share physical KV blocks.
* ``host_tier``   — host-DRAM offload tier with a PCIe-bandwidth cost model;
                    the third retention outcome (PIN / OFFLOAD / DROP).
* ``swap_stream`` — background worker + double-buffered staging that moves
                    the tier's D2H/H2D page copies off the engine's critical
                    path; ``HostTier.ready`` gates on its transfer futures.
"""
from repro.kvcache.host_tier import HostTier, HostTierConfig
from repro.kvcache.pool import BlockPool, DeviceBindingMap, TieredPoolProbe
from repro.kvcache.radix import (RadixIndex, chunk_key_digest,
                                 estimate_digest_match)
from repro.kvcache.swap_stream import (StagingBuffers, SwapStream,
                                       TransferFuture, resolved_future)

__all__ = ["BlockPool", "DeviceBindingMap", "TieredPoolProbe", "RadixIndex",
           "HostTier", "HostTierConfig", "SwapStream", "StagingBuffers",
           "TransferFuture", "resolved_future", "chunk_key_digest",
           "estimate_digest_match"]
