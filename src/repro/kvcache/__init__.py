"""Tiered KV-state subsystem.

Three layers that together replace the counter-only block manager:

* ``pool``      — block-identity pool: per-block refcounts, copy-on-write,
                  radix-cached (evictable) blocks, per-session leases.
* ``radix``     — prefix index over hashed token chunks: sessions sharing a
                  repository context share physical KV blocks.
* ``host_tier`` — host-DRAM offload tier with a PCIe-bandwidth cost model;
                  the third retention outcome (PIN / OFFLOAD / DROP).
"""
from repro.kvcache.host_tier import HostTier, HostTierConfig
from repro.kvcache.pool import BlockPool, DeviceBindingMap, TieredPoolProbe
from repro.kvcache.radix import RadixIndex

__all__ = ["BlockPool", "DeviceBindingMap", "TieredPoolProbe", "RadixIndex",
           "HostTier", "HostTierConfig"]
