"""Block-identity KV pool: refcounts, copy-on-write, cached (evictable) blocks.

Supersedes the counter-only ``engine.block_manager.BlockManager`` behind the
same ``probe()`` surface (``total`` / ``free`` / ``pinned``), adding:

* **identity** — physical blocks have ids; sessions hold ordered *leases*
  (one logical block reference per lease entry), so two sessions prefix-
  sharing a repository context reference the *same* physical blocks;
* **refcounts** — a physical block is freed only when its last reference
  drops; a block registered in the radix index instead parks on an evictable
  LRU ("cached": content retained, capacity counted as free, reclaimed on
  allocation pressure with a callback into the index);
* **copy-on-write** — writing into a partially-filled tail block that is
  shared (refcount > 1) or index-registered first copies it to a private
  block, so cached prefix content stays pristine for future matchers;
* **device placement** — every block id doubles as a *device page id*
  through a :class:`DeviceBindingMap`; ``block_table(sid)`` exports the
  session's lease as an int32 page table (lease order == token order) that
  the live paged runner feeds straight to the Pallas ``paged_attention``
  kernel. Content *generations* (bumped whenever a page may be rewritten)
  let a swapped-out session ``reacquire`` still-live shared blocks on
  restore instead of copying them back over PCIe, and the ``cow_log``
  records (sid, src, dst) pairs so a physical backend can mirror each
  copy-on-write as a device page copy.

Capacity semantics the engine relies on: ``free`` counts allocatable blocks
*including* cached ones; ``free + physical_in_use == total`` always holds.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.engine.block_manager import BlockPoolProbe


class DeviceBindingMap:
    """Block id -> device page id for a physical page pool.

    The live runner allocates ``n_device_pages`` KV pages plus one scratch
    page; the binding is identity (bid ``i`` lives in page ``i``), which this
    class makes explicit so a future remapping (e.g. per-device sub-pools
    under tensor parallelism) only touches this map. ``scratch_page`` is the
    parking target for padded/idle lanes and is never handed to a session.
    """

    def __init__(self, n_device_pages: int):
        assert n_device_pages > 0
        self.n_device_pages = n_device_pages

    @property
    def scratch_page(self) -> int:
        return self.n_device_pages

    def page_of(self, bid: int) -> int:
        assert 0 <= bid < self.n_device_pages, f"unbound block {bid}"
        return bid

    def table(self, bids: Sequence[int], width: Optional[int] = None
              ) -> np.ndarray:
        """int32 page table for ``bids`` in order, padded with the scratch
        page to ``width`` (>= len(bids)) when given."""
        n = len(bids) if width is None else width
        assert n >= len(bids)
        out = np.full((n,), self.scratch_page, np.int32)
        for i, bid in enumerate(bids):
            out[i] = self.page_of(bid)
        return out


class TieredPoolProbe(BlockPoolProbe):
    """O(1) probe extended with sharing/caching counters."""

    def __init__(self, total: int, free: int, pinned: int, *,
                 cached: int, leased: int, physical: int, cow_count: int):
        super().__init__(total, free, pinned)
        self.cached = cached          # evictable blocks retaining content
        self.leased = leased          # logical refs held by sessions
        self.physical = physical      # blocks with refcount >= 1
        self.cow_count = cow_count


class BlockPool:
    def __init__(self, total_blocks: int, block_size: int = 32):
        assert total_blocks > 0
        self.total = total_blocks
        self.block_size = block_size
        self.pinned = 0
        self.cow_count = 0
        self._free_ids: List[int] = list(range(total_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}                 # bid -> refcount
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref==0
        self._in_index: set = set()                    # bids the radix owns
        self._leases: Dict[int, List[int]] = {}        # sid -> ordered bids
        self._leased = 0                   # running sum(len(lease)) — keeps
        self._evict_cb: Optional[Callable[[int], None]] = None  # probe O(1)
        # content generation per block: bumped whenever the page may be
        # rewritten (fresh take, or unindexed while still referenced so its
        # sole owner can write in place). A (bid, gen) pair therefore
        # certifies page content across a swap-out/swap-in gap.
        self._gen: List[int] = [0] * total_blocks
        # (sid, src_bid, dst_bid) per copy_on_write, in order — a physical
        # backend drains this each tick and mirrors the copies on device
        # before any page writes.
        self.cow_log: List[Tuple[int, int, int]] = []

    # --- capacity ------------------------------------------------------
    @property
    def free(self) -> int:
        return len(self._free_ids) + len(self._cached)

    @property
    def physical_in_use(self) -> int:
        return len(self._ref)

    @property
    def leased_total(self) -> int:
        return self._leased

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size) if n_tokens > 0 else 0

    def can_alloc(self, n: int) -> bool:
        return n <= self.free

    def lease_len(self, sid: int) -> int:
        return len(self._leases.get(sid, ()))

    def lease(self, sid: int) -> List[int]:
        return list(self._leases.get(sid, ()))

    def is_cached(self, bid: int) -> bool:
        return bid in self._cached

    def gen(self, bid: int) -> int:
        """Content generation of ``bid`` (see class docstring)."""
        return self._gen[bid]

    def certify(self, pairs: Sequence[Tuple[int, int]]) -> bool:
        """True iff every (bid, gen) certificate still holds — i.e. no
        certified page was CoW-replaced, cache-evicted, re-leased, or
        unindexed since the certificate was recorded (each of those bumps
        the block's generation). The async swap stream checks this before
        committing a prefetched restore: a record that went stale while the
        transfer was in flight must fall back to recompute *before* any
        pages are touched."""
        return all(self._gen[bid] == gen for bid, gen in pairs)

    def survives_release(self, bid: int) -> bool:
        """True if the block's content outlives one reference drop: another
        session still references it, or the radix index parks it cached.
        Such blocks need no host copy on swap-out — they stay on device."""
        return self._ref.get(bid, 0) > 1 or bid in self._in_index

    def block_table(self, sid: int, binding: Optional[DeviceBindingMap] = None,
                    width: Optional[int] = None) -> np.ndarray:
        """The session's lease as an int32 device page table (lease order ==
        token order). With no binding the block ids *are* the page ids and
        no padding is possible — only a binding knows a safe (scratch) pad
        page, so ``width`` requires one."""
        lease = self._leases.get(sid, [])
        if binding is not None:
            return binding.table(lease, width)
        assert width is None, "padded tables need a DeviceBindingMap"
        return np.asarray(lease, np.int32)

    def drain_cow_log(self) -> List[Tuple[int, int, int]]:
        log, self.cow_log = self.cow_log, []
        return log

    # --- index hooks (radix) -------------------------------------------
    def set_evict_callback(self, cb: Callable[[int], None]) -> None:
        """Called with a bid when allocation pressure reclaims a cached
        block — the index must unlink the node mapped to it."""
        self._evict_cb = cb

    def index_blocks(self, bids: Sequence[int]) -> None:
        self._in_index.update(bids)

    def unindex_block(self, bid: int) -> None:
        """Index dropped its mapping: if the block was parked cached, its
        content is no longer reachable — return it to the free list. Either
        way the content is no longer certified (a still-referenced block's
        sole owner may now write it in place without CoW), so bump gen."""
        self._in_index.discard(bid)
        self._gen[bid] += 1
        if bid in self._cached:
            del self._cached[bid]
            self._free_ids.append(bid)

    # --- allocation ----------------------------------------------------
    def _take_physical(self) -> int:
        if self._free_ids:
            bid = self._free_ids.pop()
        else:
            bid, _ = self._cached.popitem(last=False)  # evict LRU cached
            self._in_index.discard(bid)
            if self._evict_cb is not None:
                self._evict_cb(bid)
        self._gen[bid] += 1                # fresh owner will overwrite
        return bid

    def alloc(self, sid: int, n: int) -> bool:
        """Lease ``n`` fresh private blocks (ref = 1) to ``sid``."""
        if n > self.free:
            return False
        lease = self._leases.setdefault(sid, [])
        for _ in range(n):
            bid = self._take_physical()
            self._ref[bid] = 1
            lease.append(bid)
        self._leased += n
        return True

    def acquire(self, sid: int, bids: Sequence[int]) -> None:
        """Add shared references: incref each block (reviving cached ones)
        and append to ``sid``'s lease in order."""
        lease = self._leases.setdefault(sid, [])
        for bid in bids:
            if bid in self._cached:
                del self._cached[bid]
                self._ref[bid] = 1
            else:
                assert bid in self._ref, f"acquire of dead block {bid}"
                self._ref[bid] += 1
            lease.append(bid)
            self._leased += 1

    def reacquire(self, sid: int, bid: int, gen: int) -> bool:
        """Re-reference a block recorded at swap-out time *iff* its content
        is certifiably unchanged (same generation) and still resident
        (referenced by another session or parked cached). Appends to
        ``sid``'s lease like acquire(); returns False when the content is
        gone and the caller must fall back to restore/recompute."""
        if self._gen[bid] != gen:
            return False
        if bid in self._ref:
            self._ref[bid] += 1
        elif bid in self._cached:
            del self._cached[bid]
            self._ref[bid] = 1
        else:
            return False                   # free-listed: content not certified
        self._leases.setdefault(sid, []).append(bid)
        self._leased += 1
        return True

    def _drop_ref(self, bid: int) -> None:
        r = self._ref[bid] - 1
        if r > 0:
            self._ref[bid] = r
            return
        del self._ref[bid]
        if bid in self._in_index:
            self._cached[bid] = None                   # park MRU, evictable
        else:
            self._free_ids.append(bid)

    def release_all(self, sid: int) -> int:
        """Drop every reference ``sid`` holds; returns the lease length."""
        lease = self._leases.pop(sid, [])
        for bid in lease:
            self._drop_ref(bid)
        self._leased -= len(lease)
        return len(lease)

    # --- copy-on-write -------------------------------------------------
    def tail_needs_cow(self, sid: int) -> bool:
        lease = self._leases.get(sid)
        if not lease:
            return False
        bid = lease[-1]
        return self._ref.get(bid, 0) > 1 or bid in self._in_index

    def copy_on_write(self, sid: int) -> bool:
        """Replace ``sid``'s tail block with a private copy (needs one free
        physical block). The shared/indexed original keeps its content for
        the other referents / future prefix matchers."""
        lease = self._leases.get(sid)
        if not lease or not self.tail_needs_cow(sid):
            return True
        if self.free < 1:
            return False
        old = lease[-1]
        new = self._take_physical()
        self._ref[new] = 1
        lease[-1] = new
        self._drop_ref(old)
        self.cow_count += 1
        self.cow_log.append((sid, old, new))
        return True

    # --- pinning (counts, as before) -----------------------------------
    def pin(self, n: int) -> None:
        self.pinned += n

    def unpin(self, n: int) -> None:
        self.pinned -= n
        assert self.pinned >= 0

    # --- probe / invariants --------------------------------------------
    def probe(self) -> TieredPoolProbe:
        return TieredPoolProbe(
            self.total, self.free, self.pinned, cached=len(self._cached),
            leased=self.leased_total, physical=len(self._ref),
            cow_count=self.cow_count)

    def check_consistency(self) -> None:
        """Refcount accounting: every reference is a lease entry, every
        physical block is in exactly one of {free, cached, referenced}."""
        refs: Dict[int, int] = {}
        for lease in self._leases.values():
            for bid in lease:
                refs[bid] = refs.get(bid, 0) + 1
        assert refs == self._ref, \
            f"refcount drift: leases={refs} pool={self._ref}"
        assert self._leased == sum(len(v) for v in self._leases.values()), \
            "leased counter drift"
        free_set = set(self._free_ids)
        cached_set = set(self._cached)
        ref_set = set(self._ref)
        assert len(free_set) == len(self._free_ids), "duplicate free id"
        assert not (free_set & cached_set), "block both free and cached"
        assert not (free_set & ref_set), "block both free and referenced"
        assert not (cached_set & ref_set), "block both cached and referenced"
        assert len(free_set) + len(cached_set) + len(ref_set) == self.total, \
            "physical block lost"
        assert cached_set <= self._in_index, "cached block not index-owned"
