"""Block-identity KV pool: refcounts, copy-on-write, cached (evictable) blocks.

Supersedes the counter-only ``engine.block_manager.BlockManager`` behind the
same ``probe()`` surface (``total`` / ``free`` / ``pinned``), adding:

* **identity** — physical blocks have ids; sessions hold ordered *leases*
  (one logical block reference per lease entry), so two sessions prefix-
  sharing a repository context reference the *same* physical blocks;
* **refcounts** — a physical block is freed only when its last reference
  drops; a block registered in the radix index instead parks on an evictable
  LRU ("cached": content retained, capacity counted as free, reclaimed on
  allocation pressure with a callback into the index);
* **copy-on-write** — writing into a partially-filled tail block that is
  shared (refcount > 1) or index-registered first copies it to a private
  block, so cached prefix content stays pristine for future matchers.

Capacity semantics the engine relies on: ``free`` counts allocatable blocks
*including* cached ones; ``free + physical_in_use == total`` always holds.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence

from repro.engine.block_manager import BlockPoolProbe


class TieredPoolProbe(BlockPoolProbe):
    """O(1) probe extended with sharing/caching counters."""

    def __init__(self, total: int, free: int, pinned: int, *,
                 cached: int, leased: int, physical: int, cow_count: int):
        super().__init__(total, free, pinned)
        self.cached = cached          # evictable blocks retaining content
        self.leased = leased          # logical refs held by sessions
        self.physical = physical      # blocks with refcount >= 1
        self.cow_count = cow_count


class BlockPool:
    def __init__(self, total_blocks: int, block_size: int = 32):
        assert total_blocks > 0
        self.total = total_blocks
        self.block_size = block_size
        self.pinned = 0
        self.cow_count = 0
        self._free_ids: List[int] = list(range(total_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}                 # bid -> refcount
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref==0
        self._in_index: set = set()                    # bids the radix owns
        self._leases: Dict[int, List[int]] = {}        # sid -> ordered bids
        self._leased = 0                   # running sum(len(lease)) — keeps
        self._evict_cb: Optional[Callable[[int], None]] = None  # probe O(1)

    # --- capacity ------------------------------------------------------
    @property
    def free(self) -> int:
        return len(self._free_ids) + len(self._cached)

    @property
    def physical_in_use(self) -> int:
        return len(self._ref)

    @property
    def leased_total(self) -> int:
        return self._leased

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size) if n_tokens > 0 else 0

    def can_alloc(self, n: int) -> bool:
        return n <= self.free

    def lease_len(self, sid: int) -> int:
        return len(self._leases.get(sid, ()))

    def lease(self, sid: int) -> List[int]:
        return list(self._leases.get(sid, ()))

    def is_cached(self, bid: int) -> bool:
        return bid in self._cached

    # --- index hooks (radix) -------------------------------------------
    def set_evict_callback(self, cb: Callable[[int], None]) -> None:
        """Called with a bid when allocation pressure reclaims a cached
        block — the index must unlink the node mapped to it."""
        self._evict_cb = cb

    def index_blocks(self, bids: Sequence[int]) -> None:
        self._in_index.update(bids)

    def unindex_block(self, bid: int) -> None:
        """Index dropped its mapping: if the block was parked cached, its
        content is no longer reachable — return it to the free list."""
        self._in_index.discard(bid)
        if bid in self._cached:
            del self._cached[bid]
            self._free_ids.append(bid)

    # --- allocation ----------------------------------------------------
    def _take_physical(self) -> int:
        if self._free_ids:
            return self._free_ids.pop()
        bid, _ = self._cached.popitem(last=False)      # evict LRU cached
        self._in_index.discard(bid)
        if self._evict_cb is not None:
            self._evict_cb(bid)
        return bid

    def alloc(self, sid: int, n: int) -> bool:
        """Lease ``n`` fresh private blocks (ref = 1) to ``sid``."""
        if n > self.free:
            return False
        lease = self._leases.setdefault(sid, [])
        for _ in range(n):
            bid = self._take_physical()
            self._ref[bid] = 1
            lease.append(bid)
        self._leased += n
        return True

    def acquire(self, sid: int, bids: Sequence[int]) -> None:
        """Add shared references: incref each block (reviving cached ones)
        and append to ``sid``'s lease in order."""
        lease = self._leases.setdefault(sid, [])
        for bid in bids:
            if bid in self._cached:
                del self._cached[bid]
                self._ref[bid] = 1
            else:
                assert bid in self._ref, f"acquire of dead block {bid}"
                self._ref[bid] += 1
            lease.append(bid)
            self._leased += 1

    def _drop_ref(self, bid: int) -> None:
        r = self._ref[bid] - 1
        if r > 0:
            self._ref[bid] = r
            return
        del self._ref[bid]
        if bid in self._in_index:
            self._cached[bid] = None                   # park MRU, evictable
        else:
            self._free_ids.append(bid)

    def release_all(self, sid: int) -> int:
        """Drop every reference ``sid`` holds; returns the lease length."""
        lease = self._leases.pop(sid, [])
        for bid in lease:
            self._drop_ref(bid)
        self._leased -= len(lease)
        return len(lease)

    # --- copy-on-write -------------------------------------------------
    def tail_needs_cow(self, sid: int) -> bool:
        lease = self._leases.get(sid)
        if not lease:
            return False
        bid = lease[-1]
        return self._ref.get(bid, 0) > 1 or bid in self._in_index

    def copy_on_write(self, sid: int) -> bool:
        """Replace ``sid``'s tail block with a private copy (needs one free
        physical block). The shared/indexed original keeps its content for
        the other referents / future prefix matchers."""
        lease = self._leases.get(sid)
        if not lease or not self.tail_needs_cow(sid):
            return True
        if self.free < 1:
            return False
        old = lease[-1]
        new = self._take_physical()
        self._ref[new] = 1
        lease[-1] = new
        self._drop_ref(old)
        self.cow_count += 1
        return True

    # --- pinning (counts, as before) -----------------------------------
    def pin(self, n: int) -> None:
        self.pinned += n

    def unpin(self, n: int) -> None:
        self.pinned -= n
        assert self.pinned >= 0

    # --- probe / invariants --------------------------------------------
    def probe(self) -> TieredPoolProbe:
        return TieredPoolProbe(
            self.total, self.free, self.pinned, cached=len(self._cached),
            leased=self.leased_total, physical=len(self._ref),
            cow_count=self.cow_count)

    def check_consistency(self) -> None:
        """Refcount accounting: every reference is a lease entry, every
        physical block is in exactly one of {free, cached, referenced}."""
        refs: Dict[int, int] = {}
        for lease in self._leases.values():
            for bid in lease:
                refs[bid] = refs.get(bid, 0) + 1
        assert refs == self._ref, \
            f"refcount drift: leases={refs} pool={self._ref}"
        assert self._leased == sum(len(v) for v in self._leases.values()), \
            "leased counter drift"
        free_set = set(self._free_ids)
        cached_set = set(self._cached)
        ref_set = set(self._ref)
        assert len(free_set) == len(self._free_ids), "duplicate free id"
        assert not (free_set & cached_set), "block both free and cached"
        assert not (free_set & ref_set), "block both free and referenced"
        assert not (cached_set & ref_set), "block both cached and referenced"
        assert len(free_set) + len(cached_set) + len(ref_set) == self.total, \
            "physical block lost"
        assert cached_set <= self._in_index, "cached block not index-owned"
