"""Prefix index over hashed token chunks (sglang-style radix tree, chunk
granularity = one KV block).

Sessions carry ``meta["prefix_hashes"]``: an ordered list of ``(key,
n_tokens)`` chunks covering their round-0 context, where ``key`` is any
hashable digest of the chunk's tokens (the workload generator uses stable
tuples; a live tokenizer front-end would use a rolling content hash). Two
sessions whose round-0 streams share a prefix produce identical leading
keys, so the second session's cold prefill *matches* the first's inserted
blocks and attaches to them instead of recomputing.

Lifecycle is owned jointly with the pool: inserted blocks are marked
index-owned; when their last session reference drops they park on the
pool's evictable LRU (content retained, capacity still "free"); allocation
pressure evicts them LRU-first, and the pool calls back here so the mapped
node — and any now-unreachable descendants — unlink.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Sequence, Tuple


class RadixNode:
    __slots__ = ("key", "bid", "n_tokens", "children", "parent")

    def __init__(self, key: Hashable, bid: int, n_tokens: int, parent):
        self.key = key
        self.bid = bid
        self.n_tokens = n_tokens
        self.children: Dict[Hashable, "RadixNode"] = {}
        self.parent = parent


class RadixIndex:
    def __init__(self, pool, chunk_tokens: int):
        assert chunk_tokens == pool.block_size, \
            "chunk granularity must equal the block size (one node per block)"
        self.pool = pool
        self.chunk_tokens = chunk_tokens
        self._root = RadixNode(None, -1, 0, None)
        self._by_bid: Dict[int, RadixNode] = {}
        pool.set_evict_callback(self._on_evict)
        # stats (exported into the unified info stream via telemetry)
        self.queries = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0

    def __len__(self) -> int:
        return len(self._by_bid)

    # --- match ---------------------------------------------------------
    def match(self, hashes: Sequence[Tuple[Hashable, int]]
              ) -> List[Tuple[int, int]]:
        """Longest indexed prefix of ``hashes``: list of (bid, n_tokens).
        A node only matches if its chunk is fully covered (same key implies
        same token count, but guard against malformed inputs).

        Pure lookup — the engine polls this per tick for every waiting
        round-0 session, so stats are recorded via record_query /
        record_hit only when the caller actually attaches."""
        out: List[Tuple[int, int]] = []
        node = self._root
        for key, n_tok in hashes:
            child = node.children.get(key)
            if child is None or child.n_tokens != n_tok:
                break
            out.append((child.bid, child.n_tokens))
            node = child
        return out

    # --- stats (driven by the engine) ----------------------------------
    def record_query(self) -> None:
        """One per session that consults the index (not per poll)."""
        self.queries += 1

    def record_hit(self, tokens: int, *, first: bool) -> None:
        """Tokens actually attached; ``first`` marks the session's first
        attach so hits counts sharing sessions, keeping hit_rate ≤ 1."""
        if first:
            self.hits += 1
        self.hit_tokens += tokens

    # --- insert --------------------------------------------------------
    def insert(self, hashes: Sequence[Tuple[Hashable, int]],
               bids: Sequence[int]) -> int:
        """Register ``bids[i]`` as the physical block holding chunk ``i``.
        Existing nodes keep their original block (first insert wins); newly
        created nodes take ownership of the caller's blocks. Returns the
        number of new nodes."""
        assert len(bids) >= len(hashes), "lease shorter than chunk cover"
        node = self._root
        created = 0
        for (key, n_tok), bid in zip(hashes, bids):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, bid, n_tok, node)
                node.children[key] = child
                self._by_bid[bid] = child
                self.pool.index_blocks([bid])
                created += 1
            node = child
        self.inserted_blocks += created
        return created

    # --- eviction ------------------------------------------------------
    def _on_evict(self, bid: int) -> None:
        """Pool reclaimed a cached block: unlink its node. Descendants are
        unreachable for future matches, so un-index their blocks too (the
        pool moves any cached ones back to the raw free list)."""
        node = self._by_bid.pop(bid, None)
        if node is None:
            return
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        stack = list(node.children.values())
        node.children.clear()
        while stack:
            n = stack.pop()
            self._by_bid.pop(n.bid, None)
            self.pool.unindex_block(n.bid)
            stack.extend(n.children.values())
            n.children.clear()
