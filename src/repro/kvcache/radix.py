"""Prefix index over hashed token chunks (sglang-style radix tree, chunk
granularity = one KV block).

Sessions carry ``meta["prefix_hashes"]``: an ordered list of ``(key,
n_tokens)`` chunks covering their round-0 context, where ``key`` is any
hashable digest of the chunk's tokens (the workload generator uses stable
tuples; a live tokenizer front-end would use a rolling content hash). Two
sessions whose round-0 streams share a prefix produce identical leading
keys, so the second session's cold prefill *matches* the first's inserted
blocks and attaches to them instead of recomputing.

Lifecycle is owned jointly with the pool: inserted blocks are marked
index-owned; when their last session reference drops they park on the
pool's evictable LRU (content retained, capacity still "free"); allocation
pressure evicts them LRU-first, and the pool calls back here so the mapped
node — and any now-unreachable descendants — unlink.

**Radix-root digest** (cluster-level prefix reuse): the index additionally
maintains O(#anchors) per-*anchor* statistics, where an anchor is a direct
child of the root — i.e. the first chunk key of an indexed prefix stream,
which identifies a session family / repository context. ``digest(top_k)``
exports the top-k anchors (by indexed-block count) as a compact,
JSON-serializable summary that the cluster router carries in heartbeats and
scores placement with; it is O(k), never O(tree), and is refreshed
incrementally on insert/evict via a monotone ``version`` counter (the
actual dict is rebuilt lazily and cached per version). See
``distributed/router.py`` for the wire format.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Hashable, List, Optional, Sequence, Tuple


def chunk_key_digest(key: Hashable) -> str:
    """Deterministic, process-independent digest of a chunk key (64-bit hex).

    Chunk keys are arbitrary hashable values (the workload generator uses
    tuples of primitives); ``repr`` of those is stable across processes,
    unlike ``hash()`` which is salted per interpreter for strings. Replicas
    and the router must agree on anchor identity without sharing a process,
    so this is the on-the-wire form of a chunk key."""
    return hashlib.blake2b(repr(key).encode(), digest_size=8).hexdigest()


class _AnchorStat:
    """Per-root-child accounting behind the digest (all O(1) to maintain)."""
    __slots__ = ("blocks", "depth", "hits", "queries")

    def __init__(self):
        self.blocks = 0    # indexed blocks in this anchor's subtree
        self.depth = 0     # longest chunk chain inserted under the anchor
        self.hits = 0      # sessions that attached under this anchor
        self.queries = 0   # sessions that consulted the index for this anchor


def estimate_digest_match(digest: Optional[dict],
                          prefix_hashes: Sequence[Tuple[Hashable, int]],
                          anchor_digest: Optional[str] = None) -> int:
    """Estimated longest-indexed-prefix match (in blocks) of a session's
    chunk-key stream against a replica's exported digest.

    The digest is top-k anchors only, so this is an upper-bound estimate:
    if the session's anchor (first chunk key) is present, the match is
    ``min(len(prefix), anchor depth)``; absent anchors estimate 0. The
    local (in-process) path should prefer the exact ``RadixIndex.match``."""
    if not digest or not prefix_hashes:
        return 0
    anchors = digest.get("anchors") or {}
    if anchor_digest is None:
        anchor_digest = chunk_key_digest(prefix_hashes[0][0])
    ent = anchors.get(anchor_digest)
    if not ent:
        return 0
    return min(len(prefix_hashes), int(ent.get("depth", 0)))


class RadixNode:
    __slots__ = ("key", "bid", "n_tokens", "children", "parent", "anchor")

    def __init__(self, key: Hashable, bid: int, n_tokens: int, parent,
                 anchor: Hashable = None):
        self.key = key
        self.bid = bid
        self.n_tokens = n_tokens
        self.children: Dict[Hashable, "RadixNode"] = {}
        self.parent = parent
        self.anchor = anchor          # root-child key this node sits under


class RadixIndex:
    def __init__(self, pool, chunk_tokens: int):
        assert chunk_tokens == pool.block_size, \
            "chunk granularity must equal the block size (one node per block)"
        self.pool = pool
        self.chunk_tokens = chunk_tokens
        self._root = RadixNode(None, -1, 0, None)
        self._by_bid: Dict[int, RadixNode] = {}
        pool.set_evict_callback(self._on_evict)
        # stats (exported into the unified info stream via telemetry)
        self.queries = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        # digest state: per-anchor stats + a monotone version bumped on any
        # insert/evict, so the O(k) export can be cached between changes
        self._anchors: Dict[Hashable, _AnchorStat] = {}
        self.version = 0
        self._digest_cache: Optional[Tuple[Tuple[int, int], dict]] = None

    def __len__(self) -> int:
        return len(self._by_bid)

    # --- match ---------------------------------------------------------
    def match(self, hashes: Sequence[Tuple[Hashable, int]]
              ) -> List[Tuple[int, int]]:
        """Longest indexed prefix of ``hashes``: list of (bid, n_tokens).
        A node only matches if its chunk is fully covered (same key implies
        same token count, but guard against malformed inputs).

        Pure lookup — the engine polls this per tick for every waiting
        round-0 session, so stats are recorded via record_query /
        record_hit only when the caller actually attaches."""
        out: List[Tuple[int, int]] = []
        node = self._root
        for key, n_tok in hashes:
            child = node.children.get(key)
            if child is None or child.n_tokens != n_tok:
                break
            out.append((child.bid, child.n_tokens))
            node = child
        return out

    # --- stats (driven by the engine) ----------------------------------
    def record_query(self, anchor: Hashable = None) -> None:
        """One per session that consults the index (not per poll).
        ``anchor`` (the session's first chunk key) attributes the query to
        its family in the digest. Always bumps ``version``: the digest
        exports the index-wide counters too, so a stats-only change must
        still invalidate the cached export."""
        self.queries += 1
        if anchor is not None:
            stat = self._anchors.get(anchor)
            if stat is not None:
                stat.queries += 1
        self.version += 1

    def record_hit(self, tokens: int, *, first: bool,
                   anchor: Hashable = None) -> None:
        """Tokens actually attached; ``first`` marks the session's first
        attach so hits counts sharing sessions, keeping hit_rate ≤ 1."""
        if first:
            self.hits += 1
            if anchor is not None:
                stat = self._anchors.get(anchor)
                if stat is not None:
                    stat.hits += 1
                    # a sibling may have consulted the index before the
                    # builder's first insert created this anchor (its query
                    # was unattributable then): count the implied query so
                    # the exported per-anchor hit_rate stays <= 1
                    stat.queries = max(stat.queries, stat.hits)
        self.hit_tokens += tokens
        self.version += 1

    # --- insert --------------------------------------------------------
    def insert(self, hashes: Sequence[Tuple[Hashable, int]],
               bids: Sequence[int]) -> int:
        """Register ``bids[i]`` as the physical block holding chunk ``i``.
        Existing nodes keep their original block (first insert wins); newly
        created nodes take ownership of the caller's blocks. Returns the
        number of new nodes."""
        assert len(bids) >= len(hashes), "lease shorter than chunk cover"
        node = self._root
        created = 0
        anchor = hashes[0][0] if hashes else None
        depth = 0
        for (key, n_tok), bid in zip(hashes, bids):
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, bid, n_tok, node, anchor=anchor)
                node.children[key] = child
                self._by_bid[bid] = child
                self.pool.index_blocks([bid])
                created += 1
            node = child
            depth += 1
        self.inserted_blocks += created
        if created and anchor is not None:
            stat = self._anchors.setdefault(anchor, _AnchorStat())
            stat.blocks += created
            stat.depth = max(stat.depth, depth)
            self.version += 1
        return created

    # --- eviction ------------------------------------------------------
    def _on_evict(self, bid: int) -> None:
        """Pool reclaimed a cached block: unlink its node. Descendants are
        unreachable for future matches, so un-index their blocks too (the
        pool moves any cached ones back to the raw free list)."""
        node = self._by_bid.pop(bid, None)
        if node is None:
            return
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        removed = 1
        stack = list(node.children.values())
        node.children.clear()
        while stack:
            n = stack.pop()
            self._by_bid.pop(n.bid, None)
            self.pool.unindex_block(n.bid)
            removed += 1
            stack.extend(n.children.values())
            n.children.clear()
        # digest upkeep: the whole unlinked subtree shares one anchor
        stat = self._anchors.get(node.anchor)
        if stat is not None:
            stat.blocks -= removed
            if stat.blocks <= 0:
                del self._anchors[node.anchor]
            else:
                # depth is maintained as a monotone max on insert; eviction
                # can only shrink the chain, so clamp it to what can remain
                stat.depth = min(stat.depth, stat.blocks)
        self.version += 1

    # --- digest --------------------------------------------------------
    def digest(self, top_k: int = 16) -> dict:
        """Compact radix-root digest for cluster-level placement: the top-k
        anchors by indexed-block count, each as
        ``{anchor_hex: {"blocks", "depth", "hits", "queries"}}`` plus the
        index-wide totals. O(#anchors log k) to build, cached per
        ``version`` so steady-state heartbeats pay a dict lookup. The
        anchor keys are ``chunk_key_digest`` values — process-independent,
        so the dict is wire-ready (JSON-serializable) as exported."""
        if self._digest_cache is not None \
                and self._digest_cache[0] == (self.version, top_k):
            return self._digest_cache[1]
        top = sorted(self._anchors.items(),
                     key=lambda kv: kv[1].blocks, reverse=True)[:top_k]
        d = {
            "v": self.version,
            "indexed_blocks": len(self._by_bid),
            "queries": self.queries,
            "hits": self.hits,
            "hit_tokens": self.hit_tokens,
            "anchors": {
                chunk_key_digest(key): {
                    "blocks": st.blocks, "depth": st.depth,
                    "hits": st.hits, "queries": st.queries,
                    "hit_rate": round(st.hits / max(1, st.queries), 4),
                } for key, st in top},
        }
        self._digest_cache = ((self.version, top_k), d)
        return d
