"""RWKV6 ("Finch") — attention-free LM with data-dependent per-channel decay.

Train/prefill use a TPU-native *chunked* formulation of the WKV6 recurrence
(MXU-friendly block matmuls + a `lax.scan` over chunks), mathematically equal
to the token-by-token recurrence used for decode. The Pallas kernel in
``repro.kernels.wkv6`` implements the same chunked scheme for the hot path;
``repro.kernels.ref.wkv6_ref`` is the shared oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.scan_util import scan as _uscan
from repro.models.layers import ParallelCtx, constrain, rms_norm

F32 = jnp.float32
MIX_NAMES = ("w", "k", "v", "r", "g")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RWKVState:
    """att_shift/ffn_shift: (L, B, D); wkv: (L, B, H, K, V) float32."""
    att_shift: jax.Array
    ffn_shift: jax.Array
    wkv: jax.Array

    def tree_flatten(self):
        return (self.att_shift, self.ffn_shift, self.wkv), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zeros(cls, cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
        D = cfg.d_model
        H = D // cfg.rwkv.head_size
        K = cfg.rwkv.head_size
        return cls(jnp.zeros((cfg.n_layers, batch, D), dtype),
                   jnp.zeros((cfg.n_layers, batch, D), dtype),
                   jnp.zeros((cfg.n_layers, batch, H, K, K), F32))

    @classmethod
    def specs(cls, cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
        D = cfg.d_model
        H = D // cfg.rwkv.head_size
        K = cfg.rwkv.head_size
        return cls(jax.ShapeDtypeStruct((cfg.n_layers, batch, D), dtype),
                   jax.ShapeDtypeStruct((cfg.n_layers, batch, D), dtype),
                   jax.ShapeDtypeStruct((cfg.n_layers, batch, H, K, K), F32))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    D, r = cfg.d_model, cfg.rwkv.mix_lora
    dl = cfg.rwkv.decay_lora
    H = D // cfg.rwkv.head_size
    ks = jax.random.split(key, 12)
    s = D ** -0.5
    return {
        # time-mix (attention analogue)
        "mu_x": jnp.full((D,), 0.5, dtype),
        "mu": jnp.full((5, D), 0.5, dtype),                       # w,k,v,r,g static mixes
        "mix_A": jax.random.normal(ks[0], (D, 5 * r), dtype) * s,
        "mix_B": jax.random.normal(ks[1], (5, r, D), dtype) * (r ** -0.5),
        "w_base": jnp.full((D,), -6.0, F32),                      # decay bias (pre -exp(exp))
        "decay_A": jax.random.normal(ks[2], (D, dl), dtype) * s,
        "decay_B": jax.random.normal(ks[3], (dl, D), dtype) * (dl ** -0.5),
        "u": jax.random.normal(ks[4], (D,), F32) * 0.1,           # current-token bonus
        "wr": jax.random.normal(ks[5], (D, D), dtype) * s,
        "wk": jax.random.normal(ks[6], (D, D), dtype) * s,
        "wv": jax.random.normal(ks[7], (D, D), dtype) * s,
        "wg": jax.random.normal(ks[8], (D, D), dtype) * s,
        "wo": jax.random.normal(ks[9], (D, D), dtype) * s,
        "ln_x_scale": jnp.ones((D,), F32),                        # group-norm over heads
        "ln1": jnp.zeros((D,), dtype),
        "ln2": jnp.zeros((D,), dtype),
        # channel-mix (FFN analogue)
        "cm_mu_k": jnp.full((D,), 0.5, dtype),
        "cm_mu_r": jnp.full((D,), 0.5, dtype),
        "cm_wk": jax.random.normal(ks[10], (D, cfg.d_ff), dtype) * s,
        "cm_wv": jax.random.normal(ks[11], (cfg.d_ff, D), dtype) * (cfg.d_ff ** -0.5),
        "cm_wr": jax.random.normal(ks[4], (D, D), dtype) * s,
    }


def init_rwkv(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "embed": jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), dtype)
                 * cfg.d_model ** -0.5,
        "layers": jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), dtype)
                   * cfg.d_model ** -0.5,
    }


# ---------------------------------------------------------------------------
# WKV6 chunked recurrence
#   S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t @ S_{t-1} + (r_t . (u*k_t)) v_t
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, w, u, state, chunk: int = 32):
    """r,k,v,w: (B, T, H, K) [w in (0,1)]; u: (H, K); state: (B, H, K, K) f32.

    Returns (o (B,T,H,K) f32, new state). T must be a multiple of ``chunk``.

    Numerics: the intra-chunk term factors exp(cum_{t-1}-cum_i) into
    exp(cum_{t-1})*exp(-cum_i); each factor is centred by half the chunk's
    total log-decay so neither overflows f32 even for strong decay
    (|log w| <= ~1.5 per step is guaranteed by the clip in ``_decay``).
    """
    B, T, H, K = r.shape
    n_chunks = T // chunk
    rs = r.astype(F32).reshape(B, n_chunks, chunk, H, K)
    ks_ = k.astype(F32).reshape(B, n_chunks, chunk, H, K)
    vs = v.astype(F32).reshape(B, n_chunks, chunk, H, K)
    lw = jnp.log(jnp.clip(w.astype(F32), 1e-12, 1.0)).reshape(B, n_chunks, chunk, H, K)
    uf = u.astype(F32)

    def chunk_step(S, xs):
        rc, kc, vc, lwc = xs                       # (B, C, H, K)
        cum = jnp.cumsum(lwc, axis=1)              # inclusive decay logs
        # inter-chunk: o_t += (r_t * decay(0..t-1)) @ S
        r_dec = rc * jnp.exp(cum - lwc)            # decay excludes current step
        o_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, S)
        # intra-chunk (strictly lower triangular):
        #   att[t,i] = sum_k r_t[k] k_i[k] exp(cum_{t-1}[k] - cum_i[k])
        half = 0.5 * cum[:, -1:]                   # centring offset (B,1,H,K)
        q_ = rc * jnp.exp(cum - lwc - half)        # (B,C,H,K)
        k_ = kc * jnp.exp(half - cum)
        att = jnp.einsum("bchk,bihk->bhci", q_, k_)
        ti = jnp.arange(chunk)
        tri = ti[None, :] < ti[:, None]            # strictly lower triangular
        # where (not multiply): masked entries can overflow to inf for large
        # chunks; inf * 0 would poison the output with NaNs.
        att = jnp.where(tri[None, None], att, 0.0)
        o_intra = jnp.einsum("bhci,bihv->bchv", att, vc)
        # current-token bonus
        bonus = jnp.einsum("bchk,bchk->bch", rc, uf[None, None] * kc)
        o_cur = bonus[..., None] * vc
        o = o_inter + o_intra + o_cur
        # state update: S' = diag(prod w) S + sum_i decay(i+1..C-1) k_i v_i^T
        total = cum[:, -1]                         # (B, H, K)
        k_dec = kc * jnp.exp(total[:, None] - cum)
        S_new = jnp.exp(total)[..., None] * S + jnp.einsum("bchk,bchv->bhkv", k_dec, vc)
        return S_new, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks_, vs, lw))
    state, outs = _uscan(chunk_step, state.astype(F32), xs)
    o = jnp.moveaxis(outs, 0, 1).reshape(B, T, H, K)
    return o, state


def wkv6_step(r, k, v, w, u, state):
    """Single-token recurrence. r,k,v,w: (B, H, K); state (B, H, K, K) f32."""
    rf, kf, vf, wf = (a.astype(F32) for a in (r, k, v, w))
    o = jnp.einsum("bhk,bhkv->bhv", rf, state) \
        + jnp.einsum("bhk,bhk->bh", rf, u.astype(F32)[None] * kf)[..., None] * vf
    state = wf[..., None] * state + jnp.einsum("bhk,bhv->bhkv", kf, vf)
    return o, state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _dyn_mix(p, x, dx):
    """5-way data-dependent token-shift mix -> dict of mixed inputs."""
    base = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", base, p["mix_A"],
                               preferred_element_type=F32))
    r5 = lora.reshape(*lora.shape[:-1], 5, -1)
    offs = jnp.einsum("btnr,nrd->nbtd", r5, p["mix_B"].astype(F32))
    out = {}
    for i, name in enumerate(MIX_NAMES):
        mix = p["mu"][i].astype(F32) + offs[i]
        out[name] = (x.astype(F32) + dx.astype(F32) * mix).astype(x.dtype)
    return out


def _decay(p, xw):
    """Data-dependent decay w in (0,1): exp(-exp(base + lora(xw))).

    The pre-decay exponent is clipped at +0.35 (=> w >= ~0.24, |log w| <= 1.42)
    so the chunked WKV form stays within f32 range; this is the same kind of
    clamp chunked GLA/RWKV production kernels apply.
    """
    lora = jnp.einsum("...d,dr->...r", jnp.tanh(
        jnp.einsum("...d,dr->...r", xw, p["decay_A"], preferred_element_type=F32)),
        p["decay_B"].astype(F32))
    return jnp.exp(-jnp.exp(jnp.clip(p["w_base"] + lora, -20.0, 0.35)))


def _group_norm(o, scale, H):
    """Per-head normalization of (..., H, K) flattened to (..., D)."""
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * lax.rsqrt(var + 1e-5)
    flat = o.reshape(*o.shape[:-2], -1)
    return flat * scale


def _probe_chunk(default: int) -> int:
    import os
    v = os.environ.get("REPRO_PROBE_CHUNK")
    return int(v) if v else default


def time_mix_full(cfg, p, x, shift_in, wkv_state, chunk=32):
    """Full-sequence time-mix. x (B,T,D). Returns (out, last_x, new_state)."""
    B, T, D = x.shape
    H, K = D // cfg.rwkv.head_size, cfg.rwkv.head_size
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    dx = x_prev - x
    m = _dyn_mix(p, x, dx)
    r = jnp.einsum("btd,de->bte", m["r"], p["wr"]).reshape(B, T, H, K)
    k = jnp.einsum("btd,de->bte", m["k"], p["wk"]).reshape(B, T, H, K)
    v = jnp.einsum("btd,de->bte", m["v"], p["wv"]).reshape(B, T, H, K)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", m["g"], p["wg"]).astype(F32))
    w = _decay(p, m["w"]).reshape(B, T, H, K)
    u = p["u"].reshape(H, K)
    o, new_state = wkv6_chunked(r, k, v, w, u, wkv_state,
                                chunk=min(_probe_chunk(chunk), T))
    o = _group_norm(o, p["ln_x_scale"], H) * g
    out = jnp.einsum("btd,de->bte", o.astype(x.dtype), p["wo"])
    return out, x[:, -1], new_state


def time_mix_step(cfg, p, x, shift_in, wkv_state):
    """Single-token time-mix. x (B, D)."""
    B, D = x.shape
    H, K = D // cfg.rwkv.head_size, cfg.rwkv.head_size
    out, last, state = time_mix_full(cfg, p, x[:, None], shift_in,
                                     wkv_state, chunk=1)
    return out[:, 0], last, state


def channel_mix(p, x, shift_in):
    """x (B,T,D) or (B,D) with matching shift_in (B,D)."""
    single = x.ndim == 2
    if single:
        x = x[:, None]
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["cm_mu_k"].astype(x.dtype)
    xr = x + dx * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["cm_wk"],
                                          preferred_element_type=F32)))
    kv = jnp.einsum("btf,fd->btd", k.astype(x.dtype), p["cm_wv"])
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["cm_wr"],
                                    preferred_element_type=F32)).astype(x.dtype) * kv
    last = x[:, -1]
    return (out[:, 0], last) if single else (out, last)


# ---------------------------------------------------------------------------
# model-level forward
# ---------------------------------------------------------------------------

def rwkv_forward(cfg: ModelConfig, params, tokens, *, pctx: Optional[ParallelCtx] = None,
                 state: Optional[RWKVState] = None, return_state: bool = False,
                 remat: bool = False):
    B, T = tokens.shape
    x = params["embed"][tokens]
    x = constrain(x, pctx, pctx.dp_spec if pctx else None, None, None)
    if state is None:
        state = RWKVState.zeros(cfg, B, x.dtype)

    def body(x, scanned):
        lp, att_s, ffn_s, wkv_s = scanned
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        att, att_last, wkv_new = time_mix_full(cfg, lp, h, att_s, wkv_s)
        x = x + att
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        ffn, ffn_last = channel_mix(lp, h2, ffn_s)
        x = x + ffn
        return x, (att_last, ffn_last, wkv_new)

    body_fn = jax.checkpoint(body) if remat else body
    x, (att_s, ffn_s, wkv_s) = _uscan(
        body_fn, x, (params["layers"], state.att_shift, state.ffn_shift, state.wkv))
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"],
                        preferred_element_type=F32)
    if return_state:
        return logits, RWKVState(att_s, ffn_s, wkv_s)
    return logits


def rwkv_prefill(cfg, params, tokens, *, pctx=None):
    logits, st = rwkv_forward(cfg, params, tokens, pctx=pctx, return_state=True)
    return logits[:, -1], st


def rwkv_decode(cfg, params, state: RWKVState, tokens, positions=None, *, pctx=None):
    """tokens (B,) -> (logits (B,V), new state). positions unused (stateful)."""
    logits, st = rwkv_forward(cfg, params, tokens[:, None], pctx=pctx,
                              state=state, return_state=True)
    return logits[:, -1], st
