"""Scan wrapper honoring REPRO_UNROLL_SCANS.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count, so scanned layer stacks under-report flops/bytes/collectives. The
roofline probe (``launch/roofline_probe.py``) sets REPRO_UNROLL_SCANS=1 and
compiles reduced-depth configs with every scan unrolled, then extrapolates
per-layer costs to full depth. Production/dry-run paths keep rolled scans
(small HLO, fast compiles).
"""
from __future__ import annotations

import os

from jax import lax


def scan(body, init, xs, length=None):
    unroll = os.environ.get("REPRO_UNROLL_SCANS") == "1"
    return lax.scan(body, init, xs, length=length, unroll=True if unroll else 1)
