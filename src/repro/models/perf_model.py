"""Analytic performance/footprint model.

Two consumers:
  * the discrete-event serving simulator (service time of prefill chunks /
    decode steps on a given hardware spec), and
  * the roofline analysis (MODEL_FLOPS = 6·N·D for train, 2·N_active·tokens
    for inference, KV footprints, ideal execution times for SLO targets).

All byte counts assume bf16 weights/KV unless stated.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s
    hbm_bytes: float
    link_bw: float             # bytes/s per ICI/NVLink link
    host_dev_bw: float = 25e9  # device<->host (KV swap path)
    mfu_prefill: float = 0.55  # achievable fraction of peak, compute-bound
    mbu_decode: float = 0.70   # achievable fraction of HBM bw, memory-bound


# host_dev_bw: effective KV swap bandwidth of the stock vLLM swapper (the
# baseline implementation the paper evaluates InferCept on): paged KV is
# offloaded as per-layer-per-block scattered copies (~32 KB each for a
# 16-token GQA block), thousands of small DMAs whose launch overhead caps
# effective bandwidth at a few GB/s — InferCept's own measurements of the
# stock swap path report low single-digit GB/s. We use 3 GB/s effective.
TPU_V5E = HardwareSpec("tpu-v5e", 197e12, 819e9, 16e9, 50e9, host_dev_bw=3e9)
H100 = HardwareSpec("h100-nvl", 989e12, 3.35e12, 96e9, 450e9, host_dev_bw=3e9)
H200 = HardwareSpec("h200-nvl", 989e12, 4.8e12, 144e9, 450e9, host_dev_bw=3e9)

HW = {"tpu-v5e": TPU_V5E, "h100": H100, "h200": H200}


# ---------------------------------------------------------------------------
# per-token costs
# ---------------------------------------------------------------------------

def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV-cache bytes appended per generated/prefilled token."""
    if cfg.family == "rwkv6":
        return 0               # constant state, no per-token growth
    if cfg.family == "zamba2":
        n_apps = max(1, cfg.n_layers // cfg.shared_attn_every)
        return n_apps * 2 * cfg.n_kv_heads * cfg.head_dim_ * dtype_bytes
    if cfg.family == "whisper":
        return cfg.n_layers * 2 * cfg.n_kv_heads * cfg.head_dim_ * dtype_bytes
    per_layer = 2 * cfg.n_kv_heads * cfg.head_dim_ * dtype_bytes
    if cfg.sliding_window is not None and "local" in cfg.layer_pattern:
        kinds = cfg.layer_kinds()
        n_global = sum(1 for k in kinds if k != "local")
        # local layers stop growing past the window; amortized ~global only
        # for long contexts. Report full-rate here; window capping is applied
        # by callers that know the context length (see kv_cache_bytes).
        return cfg.n_layers * per_layer
    return cfg.n_layers * per_layer


def state_bytes(cfg: ModelConfig) -> int:
    """Constant per-sequence state (SSM/RWKV) in bytes."""
    if cfg.family == "rwkv6":
        H = cfg.d_model // cfg.rwkv.head_size
        K = cfg.rwkv.head_size
        return cfg.n_layers * (2 * cfg.d_model * 2 + H * K * K * 4)
    if cfg.family == "zamba2":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        H = s.n_heads(cfg.d_model)
        conv = (di + 2 * s.d_state) * (s.d_conv - 1) * 2
        ssm = H * s.head_dim * s.d_state * 4
        return cfg.n_layers * (conv + ssm)
    return 0


def kv_cache_bytes(cfg: ModelConfig, context_len: int, dtype_bytes: int = 2) -> int:
    """Total suspended-state bytes for one sequence at ``context_len``."""
    base = state_bytes(cfg)
    if cfg.family == "rwkv6":
        return base
    per_layer_tok = 2 * cfg.n_kv_heads * cfg.head_dim_ * dtype_bytes
    if cfg.family == "zamba2":
        n_apps = max(1, cfg.n_layers // cfg.shared_attn_every)
        return base + n_apps * context_len * per_layer_tok
    if cfg.family == "whisper":
        dec = min(context_len, cfg.max_target_len)
        return cfg.n_layers * (dec + context_len) * per_layer_tok
    if cfg.sliding_window is not None and "local" in cfg.layer_pattern:
        kinds = cfg.layer_kinds()
        n_local = sum(1 for k in kinds if k == "local")
        n_global = cfg.n_layers - n_local
        local_len = min(context_len, cfg.sliding_window)
        return (n_local * local_len + n_global * context_len) * per_layer_tok
    return cfg.n_layers * context_len * per_layer_tok


def flops_per_token(cfg: ModelConfig, context_len: int = 0) -> float:
    """Forward FLOPs per token: 2·N_active + attention term."""
    n_active = cfg.param_count(active_only=True)
    f = 2.0 * n_active
    if cfg.family not in ("rwkv6",):
        # attention score+value FLOPs vs average context
        H, Dh = cfg.n_heads, cfg.head_dim_
        eff_layers = (max(1, cfg.n_layers // cfg.shared_attn_every)
                      if cfg.family == "zamba2" else cfg.n_layers)
        f += 4.0 * eff_layers * H * Dh * max(context_len, 1)
    return f


def train_flops(cfg: ModelConfig, tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE)."""
    return 6.0 * cfg.param_count(active_only=True) * tokens


# ---------------------------------------------------------------------------
# service-time model (simulator)
# ---------------------------------------------------------------------------

def prefill_time(cfg: ModelConfig, hw: HardwareSpec, n_tokens: int,
                 context_len: int = 0, tp: int = 1) -> float:
    """Seconds to prefill ``n_tokens`` against ``context_len`` history."""
    f = flops_per_token(cfg, context_len + n_tokens // 2) * n_tokens
    return f / (hw.peak_flops * tp * hw.mfu_prefill)


def decode_step_time(cfg: ModelConfig, hw: HardwareSpec, batch: int,
                     avg_context: int, tp: int = 1) -> float:
    """Seconds for one decode step of a ``batch`` of sequences.

    Memory-bound: weights are read once per step; KV is read per sequence.
    """
    w_bytes = 2.0 * cfg.param_count(active_only=True)
    kv = kv_cache_bytes(cfg, avg_context) * batch
    t_mem = (w_bytes + kv) / (hw.hbm_bw * tp * hw.mbu_decode)
    f = flops_per_token(cfg, avg_context) * batch
    t_flop = f / (hw.peak_flops * tp * hw.mfu_prefill)
    return max(t_mem, t_flop)


def swap_time(cfg: ModelConfig, hw: HardwareSpec, context_len: int) -> float:
    """One-way host<->device KV transfer time."""
    return kv_cache_bytes(cfg, context_len) / hw.host_dev_bw


def ideal_session_time(cfg: ModelConfig, hw: HardwareSpec, rounds, tp: int = 1) -> float:
    """Isolated (concurrency=1) execution time of a session.

    ``rounds``: iterable of (new_input_tokens, decode_tokens, tool_seconds).
    Matches the paper's T_ideal definition (vLLM, max concurrency 1).
    """
    t = 0.0
    ctx = 0
    for new_in, n_dec, tool_s in rounds:
        t += prefill_time(cfg, hw, new_in, ctx, tp)
        ctx += new_in
        # closed-form decode: batch-1 steps at the round's average context
        t += n_dec * decode_step_time(cfg, hw, 1, ctx + n_dec // 2, tp)
        ctx += n_dec
        t += tool_s
    return t
