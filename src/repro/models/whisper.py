"""Whisper-style encoder-decoder backbone (audio frontend is a STUB: the
conv feature extractor is replaced by precomputed frame embeddings supplied
via ``input_specs()``, per the assignment).

Encoder: bidirectional pre-LN transformer over frame embeddings + sinusoidal
positions. Decoder: causal self-attention (cached) + cross-attention over the
encoder output (cross-KV computed once at prefill), learned positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.scan_util import scan as _uscan
from repro.models.layers import (ParallelCtx, apply_norm, attention, attn_out,
                                 attn_qkv, constrain, init_attn, init_mlp,
                                 init_norm, mlp)

F32 = jnp.float32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EncDecCache:
    """self_k/self_v: (L, B, Tdec_max, H, Dh); cross_k/cross_v: (L, B, Tenc, H, Dh)."""
    self_k: jax.Array
    self_v: jax.Array
    cross_k: jax.Array
    cross_v: jax.Array

    def tree_flatten(self):
        return (self.self_k, self.self_v, self.cross_k, self.cross_v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zeros(cls, cfg: ModelConfig, batch: int, dec_len: int, enc_len: int,
              dtype=jnp.bfloat16):
        s = (cfg.n_layers, batch, dec_len, cfg.n_kv_heads, cfg.head_dim_)
        c = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim_)
        z = jnp.zeros
        return cls(z(s, dtype), z(s, dtype), z(c, dtype), z(c, dtype))

    @classmethod
    def specs(cls, cfg: ModelConfig, batch: int, dec_len: int, enc_len: int,
              dtype=jnp.bfloat16):
        s = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, dec_len, cfg.n_kv_heads, cfg.head_dim_), dtype)
        c = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim_), dtype)
        return cls(s, s, c, c)


def _sinusoid(length: int, dim: int):
    pos = jnp.arange(length, dtype=F32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, dtype=F32) / dim)
    pe = jnp.zeros((length, dim), F32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _init_enc_layer(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln_attn": init_norm(cfg, cfg.d_model, dtype),
            "attn": init_attn(cfg, k1, dtype),
            "ln_mlp": init_norm(cfg, cfg.d_model, dtype),
            "mlp": init_mlp(cfg, k2, dtype)}


def _init_dec_layer(cfg, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln_self": init_norm(cfg, cfg.d_model, dtype),
            "self_attn": init_attn(cfg, k1, dtype),
            "ln_cross": init_norm(cfg, cfg.d_model, dtype),
            "cross_attn": init_attn(cfg, k2, dtype),
            "ln_mlp": init_norm(cfg, cfg.d_model, dtype),
            "mlp": init_mlp(cfg, k3, dtype)}


def init_whisper(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ke, kd, kt, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    D = cfg.d_model
    return {
        "embed": jax.random.normal(kt, (cfg.vocab_size, D), dtype) * D ** -0.5,
        "dec_pos": jax.random.normal(kp, (cfg.max_target_len, D), dtype) * 0.01,
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k, dtype))(dec_keys),
        "ln_enc_final": init_norm(cfg, D, dtype),
        "ln_dec_final": init_norm(cfg, D, dtype),
    }


def encode(cfg: ModelConfig, params, frames, *, pctx: Optional[ParallelCtx] = None):
    """frames: (B, Tenc, D) precomputed frame embeddings (stub frontend)."""
    B, T, D = frames.shape
    x = frames + _sinusoid(T, D).astype(frames.dtype)[None]
    x = constrain(x, pctx, pctx.dp_spec if pctx else None, None, None)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(x, lp):
        h = apply_norm(cfg, lp["ln_attn"], x)
        q, k, v = attn_qkv(cfg, lp["attn"], h, positions, use_rope=False)
        x = x + attn_out(lp["attn"], attention(q, k, v, positions, positions,
                                               causal=False))
        x = x + mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln_mlp"], x), pctx)
        return x, None

    x, _ = _uscan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["ln_enc_final"], x)


def _dec_layer(cfg, lp, x, positions, self_kv, cross_kv, pos_write=None,
               kv_pos=None, kv_valid=None):
    """One decoder layer; self_kv/cross_kv are (k, v) tensors."""
    B = x.shape[0]
    h = apply_norm(cfg, lp["ln_self"], x)
    q, k_new, v_new = attn_qkv(cfg, lp["self_attn"], h, positions, use_rope=False)
    k_c, v_c = self_kv
    if pos_write is not None:                      # decode: single-token write
        b_idx = jnp.arange(B)
        k_c = k_c.at[b_idx, pos_write].set(k_new[:, 0])
        v_c = v_c.at[b_idx, pos_write].set(v_new[:, 0])
    else:
        k_c, v_c = k_new, v_new
    skv_pos = positions if kv_pos is None else kv_pos
    x = x + attn_out(lp["self_attn"], attention(
        q, k_c, v_c, positions, skv_pos, kv_valid=kv_valid, causal=True))
    h = apply_norm(cfg, lp["ln_cross"], x)
    qc = attn_qkv(cfg, lp["cross_attn"], h, positions, use_rope=False)[0]
    ck, cv = cross_kv
    enc_pos = jnp.broadcast_to(jnp.arange(ck.shape[1], dtype=jnp.int32),
                               (B, ck.shape[1]))
    x = x + attn_out(lp["cross_attn"], attention(
        qc, ck, cv, positions, enc_pos, causal=False))
    x = x + mlp(cfg, lp["mlp"], apply_norm(cfg, lp["ln_mlp"], x))
    return x, (k_c, v_c)


def _cross_kv(cfg, lp, enc_out):
    """Precompute cross K/V from encoder output for one layer."""
    B, T, _ = enc_out.shape
    k = jnp.einsum("btd,de->bte", enc_out, lp["cross_attn"]["wk"]
                   ).reshape(B, T, cfg.n_kv_heads, cfg.head_dim_)
    v = jnp.einsum("btd,de->bte", enc_out, lp["cross_attn"]["wv"]
                   ).reshape(B, T, cfg.n_kv_heads, cfg.head_dim_)
    return k, v


def whisper_forward(cfg: ModelConfig, params, dec_tokens, frames, *,
                    pctx: Optional[ParallelCtx] = None, return_cache: bool = False,
                    remat: bool = False):
    """Teacher-forced full forward: frames (B,Tenc,D), dec_tokens (B,Tdec)."""
    enc_out = encode(cfg, params, frames, pctx=pctx)
    B, Tdec = dec_tokens.shape
    x = params["embed"][dec_tokens] + params["dec_pos"][:Tdec][None]
    positions = jnp.broadcast_to(jnp.arange(Tdec, dtype=jnp.int32), (B, Tdec))

    def body(x, lp):
        ck, cv = _cross_kv(cfg, lp, enc_out)
        x, (k, v) = _dec_layer(cfg, lp, x, positions, (None, None), (ck, cv))
        return x, (k, v, ck, cv)

    body_fn = jax.checkpoint(body) if remat else body
    x, (ks, vs, cks, cvs) = _uscan(body_fn, x, params["dec_layers"])
    x = apply_norm(cfg, params["ln_dec_final"], x)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"],
                        preferred_element_type=F32)
    if return_cache:
        return logits, EncDecCache(ks, vs, cks, cvs)
    return logits


def whisper_prefill(cfg, params, dec_tokens, frames, *, pctx=None):
    logits, cache = whisper_forward(cfg, params, dec_tokens, frames, pctx=pctx,
                                    return_cache=True)
    return logits[:, -1], cache


def whisper_decode(cfg: ModelConfig, params, cache: EncDecCache, tokens, positions,
                   *, pctx: Optional[ParallelCtx] = None):
    """tokens (B,), positions (B,) -> (logits, cache). Cross-KV is static."""
    B = tokens.shape[0]
    Smax = cache.self_k.shape[2]
    pos_emb = params["dec_pos"][jnp.clip(positions, 0, cfg.max_target_len - 1)]
    x = params["embed"][tokens] + pos_emb
    x = x[:, None]
    kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    kv_valid = kv_pos <= positions[:, None]

    def body(x, scanned):
        lp, k_c, v_c, ck, cv = scanned
        x, (k_c, v_c) = _dec_layer(cfg, lp, x, positions[:, None],
                                   (k_c, v_c), (ck, cv), pos_write=positions,
                                   kv_pos=kv_pos, kv_valid=kv_valid)
        return x, (k_c, v_c)

    x, (ks, vs) = _uscan(body, x, (params["dec_layers"], cache.self_k,
                                     cache.self_v, cache.cross_k, cache.cross_v))
    x = apply_norm(cfg, params["ln_dec_final"], x[:, 0])
    logits = jnp.einsum("bd,vd->bv", x, params["embed"], preferred_element_type=F32)
    return logits, EncDecCache(ks, vs, cache.cross_k, cache.cross_v)
