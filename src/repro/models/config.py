"""Model configuration for all assigned architectures.

One ``ModelConfig`` describes any member of the supported families:
dense decoder LMs (optionally with sliding-window/global alternation and logit
softcaps), MoE decoder LMs, RWKV6, Mamba2/Zamba2 hybrids, and Whisper-style
encoder-decoders. ``src/repro/configs/<arch>.py`` instantiate the full-scale
configs; ``reduced()`` derives CPU-smoke-test variants of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # 'ep' shards experts over the data axis (all_to_all dispatch); 'tp' keeps
    # experts replicated over data and shards d_ff over the model axis.
    shard_mode: str = "ep"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    # 'dense' | 'moe' | 'rwkv6' | 'zamba2' | 'whisper' — selects the forward fn.
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # defaults to d_model // n_heads

    # --- attention options -------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None      # gemma2: 50.0
    final_logit_softcap: Optional[float] = None     # gemma2: 30.0
    sliding_window: Optional[int] = None            # window size for local layers
    # layer pattern, e.g. ('local', 'global'); repeated to cover n_layers.
    layer_pattern: Tuple[str, ...] = ("global",)
    post_sublayer_norm: bool = False                # gemma2 pre+post norms
    norm_type: str = "rmsnorm"                      # 'rmsnorm' | 'layernorm'
    act: str = "silu"                               # mlp activation ('silu'|'gelu')
    gated_mlp: bool = True
    tie_embeddings: bool = False
    embed_scale: bool = False                       # gemma-style sqrt(d) embed scale
    norm_eps: float = 1e-6

    # --- family-specific ----------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # zamba2: shared attention block applied every k mamba layers.
    shared_attn_every: int = 6
    # whisper: encoder depth (decoder uses n_layers); frontend is a stub that
    # consumes precomputed frame embeddings of length enc_len.
    n_enc_layers: int = 0
    max_target_len: int = 448

    # --- modality stubs ----------------------------------------------------
    # 'none' | 'audio_frames' | 'image_patches': input_specs() provides
    # precomputed embeddings for the stub frontend.
    frontend: str = "none"
    n_frontend_tokens: int = 0

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention kind, e.g. ('local','global','local',...)."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def is_subquadratic(self) -> bool:
        """True if the arch admits 500K-token decode (SSM / linear-attn /
        local+global hybrids where local layers bound most KV)."""
        if self.family in ("rwkv6", "zamba2"):
            return True
        return self.sliding_window is not None and "local" in self.layer_pattern

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4 if self.family != "zamba2" else 7),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            sliding_window=64 if self.sliding_window else None,
            n_enc_layers=2 if self.n_enc_layers else 0,
            shared_attn_every=3,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2), d_ff_expert=64)
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk_size=16)
        if self.rwkv is not None:
            small["rwkv"] = dataclasses.replace(
                self.rwkv, head_size=32, decay_lora=16, mix_lora=8)
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-smoke", **small)

    # --- analytic parameter count (for roofline MODEL_FLOPS) ----------------
    def param_count(self, active_only: bool = False) -> int:
        D, V, L = self.d_model, self.vocab_size, self.n_layers
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D
        if self.family == "rwkv6":
            assert self.rwkv is not None
            H = D // self.rwkv.head_size
            per = (5 * D * D            # r,k,v,g,o  (w is lora)
                   + 2 * D * self.rwkv.decay_lora
                   + 5 * 2 * D * self.rwkv.mix_lora
                   + 2 * H * self.rwkv.head_size
                   + 2 * D * self.d_ff + self.d_ff * 0)
            return n + L * per
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        mlp_dense = (3 if self.gated_mlp else 2) * D * self.d_ff
        if self.family == "moe":
            assert self.moe is not None
            e_all = self.moe.num_experts
            e_act = self.moe.top_k
            per_expert = (3 if self.gated_mlp else 2) * D * self.moe.d_ff_expert
            router = D * e_all
            per_layer_total = attn + router + e_all * per_expert
            per_layer_active = attn + router + e_act * per_expert
            return n + L * (per_layer_active if active_only else per_layer_total)
        if self.family == "zamba2":
            assert self.ssm is not None
            di = self.ssm.d_inner(D)
            H = self.ssm.n_heads(D)
            mamba = (D * (2 * di + 2 * self.ssm.d_state + H)  # in_proj(z,x,B,C,dt)
                     + di * self.ssm.d_conv + di * D)
            n_shared = max(1, L // self.shared_attn_every)
            shared = attn + mlp_dense
            return n + L * mamba + shared + 0 * n_shared
        if self.family == "whisper":
            enc = self.n_enc_layers * (attn + mlp_dense)
            dec = L * (2 * attn + mlp_dense)  # self + cross
            return n + enc + dec
        return n + L * (attn + mlp_dense)
