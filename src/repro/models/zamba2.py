"""Zamba2 hybrid: Mamba2 backbone + a *shared* transformer block applied every
``shared_attn_every`` layers. The shared block's weights are reused at each
application but each application keeps its own KV cache; its input is
``proj(concat(hidden, original_embedding))`` as in the Zamba papers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.scan_util import scan as _uscan
from repro.models import mamba2
from repro.models.layers import (ParallelCtx, apply_norm, attention, attn_out,
                                 attn_qkv, constrain, init_attn, init_mlp,
                                 init_norm, mlp, rms_norm)
from repro.models.transformer import _unembed

F32 = jnp.float32


def shared_positions(cfg: ModelConfig):
    """Mamba-layer indices after which the shared attention block runs."""
    return tuple(i for i in range(cfg.n_layers)
                 if (i + 1) % cfg.shared_attn_every == 0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ZambaCache:
    """mamba: MambaState with leading L axis; k/v: (n_apps, B, Smax, Hkv, Dh)."""
    mamba: mamba2.MambaState
    k: jax.Array
    v: jax.Array

    def tree_flatten(self):
        return (self.mamba, self.k, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zeros(cls, cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
        n_apps = len(shared_positions(cfg))
        shp = (n_apps, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        return cls(mamba2.state_zeros(cfg, cfg.n_layers, batch, dtype),
                   jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))

    @classmethod
    def specs(cls, cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
        n_apps = len(shared_positions(cfg))
        shp = (n_apps, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        sds = jax.ShapeDtypeStruct(shp, dtype)
        return cls(mamba2.state_specs(cfg, cfg.n_layers, batch, dtype), sds, sds)


def init_zamba(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_embed, k_layers, k_shared, k_head, k_proj = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    ks = jax.random.split(k_shared, 2)
    D = cfg.d_model
    shared = {
        "in_proj": jax.random.normal(k_proj, (2 * D, D), dtype) * (2 * D) ** -0.5,
        "ln_attn": init_norm(cfg, D, dtype),
        "attn": init_attn(cfg, ks[0], dtype),
        "ln_mlp": init_norm(cfg, D, dtype),
        "mlp": init_mlp(cfg, ks[1], dtype),
    }
    return {
        "embed": jax.random.normal(k_embed, (cfg.vocab_size, D), dtype) * D ** -0.5,
        "mamba_layers": jax.vmap(
            lambda k: mamba2.init_mamba_layer(cfg, k, dtype))(layer_keys),
        "shared": shared,
        "ln_final": jnp.zeros((D,), dtype),
        "lm_head": jax.random.normal(k_head, (D, cfg.vocab_size), dtype) * D ** -0.5,
    }


def _shared_block_full(cfg, sp, x, x0, positions, kv_pos=None, kv_valid=None,
                       k_cache=None, v_cache=None, pos_write=None):
    """Shared attn+MLP block over full sequence; returns (delta, k, v)."""
    h = jnp.concatenate([x, x0], axis=-1)
    h = jnp.einsum("bsd,de->bse", h, sp["in_proj"]).astype(x.dtype)
    a_in = apply_norm(cfg, sp["ln_attn"], h)
    q, k, v = attn_qkv(cfg, sp["attn"], a_in, positions)
    if k_cache is not None:                        # decode: write into cache
        b_idx = jnp.arange(x.shape[0])
        k_cache = k_cache.at[b_idx, pos_write].set(k[:, 0])
        v_cache = v_cache.at[b_idx, pos_write].set(v[:, 0])
        k, v = k_cache, v_cache
    o = attn_out(sp["attn"], attention(
        q, k, v, positions, positions if kv_pos is None else kv_pos,
        kv_valid=kv_valid, causal=True))
    h = h + o
    m_in = apply_norm(cfg, sp["ln_mlp"], h)
    h = h + mlp(cfg, sp["mlp"], m_in)
    return h, k, v


def zamba_forward(cfg: ModelConfig, params, tokens, *,
                  pctx: Optional[ParallelCtx] = None, cache: Optional[ZambaCache] = None,
                  return_cache: bool = False, remat: bool = False):
    """Full-sequence forward (train / prefill)."""
    B, T = tokens.shape
    x = params["embed"][tokens]
    x = constrain(x, pctx, pctx.dp_spec if pctx else None, None, None)
    x0 = x
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if cache is None:
        cache = ZambaCache.zeros(cfg, B, T, x.dtype)
    spos = shared_positions(cfg)
    segments = _segments(cfg, spos)
    new_conv, new_ssm, new_k, new_v = [], [], [], []
    start = 0
    for app_i, (lo, hi) in enumerate(segments):
        lp = jax.tree.map(lambda a: a[lo:hi], params["mamba_layers"])
        cs = cache.mamba.conv[lo:hi]
        ss = cache.mamba.ssm[lo:hi]

        def body(x, scanned):
            lpi, c, s = scanned
            h = rms_norm(x, lpi["ln"], cfg.norm_eps)
            out, (nc, ns) = mamba2.mamba_block_full(cfg, lpi, h, c, s)
            return x + out, (nc, ns)

        body_fn = jax.checkpoint(body) if remat else body
        x, (nc, ns) = _uscan(body_fn, x, (lp, cs, ss))
        new_conv.append(nc)
        new_ssm.append(ns)
        if app_i < len(spos):
            delta, k, v = _shared_block_full(cfg, params["shared"], x, x0,
                                             positions)
            x = x + delta
            new_k.append(k)
            new_v.append(v)
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    if return_cache:
        new_cache = ZambaCache(
            mamba2.MambaState(jnp.concatenate(new_conv), jnp.concatenate(new_ssm)),
            jnp.stack(new_k) if new_k else cache.k,
            jnp.stack(new_v) if new_v else cache.v)
        return logits, new_cache
    return logits


def zamba_prefill(cfg, params, tokens, *, pctx=None):
    logits, cache = zamba_forward(cfg, params, tokens, pctx=pctx, return_cache=True)
    return logits[:, -1], cache


def zamba_decode(cfg: ModelConfig, params, cache: ZambaCache, tokens, positions, *,
                 pctx: Optional[ParallelCtx] = None):
    """tokens (B,), positions (B,) -> (logits (B,V), cache)."""
    B = tokens.shape[0]
    Smax = cache.k.shape[2]
    x = params["embed"][tokens]                    # (B, D)
    x0 = x
    kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    kv_valid = kv_pos <= positions[:, None]
    spos = shared_positions(cfg)
    segments = _segments(cfg, spos)
    new_conv, new_ssm, new_k, new_v = [], [], [], []
    for app_i, (lo, hi) in enumerate(segments):
        lp = jax.tree.map(lambda a: a[lo:hi], params["mamba_layers"])
        cs = cache.mamba.conv[lo:hi]
        ss = cache.mamba.ssm[lo:hi]

        def body(x, scanned):
            lpi, c, s = scanned
            h = rms_norm(x, lpi["ln"], cfg.norm_eps)
            out, (nc, ns) = mamba2.mamba_block_step(cfg, lpi, h, c, s)
            return x + out, (nc, ns)

        x, (nc, ns) = _uscan(body, x, (lp, cs, ss))
        new_conv.append(nc)
        new_ssm.append(ns)
        if app_i < len(spos):
            delta, k, v = _shared_block_full(
                cfg, params["shared"], x[:, None], x0[:, None], positions[:, None],
                kv_pos=kv_pos, kv_valid=kv_valid,
                k_cache=cache.k[app_i], v_cache=cache.v[app_i],
                pos_write=positions)
            x = x + delta[:, 0]
            new_k.append(k)
            new_v.append(v)
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    new_cache = ZambaCache(
        mamba2.MambaState(jnp.concatenate(new_conv), jnp.concatenate(new_ssm)),
        jnp.stack(new_k) if new_k else cache.k,
        jnp.stack(new_v) if new_v else cache.v)
    return logits, new_cache


def _segments(cfg: ModelConfig, spos) -> Tuple[Tuple[int, int], ...]:
    """Contiguous mamba-layer ranges split at shared-block positions."""
    segs = []
    start = 0
    for p in spos:
        segs.append((start, p + 1))
        start = p + 1
    if start < cfg.n_layers:
        segs.append((start, cfg.n_layers))
    return tuple(segs)
