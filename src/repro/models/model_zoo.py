"""Family dispatcher: one uniform API over all 10 assigned architectures.

    init(cfg, key, dtype)                      -> params
    forward(cfg, params, batch, pctx)          -> logits   (full sequence)
    prefill(cfg, params, batch, pctx)          -> (last_logits, cache)
    decode(cfg, params, cache, tok, pos, pctx) -> (logits, cache)
    cache_specs(cfg, batch, max_len)           -> ShapeDtypeStruct pytree

``batch`` is a dict: {'tokens': (B,S)} plus optional 'embeds' (VLM patch
embeddings), 'frames' (audio frame embeddings).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import rwkv6, transformer, whisper, zamba2
from repro.models.layers import ParallelCtx


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe"):
        return transformer.init_lm(cfg, key, dtype)
    if cfg.family == "rwkv6":
        return rwkv6.init_rwkv(cfg, key, dtype)
    if cfg.family == "zamba2":
        return zamba2.init_zamba(cfg, key, dtype)
    if cfg.family == "whisper":
        return whisper.init_whisper(cfg, key, dtype)
    raise ValueError(cfg.family)


def forward(cfg: ModelConfig, params, batch: Dict[str, Any], *,
            pctx: Optional[ParallelCtx] = None, remat: bool = False):
    if cfg.family in ("dense", "moe"):
        return transformer.lm_forward(cfg, params, batch["tokens"], pctx=pctx,
                                      embeds=batch.get("embeds"), remat=remat)
    if cfg.family == "rwkv6":
        return rwkv6.rwkv_forward(cfg, params, batch["tokens"], pctx=pctx,
                                  remat=remat)
    if cfg.family == "zamba2":
        return zamba2.zamba_forward(cfg, params, batch["tokens"], pctx=pctx,
                                    remat=remat)
    if cfg.family == "whisper":
        return whisper.whisper_forward(cfg, params, batch["tokens"],
                                       batch["frames"], pctx=pctx, remat=remat)
    raise ValueError(cfg.family)


def prefill(cfg: ModelConfig, params, batch: Dict[str, Any], *,
            pctx: Optional[ParallelCtx] = None):
    if cfg.family in ("dense", "moe"):
        return transformer.lm_prefill(cfg, params, batch["tokens"], pctx=pctx,
                                      embeds=batch.get("embeds"))
    if cfg.family == "rwkv6":
        return rwkv6.rwkv_prefill(cfg, params, batch["tokens"], pctx=pctx)
    if cfg.family == "zamba2":
        return zamba2.zamba_prefill(cfg, params, batch["tokens"], pctx=pctx)
    if cfg.family == "whisper":
        return whisper.whisper_prefill(cfg, params, batch["tokens"],
                                       batch["frames"], pctx=pctx)
    raise ValueError(cfg.family)


def decode(cfg: ModelConfig, params, cache, tokens, positions, *,
           pctx: Optional[ParallelCtx] = None):
    if cfg.family in ("dense", "moe"):
        return transformer.lm_decode(cfg, params, cache, tokens, positions, pctx=pctx)
    if cfg.family == "rwkv6":
        return rwkv6.rwkv_decode(cfg, params, cache, tokens, positions, pctx=pctx)
    if cfg.family == "zamba2":
        return zamba2.zamba_decode(cfg, params, cache, tokens, positions, pctx=pctx)
    if cfg.family == "whisper":
        return whisper.whisper_decode(cfg, params, cache, tokens, positions, pctx=pctx)
    raise ValueError(cfg.family)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                enc_len: int = 0):
    if cfg.family in ("dense", "moe"):
        return transformer.KVCache.specs(cfg, batch, max_len, dtype)
    if cfg.family == "rwkv6":
        return rwkv6.RWKVState.specs(cfg, batch, dtype)
    if cfg.family == "zamba2":
        return zamba2.ZambaCache.specs(cfg, batch, max_len, dtype)
    if cfg.family == "whisper":
        return whisper.EncDecCache.specs(cfg, batch, max_len,
                                         enc_len or cfg.n_frontend_tokens, dtype)
    raise ValueError(cfg.family)


def cache_zeros(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                enc_len: int = 0):
    specs = cache_specs(cfg, batch, max_len, dtype, enc_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
