"""Dense / MoE decoder-only LM (covers gemma2, internlm2, qwen2.5, llama3.2,
llava backbone, dbrx, granite-moe).

Layer stacks are scanned (``lax.scan``) so HLO size is O(1) in depth — this is
what keeps 512-device dry-run compiles tractable. Alternating local/global
attention (gemma2) is handled by a per-layer ``is_local`` scalar carried as a
scan input; logit softcaps and pre+post sublayer norms are config-driven.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.scan_util import scan as _uscan
from repro.models import layers as L
from repro.models.layers import (ParallelCtx, apply_norm, attention, attn_out,
                                 attn_qkv, constrain, init_attn, init_mlp,
                                 init_moe, init_norm, mha, mlp, moe_ffn,
                                 moe_ffn_ep_local, paged_decode_attention,
                                 paged_prefill_attention)

F32 = jnp.float32


def _shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` landed (with ``check_vma``) in newer JAX; older
    releases only ship ``jax.experimental.shard_map.shard_map`` (with the
    equivalent ``check_rep`` flag)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """Dense per-layer KV cache: k/v (L, B, Smax, Hkv, Dh)."""
    k: jax.Array
    v: jax.Array

    def tree_flatten(self):
        return (self.k, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zeros(cls, cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
        shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        return cls(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))

    @classmethod
    def specs(cls, cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
        shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        sds = jax.ShapeDtypeStruct(shp, dtype)
        return cls(sds, sds)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": init_norm(cfg, cfg.d_model, dtype),
        "attn": init_attn(cfg, ks[0], dtype),
        "ln_mlp": init_norm(cfg, cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(cfg, ks[1], dtype)
    else:
        p["mlp"] = init_mlp(cfg, ks[1], dtype)
    if cfg.post_sublayer_norm:
        p["ln_post_attn"] = init_norm(cfg, cfg.d_model, dtype)
        p["ln_post_mlp"] = init_norm(cfg, cfg.d_model, dtype)
    return p


def init_lm(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    params = {
        "embed": jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), dtype)
                 * cfg.d_model ** -0.5,
        "layers": stacked,
        "ln_final": init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), dtype) * cfg.d_model ** -0.5
    return params


def layer_kind_flags(cfg: ModelConfig) -> jax.Array:
    """(L,) float32: 1.0 where the layer uses local (sliding-window) attention."""
    return jnp.array([1.0 if k == "local" else 0.0 for k in cfg.layer_kinds()], F32)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _moe_block(cfg: ModelConfig, lp, h, pctx: Optional[ParallelCtx]):
    if pctx is not None and pctx.ep_axis is not None and pctx.mesh is not None:
        m = cfg.moe
        dp = pctx.dp_spec
        ep, tp = pctx.ep_axis, pctx.tp_axis
        wspec = {"router": P(), "w_gate": P(ep, None, tp), "w_up": P(ep, None, tp),
                 "w_down": P(ep, tp, None)}
        fn = _shard_map(
            partial(moe_ffn_ep_local, cfg, ep_axis=ep, tp_axis=tp),
            mesh=pctx.mesh, in_specs=(wspec, P(dp, None, None)),
            out_specs=P(dp, None, None))
        return fn(lp["moe"], h)
    import os
    token_shard = "moe_replicated" in os.environ.get("REPRO_OPT", "")
    return moe_ffn(cfg, lp["moe"], h, pctx, token_shard=token_shard)


def _layer_full(cfg: ModelConfig, x, lp, is_local, positions, pctx):
    """Full-sequence layer (train / prefill). Returns (x, (k, v))."""
    h = apply_norm(cfg, lp["ln_attn"], x)
    q, k, v = attn_qkv(cfg, lp["attn"], h, positions)
    o = attention(q, k, v, positions, positions, causal=True,
                  window=cfg.sliding_window, is_local=is_local,
                  softcap=cfg.attn_logit_softcap)
    o = attn_out(lp["attn"], o)
    if cfg.post_sublayer_norm:
        o = apply_norm(cfg, lp["ln_post_attn"], o)
    x = x + o
    x = constrain(x, pctx, pctx.dp_spec if pctx else None, None, None)
    h2 = apply_norm(cfg, lp["ln_mlp"], x)
    if cfg.family == "moe":
        f = _moe_block(cfg, lp, h2, pctx)
    else:
        f = mlp(cfg, lp["mlp"], h2, pctx)
    if cfg.post_sublayer_norm:
        f = apply_norm(cfg, lp["ln_post_mlp"], f)
    x = x + f
    x = constrain(x, pctx, pctx.dp_spec if pctx else None, None, None)
    return x, (k, v)


def _embed(cfg: ModelConfig, params, tokens, embeds=None):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if embeds is not None:   # modality-stub tokens are prepended
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def _unembed(cfg: ModelConfig, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, w, preferred_element_type=F32)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ---------------------------------------------------------------------------
# forward: full sequence (train / prefill)
# ---------------------------------------------------------------------------

def lm_forward(cfg: ModelConfig, params, tokens, *, pctx: Optional[ParallelCtx] = None,
               embeds=None, positions=None, return_cache: bool = False,
               remat: bool = False, return_hidden: bool = False):
    """tokens (B, S) -> logits (B, S_total, V); optionally per-layer KV."""
    x = _embed(cfg, params, tokens, embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, pctx, pctx.dp_spec if pctx else None, None, None)
    kinds = layer_kind_flags(cfg)
    q_pos = positions

    def body(x, scanned):
        lp, is_local = scanned
        return _layer_full(cfg, x, lp, is_local, positions, pctx)

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, (ks, vs) = _uscan(body_fn, x, (params["layers"], kinds))
    x = apply_norm(cfg, params["ln_final"], x)
    if return_hidden:
        return x
    logits = _unembed(cfg, params, x)
    logits = constrain(logits, pctx, pctx.dp_spec if pctx else None, None,
                       pctx.tp_axis if pctx else None)
    if return_cache:
        return logits, KVCache(ks, vs)
    return logits


def lm_prefill(cfg: ModelConfig, params, tokens, *, pctx=None, embeds=None,
               positions=None):
    logits, cache = lm_forward(cfg, params, tokens, pctx=pctx, embeds=embeds,
                               positions=positions, return_cache=True)
    return logits[:, -1], cache


# ---------------------------------------------------------------------------
# forward: incremental step against a dense KV cache
#   C == 1      -> decode
#   C == chunk  -> chunked prefill (attends to previously cached prefix)
# ---------------------------------------------------------------------------

def lm_step(cfg: ModelConfig, params, cache: KVCache, tokens, positions, *,
            pctx: Optional[ParallelCtx] = None):
    """tokens (B, C) int32; positions (B, C) int32 (cache indices to write).

    Returns (logits (B, C, V), updated cache). Every layer writes its new KV
    at ``positions`` then attends over the full valid prefix (+ sliding
    window on local layers).
    """
    B, C = tokens.shape
    Smax = cache.k.shape[2]
    x = _embed(cfg, params, tokens)                   # (B, C, D)
    x = constrain(x, pctx, _decode_dp(pctx, B), None, None)
    kinds = layer_kind_flags(cfg)
    kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    kv_valid = kv_pos <= jnp.max(positions, axis=1, keepdims=True)
    q_pos = positions
    b_idx = jnp.arange(B)[:, None]

    def body(x, scanned):
        lp, is_local, k_l, v_l = scanned
        h = apply_norm(cfg, lp["ln_attn"], x)
        q, k_new, v_new = attn_qkv(cfg, lp["attn"], h, q_pos)
        k_l = k_l.at[b_idx, positions].set(k_new)
        v_l = v_l.at[b_idx, positions].set(v_new)
        o = attention(q, k_l, v_l, q_pos, kv_pos, kv_valid=kv_valid,
                      causal=True, window=cfg.sliding_window,
                      is_local=is_local, softcap=cfg.attn_logit_softcap)
        o = attn_out(lp["attn"], o)
        if cfg.post_sublayer_norm:
            o = apply_norm(cfg, lp["ln_post_attn"], o)
        x = x + o
        h2 = apply_norm(cfg, lp["ln_mlp"], x)
        if cfg.family == "moe":
            f = _moe_block(cfg, lp, h2, pctx)
        else:
            f = mlp(cfg, lp["mlp"], h2, pctx)
        if cfg.post_sublayer_norm:
            f = apply_norm(cfg, lp["ln_post_mlp"], f)
        x = x + f
        return x, (k_l, v_l)

    x, (ks, vs) = _uscan(body, x, (params["layers"], kinds, cache.k, cache.v))
    x = apply_norm(cfg, params["ln_final"], x)
    logits = _unembed(cfg, params, x)
    return logits, KVCache(ks, vs)


def lm_decode(cfg: ModelConfig, params, cache: KVCache, tokens, positions, *,
              pctx: Optional[ParallelCtx] = None):
    """tokens (B,), positions (B,) -> (logits (B, V), updated cache)."""
    logits, cache = lm_step(cfg, params, cache, tokens[:, None],
                            positions[:, None], pctx=pctx)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# paged KV: physical page-pool layout driven by BlockPool block tables.
#
# The cache is a *global* pool of P pages of `page` tokens each, shared by
# every session on the device: a sequence's KV lives wherever its block
# table points, so two sessions prefix-sharing a repository context read the
# SAME physical pages (the live analogue of the kvcache radix accounting).
# Page id P-1 by convention is scratch: padded prefill lanes and idle decode
# lanes park their writes there.
# ---------------------------------------------------------------------------

def supports_paged(cfg: ModelConfig) -> bool:
    """The paged decode path covers plain causal GQA; sliding-window
    alternation and logit softcaps (gemma2) stay on the dense layout."""
    return (cfg.family in ("dense", "moe") and cfg.sliding_window is None
            and cfg.attn_logit_softcap is None)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """Paged per-layer KV pool: k/v (L, P, page, Hkv, Dh)."""
    k: jax.Array
    v: jax.Array

    def tree_flatten(self):
        return (self.k, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def page_size(self) -> int:
        return self.k.shape[2]

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]

    @classmethod
    def zeros(cls, cfg: ModelConfig, n_pages: int, page: int,
              dtype=jnp.bfloat16):
        shp = (cfg.n_layers, n_pages, page, cfg.n_kv_heads, cfg.head_dim_)
        return cls(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))


def lm_decode_paged(cfg: ModelConfig, params, cache: PagedKVCache, tokens,
                    positions, block_tables, lengths, write_pages,
                    write_offsets, *, pctx: Optional[ParallelCtx] = None):
    """One decode step against the global page pool.

    tokens/positions: (B,) int32 (absolute positions for RoPE);
    block_tables: (B, max_pages) int32 device page ids in token order;
    lengths: (B,) valid kv tokens AFTER this step's write (pos + 1);
    write_pages/write_offsets: (B,) — the page/slot each lane's new KV
    lands in (idle lanes point at the scratch page). The slot must be the
    table position of sequence index ``lengths - 1`` (the fused kernel's
    write/read contract; idle lanes satisfy it degenerately with length 1
    and an all-scratch table row).
    Returns (logits (B, V), updated cache).
    """
    assert supports_paged(cfg), "paged decode: unsupported attention variant"
    B = tokens.shape[0]
    x = _embed(cfg, params, tokens[:, None])          # (B, 1, D)
    x = constrain(x, pctx, _decode_dp(pctx, B), None, None)
    q_pos = positions[:, None]

    def body(x, scanned):
        lp, k_l, v_l = scanned                        # k/v_l: (P, page, H, D)
        h = apply_norm(cfg, lp["ln_attn"], x)
        q, k_new, v_new = attn_qkv(cfg, lp["attn"], h, q_pos)
        # KV write fused into the attention dispatch (kernel prologue on
        # the Pallas path; scatter-then-attend on the jnp oracle path —
        # bitwise the old separate-scatter math)
        o, k_l, v_l = paged_decode_attention(
            q[:, 0], k_l, v_l, block_tables, lengths,
            k_new=k_new[:, 0], v_new=v_new[:, 0],
            write_pages=write_pages, write_offsets=write_offsets)
        o = attn_out(lp["attn"], o[:, None])
        if cfg.post_sublayer_norm:
            o = apply_norm(cfg, lp["ln_post_attn"], o)
        x = x + o
        h2 = apply_norm(cfg, lp["ln_mlp"], x)
        if cfg.family == "moe":
            f = _moe_block(cfg, lp, h2, pctx)
        else:
            f = mlp(cfg, lp["mlp"], h2, pctx)
        if cfg.post_sublayer_norm:
            f = apply_norm(cfg, lp["ln_post_mlp"], f)
        x = x + f
        return x, (k_l, v_l)

    x, (ks, vs) = _uscan(body, x, (params["layers"], cache.k, cache.v))
    x = apply_norm(cfg, params["ln_final"], x)
    logits = _unembed(cfg, params, x[:, 0])
    return logits, PagedKVCache(ks, vs)


def lm_prefill_paged(cfg: ModelConfig, params, cache: PagedKVCache, tokens,
                     positions, table, write_pages, write_offsets, kv_len, *,
                     pctx: Optional[ParallelCtx] = None):
    """Chunked prefill of ONE sequence against the page pool, gather-free.

    tokens/positions: (1, C) — absolute positions; padded lanes sit at
    ``Np*page - 1`` (which the table maps to the scratch page). table:
    (Np,) page ids covering the sequence's lease in token order,
    scratch-padded, with the LAST entry always scratch.
    write_pages/write_offsets: (C,) destination of each chunk token's KV
    (padded lanes: the scratch page). kv_len: () int32 — valid kv tokens
    after this chunk (chunk start + real chunk tokens), traced so chunk
    starts never recompile.

    Per layer the chunk's KV is scattered into its leased pages FIRST, then
    ``paged_prefill_attention`` reads every page **in place** via the
    scalar-prefetched table — the read side never materializes a dense
    ``pages[table]`` view (the O(context)-bytes-per-chunk copy the legacy
    ``lm_prefill_paged_gather`` pays). Exact semantics: queries at absolute
    positions, causal over the previously cached (possibly *shared*) prefix
    + the chunk itself, stale/scratch slots masked by ``kv_len``.
    Returns (logits (1, C, V), cache).
    """
    assert supports_paged(cfg), "paged prefill: unsupported attention variant"
    x = _embed(cfg, params, tokens)                   # (1, C, D)
    x = constrain(x, pctx, _decode_dp(pctx, 1), None, None)
    q_pos = positions
    q_offset = positions[:, 0]                        # (1,) chunk start
    kv_len_b = jnp.reshape(kv_len, (1,)).astype(jnp.int32)
    table_b = table[None]                             # (1, Np)

    def body(x, scanned):
        lp, k_l, v_l = scanned                        # k/v_l: (P, page, H, D)
        h = apply_norm(cfg, lp["ln_attn"], x)
        q, k_new, v_new = attn_qkv(cfg, lp["attn"], h, q_pos)
        k_l = k_l.at[write_pages, write_offsets].set(k_new[0])
        v_l = v_l.at[write_pages, write_offsets].set(v_new[0])
        o = paged_prefill_attention(q, k_l, v_l, table_b, kv_len_b, q_offset)
        o = attn_out(lp["attn"], o)
        if cfg.post_sublayer_norm:
            o = apply_norm(cfg, lp["ln_post_attn"], o)
        x = x + o
        h2 = apply_norm(cfg, lp["ln_mlp"], x)
        if cfg.family == "moe":
            f = _moe_block(cfg, lp, h2, pctx)
        else:
            f = mlp(cfg, lp["mlp"], h2, pctx)
        if cfg.post_sublayer_norm:
            f = apply_norm(cfg, lp["ln_post_mlp"], f)
        x = x + f
        return x, (k_l, v_l)

    x, (ks, vs) = _uscan(body, x, (params["layers"], cache.k, cache.v))
    x = apply_norm(cfg, params["ln_final"], x)
    logits = _unembed(cfg, params, x)
    return logits, PagedKVCache(ks, vs)


def lm_mixed_paged(cfg: ModelConfig, params, cache: PagedKVCache,
                   p_tokens, p_positions, p_tables, p_write_pages,
                   p_write_offsets, p_kv_lens, p_last_idx,
                   d_tokens, d_positions, d_tables, d_lengths,
                   d_write_pages, d_write_offsets, *,
                   pctx: Optional[ParallelCtx] = None):
    """ONE mixed continuous-batching iteration against the page pool:
    ``P`` chunked-prefill packs (scanned, cache as carry) followed by ``B``
    one-token decode lanes, fused into a single traced computation so the
    engine's mixed tick costs one dispatch.

    Prefill pack arrays carry a leading ``P`` axis over the per-pack
    ``lm_prefill_paged`` arguments: p_tokens (P, 1, C), p_positions
    (P, 1, C), p_tables (P, Np), p_write_pages/p_write_offsets (P, C),
    p_kv_lens (P,), p_last_idx (P,) — the index of each pack's last real
    chunk token, whose greedy argmax seeds the session's decoding. Decode
    arrays are ``lm_decode_paged``'s, batch-first: d_tokens/d_positions/
    d_lengths/d_write_pages/d_write_offsets (B,), d_tables (B, max_pages).
    ``P == 0`` / ``B == 0`` skip their stage *statically* (shape-driven:
    a different bucket recompiles, which the power-of-two bucketing
    bounds).

    Ordering within the fused iteration is safe by the pool's write-
    exclusivity: a pack scatters KV only into its session's exclusively
    owned pages (freshly leased or CoW'd), so prefill writes can never
    alias a decode lane's readable prefix — the scan-then-decode order is
    an implementation choice, not a correctness requirement.

    Returns (p_next (P,) int32, d_next (B,) int32, cache).
    """
    assert supports_paged(cfg), "mixed paged: unsupported attention variant"
    P = p_tokens.shape[0]
    B = d_tokens.shape[0]

    def pack_body(carry, inp):
        toks, pos, table, wpid, woff, kv_len, last = inp
        logits, carry = lm_prefill_paged(cfg, params, carry, toks, pos,
                                         table, wpid, woff, kv_len,
                                         pctx=pctx)
        nxt = jnp.argmax(logits[0, last], axis=-1).astype(jnp.int32)
        return carry, nxt

    if P > 0:
        cache, p_next = lax.scan(
            pack_body, cache,
            (p_tokens, p_positions, p_tables, p_write_pages,
             p_write_offsets, p_kv_lens, p_last_idx))
    else:
        p_next = jnp.zeros((0,), jnp.int32)
    if B > 0:
        logits, cache = lm_decode_paged(cfg, params, cache, d_tokens,
                                        d_positions, d_tables, d_lengths,
                                        d_write_pages, d_write_offsets,
                                        pctx=pctx)
        d_next = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        d_next = jnp.zeros((0,), jnp.int32)
    return p_next, d_next, cache


def lm_prefill_paged_gather(cfg: ModelConfig, params, cache: PagedKVCache,
                            tokens, positions, table, write_pages,
                            write_offsets, *,
                            pctx: Optional[ParallelCtx] = None):
    """Legacy chunked prefill: gather the lease into a dense view, run the
    dense ``lm_step`` on it, scatter the chunk's KV back.

    Exact but O(context) HBM bytes per chunk (gather read + dense-copy
    write + kernel read). Kept as the bit-exactness baseline for
    ``lm_prefill_paged`` (tests) and the accounting baseline for the
    ``prefill_hbm_bytes_per_chunk`` bench figure. Same argument layout as
    ``lm_prefill_paged`` minus ``kv_len``.
    """
    assert supports_paged(cfg), "paged prefill: unsupported attention variant"
    page = cache.page_size
    C = tokens.shape[1]
    ks = cache.k[:, table]                            # (L, Np, page, H, D)
    vs = cache.v[:, table]
    L_, Np = ks.shape[0], ks.shape[1]
    dense = KVCache(ks.reshape(L_, 1, Np * page, *ks.shape[3:]),
                    vs.reshape(L_, 1, Np * page, *vs.shape[3:]))
    logits, sub = lm_step(cfg, params, dense, tokens, positions, pctx=pctx)
    start = positions[0, 0]
    k_chunk = jax.lax.dynamic_slice_in_dim(sub.k, start, C, axis=2)[:, 0]
    v_chunk = jax.lax.dynamic_slice_in_dim(sub.v, start, C, axis=2)[:, 0]
    k = cache.k.at[:, write_pages, write_offsets].set(k_chunk)
    v = cache.v.at[:, write_pages, write_offsets].set(v_chunk)
    return logits, PagedKVCache(k, v)


# ---------------------------------------------------------------------------
# windowed decode (perf iteration, EXPERIMENTS.md §Perf): local (sliding-
# window) layers keep a ring buffer of `window` KV slots instead of the full
# sequence — for gemma2-style local/global alternation this halves the KV
# footprint and HBM traffic of long-context decode exactly.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WindowedKVCache:
    """k/v_loc: (Lp, B, W, Hkv, Dh) ring buffers (local layers);
    k/v_glob: (Lp, B, Smax, Hkv, Dh). Pattern period must be 2
    ('local','global')."""
    k_loc: jax.Array
    v_loc: jax.Array
    k_glob: jax.Array
    v_glob: jax.Array

    def tree_flatten(self):
        return (self.k_loc, self.v_loc, self.k_glob, self.v_glob), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def specs(cls, cfg: ModelConfig, batch: int, max_len: int,
              dtype=jnp.bfloat16):
        assert cfg.layer_pattern == ("local", "global")
        Lp = cfg.n_layers // 2
        loc = jax.ShapeDtypeStruct(
            (Lp, batch, cfg.sliding_window, cfg.n_kv_heads, cfg.head_dim_),
            dtype)
        glob = jax.ShapeDtypeStruct(
            (Lp, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), dtype)
        return cls(loc, loc, glob, glob)


def lm_decode_windowed(cfg: ModelConfig, params, cache: WindowedKVCache,
                       tokens, positions, *,
                       pctx: Optional[ParallelCtx] = None):
    """Single-token decode with ring-buffered local layers. Exact semantics:
    slot i of the ring holds the most recent position p <= pos with
    p ≡ i (mod W), which is precisely the sliding-window attention set."""
    assert cfg.layer_pattern == ("local", "global")
    B = tokens.shape[0]
    W = cfg.sliding_window
    Smax = cache.k_glob.shape[2]
    x = _embed(cfg, params, tokens[:, None])
    b_idx = jnp.arange(B)
    q_pos = positions[:, None]
    # ring-buffer positions per slot
    slot = jnp.arange(W, dtype=jnp.int32)
    ring_pos = positions[:, None] - ((positions[:, None] - slot[None]) % W)
    ring_valid = ring_pos >= 0
    kv_pos_g = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    kv_valid_g = kv_pos_g <= positions[:, None]
    pair_params = jax.tree.map(
        lambda a: a.reshape((cfg.n_layers // 2, 2) + a.shape[1:]),
        params["layers"])

    def sublayer(x, lp, k_l, v_l, kv_pos, kv_valid, write_pos):
        h = apply_norm(cfg, lp["ln_attn"], x)
        q, k_new, v_new = attn_qkv(cfg, lp["attn"], h, q_pos)
        k_l = k_l.at[b_idx, write_pos].set(k_new[:, 0])
        v_l = v_l.at[b_idx, write_pos].set(v_new[:, 0])
        o = attention(q, k_l, v_l, q_pos, kv_pos, kv_valid=kv_valid,
                      causal=True, softcap=cfg.attn_logit_softcap)
        o = attn_out(lp["attn"], o)
        if cfg.post_sublayer_norm:
            o = apply_norm(cfg, lp["ln_post_attn"], o)
        x = x + o
        h2 = apply_norm(cfg, lp["ln_mlp"], x)
        f = mlp(cfg, lp["mlp"], h2, pctx)
        if cfg.post_sublayer_norm:
            f = apply_norm(cfg, lp["ln_post_mlp"], f)
        return x + f, k_l, v_l

    def body(x, scanned):
        lp_pair, kl, vl, kg, vg = scanned
        lp0 = jax.tree.map(lambda a: a[0], lp_pair)
        lp1 = jax.tree.map(lambda a: a[1], lp_pair)
        x, kl, vl = sublayer(x, lp0, kl, vl, ring_pos, ring_valid,
                             positions % W)
        x, kg, vg = sublayer(x, lp1, kg, vg, kv_pos_g, kv_valid_g, positions)
        return x, (kl, vl, kg, vg)

    x, (kl, vl, kg, vg) = _uscan(
        body, x, (pair_params, cache.k_loc, cache.v_loc,
                  cache.k_glob, cache.v_glob))
    x = apply_norm(cfg, params["ln_final"], x)
    logits = _unembed(cfg, params, x[:, 0])
    return logits, WindowedKVCache(kl, vl, kg, vg)


def _decode_dp(pctx: Optional[ParallelCtx], batch: int):
    if pctx is None:
        return None
    return pctx.dp_spec
