"""Mamba2 (SSD) block — chunked state-space duality formulation.

Recurrence (per head h, head dim P, state dim N):
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * x_t (outer) B_t
    y_t = S_t @ C_t + D_h * x_t
Train/prefill run the chunked form (block matmuls + scan over chunks);
decode runs the single-step recurrence on carried (conv, ssm) state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.scan_util import scan as _uscan

F32 = jnp.float32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MambaState:
    """conv: (..., B, conv_ch, d_conv-1); ssm: (..., B, H, P, N) f32."""
    conv: jax.Array
    ssm: jax.Array

    def tree_flatten(self):
        return (self.conv, self.ssm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def conv_channels(cfg: ModelConfig) -> int:
    di = cfg.ssm.d_inner(cfg.d_model)
    return di + 2 * cfg.ssm.d_state


def state_zeros(cfg: ModelConfig, n_layers: int, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H, P, N = s.n_heads(cfg.d_model), s.head_dim, s.d_state
    return MambaState(
        jnp.zeros((n_layers, batch, conv_channels(cfg), s.d_conv - 1), dtype),
        jnp.zeros((n_layers, batch, H, P, N), F32))


def state_specs(cfg: ModelConfig, n_layers: int, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    H, P, N = s.n_heads(cfg.d_model), s.head_dim, s.d_state
    return MambaState(
        jax.ShapeDtypeStruct((n_layers, batch, conv_channels(cfg), s.d_conv - 1), dtype),
        jax.ShapeDtypeStruct((n_layers, batch, H, P, N), F32))


def init_mamba_layer(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    """Projection weights are stored per-section (z, x, B, C, dt) rather than
    packed, so each can carry its own tensor-parallel sharding (a packed
    in_proj cannot shard cleanly: the section boundaries don't align with
    model-axis shards)."""
    D = cfg.d_model
    s = cfg.ssm
    di = s.d_inner(D)
    H = s.n_heads(D)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    sd = D ** -0.5
    return {
        "ln": jnp.zeros((D,), dtype),
        "w_z": jax.random.normal(k1, (D, di), dtype) * sd,
        "w_x": jax.random.normal(k2, (D, di), dtype) * sd,
        "w_B": jax.random.normal(k4, (D, s.d_state), dtype) * sd,
        "w_C": jax.random.normal(k5, (D, s.d_state), dtype) * sd,
        "w_dt": jax.random.normal(k6, (D, H), dtype) * sd,
        "conv_x_w": jax.random.normal(k3, (di, s.d_conv), dtype) * 0.2,
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": jax.random.normal(k3, (2 * s.d_state, s.d_conv), dtype) * 0.2,
        "conv_bc_b": jnp.zeros((2 * s.d_state,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(F32)),   # A = -exp(A_log)
        "dt_bias": jnp.full((H,), -4.0, F32),
        "D_skip": jnp.ones((H,), F32),
        "w_out": jax.random.normal(k7, (di, D), dtype) * di ** -0.5,
        "gn_scale": jnp.ones((di,), F32),
    }


# ---------------------------------------------------------------------------
# chunked SSD
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, state, chunk: int):
    """x: (B,T,H,P); dt: (B,T,H); A: (H,) negative; Bm/Cm: (B,T,N);
    state: (B,H,P,N) f32. Returns (y (B,T,H,P) f32, new state)."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    nc = T // chunk
    xs = x.astype(F32).reshape(B, nc, chunk, H, P)
    dts = dt.astype(F32).reshape(B, nc, chunk, H)
    Bs = Bm.astype(F32).reshape(B, nc, chunk, N)
    Cs = Cm.astype(F32).reshape(B, nc, chunk, N)
    Af = A.astype(F32)

    def step(S, xs_c):
        xc, dtc, Bc, Cc = xs_c                     # (B,C,H,P) (B,C,H) (B,C,N)
        la = dtc * Af[None, None]                  # per-step log decay (<=0)
        cum = jnp.cumsum(la, axis=1)               # (B,C,H)
        # inter-chunk: y_t += exp(cum_t) * C_t @ S^T
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bhpn,bcn->bchp", S, Cc)
        # intra-chunk: y_t += sum_{i<=t} exp(cum_t-cum_i) dt_i (C_t.B_i) x_i
        half = 0.5 * cum[:, -1:]
        qd = jnp.exp(cum - half)                   # (B,C,H)
        kd = jnp.exp(half - cum) * dtc
        cb = jnp.einsum("bcn,bin->bci", Cc, Bc)    # (B,C,C)
        ci = jnp.arange(xc.shape[1])
        tri = ci[None, :] <= ci[:, None]           # inclusive lower triangular
        att = cb[:, None] * (qd.transpose(0, 2, 1)[..., None] *
                             kd.transpose(0, 2, 1)[..., None, :])
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhci,bihp->bchp", att, xc)
        # state update
        total = cum[:, -1]                         # (B,H)
        k_dec = jnp.exp(total[:, None] - cum) * dtc          # (B,C,H)
        S_new = jnp.exp(total)[..., None, None] * S + \
            jnp.einsum("bch,bchp,bcn->bhpn", k_dec, xc, Bc)
        return S_new, y_inter + y_intra

    xs_t = tuple(jnp.moveaxis(a, 1, 0) for a in (xs, dts, Bs, Cs))
    state, ys = _uscan(step, state.astype(F32), xs_t)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    return y, state


def ssd_step(x, dt, A, Bm, Cm, state):
    """Single token: x (B,H,P); dt (B,H); Bm/Cm (B,N); state (B,H,P,N)."""
    xf, dtf, Bf, Cf = (a.astype(F32) for a in (x, dt, Bm, Cm))
    decay = jnp.exp(dtf * A.astype(F32)[None])                 # (B,H)
    state = decay[..., None, None] * state + \
        jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, Bf)
    y = jnp.einsum("bhpn,bn->bhp", state, Cf)
    return y, state


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _project(p, x):
    """x (..., D) -> (z, xc, Bc, Cc, dt) per-section projections."""
    f32 = lambda w: jnp.einsum("...d,de->...e", x, w,
                               preferred_element_type=F32).astype(x.dtype)
    return f32(p["w_z"]), f32(p["w_x"]), f32(p["w_B"]), f32(p["w_C"]), \
        f32(p["w_dt"])


def _rmsnorm_gated(y, z, scale):
    yf = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return yf * lax.rsqrt(var + 1e-6) * scale


def _causal_conv(u, state, w, b, d_conv: int, T: int):
    """u (B,T,ch); state (B,ch,d_conv-1) -> (silu(conv(u)), new state)."""
    pad = jnp.moveaxis(state.astype(u.dtype), -1, 1)           # (B,d_conv-1,ch)
    up = jnp.concatenate([pad, u], axis=1)
    new_state = jnp.moveaxis(up[:, -(d_conv - 1):], 1, -1)
    wf = w.astype(F32)
    out = sum(up[:, i:i + T].astype(F32) * wf[:, i] for i in range(d_conv))
    return jax.nn.silu(out + b.astype(F32)).astype(u.dtype), new_state


def _causal_conv_step(u, state, w, b):
    """u (B,ch); state (B,ch,d_conv-1)."""
    window = jnp.concatenate([state.astype(F32), u.astype(F32)[..., None]],
                             axis=-1)
    new_state = window[..., 1:].astype(state.dtype)
    out = jnp.einsum("bcw,cw->bc", window, w.astype(F32))
    return jax.nn.silu(out + b.astype(F32)).astype(u.dtype), new_state


def mamba_block_full(cfg: ModelConfig, p, x, conv_state, ssm_state):
    """x (B,T,D) -> (out, (new_conv_state, new_ssm_state))."""
    s = cfg.ssm
    B, T, D = x.shape
    di = s.d_inner(D)
    H, P, N = s.n_heads(D), s.head_dim, s.d_state
    z, xc, Bc, Cc, dt = _project(p, x)
    # causal depthwise conv, applied per section so TP shardings stay intact
    # (xc is model-sharded on d_inner; B/C are small and replicated)
    bc = jnp.concatenate([Bc, Cc], axis=-1)                    # (B,T,2N)
    xc, new_conv_x = _causal_conv(xc, conv_state[..., :di, :], p["conv_x_w"],
                                  p["conv_x_b"], s.d_conv, T)
    bc, new_conv_bc = _causal_conv(bc, conv_state[..., di:, :], p["conv_bc_w"],
                                   p["conv_bc_b"], s.d_conv, T)
    new_conv = jnp.concatenate([new_conv_x, new_conv_bc], axis=-2)
    Bc, Cc = jnp.split(bc, [N], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])        # (B,T,H)
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(B, T, H, P)
    import os
    chunk = int(os.environ.get("REPRO_PROBE_CHUNK", 0)) or s.chunk_size
    chunk = min(chunk, T)
    assert T % chunk == 0, f"T={T} not divisible by chunk={chunk}"
    y, new_ssm = ssd_chunked(xh, dt, A, Bc, Cc, ssm_state, chunk)
    y = y + p["D_skip"][None, None, :, None] * xh.astype(F32)
    y = _rmsnorm_gated(y.reshape(B, T, di), z, p["gn_scale"])
    return jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["w_out"]), \
        (new_conv, new_ssm)


def mamba_block_step(cfg: ModelConfig, p, x, conv_state, ssm_state):
    """Single-token decode. x (B,D)."""
    s = cfg.ssm
    B, D = x.shape
    di = s.d_inner(D)
    H, P, N = s.n_heads(D), s.head_dim, s.d_state
    z, xc, Bc, Cc, dt = _project(p, x)
    bc = jnp.concatenate([Bc, Cc], axis=-1)                    # (B,2N)
    xc, new_conv_x = _causal_conv_step(xc, conv_state[..., :di, :],
                                       p["conv_x_w"], p["conv_x_b"])
    bc, new_conv_bc = _causal_conv_step(bc, conv_state[..., di:, :],
                                        p["conv_bc_w"], p["conv_bc_b"])
    new_conv = jnp.concatenate([new_conv_x, new_conv_bc], axis=-2)
    Bc, Cc = jnp.split(bc, [N], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])        # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(B, H, P)
    y, new_ssm = ssd_step(xh, dt, A, Bc, Cc, ssm_state)
    y = y + p["D_skip"][None, :, None] * xh.astype(F32)
    y = _rmsnorm_gated(y.reshape(B, di), z, p["gn_scale"])
    return jnp.einsum("be,ed->bd", y.astype(x.dtype), p["w_out"]), \
        (new_conv, new_ssm)
