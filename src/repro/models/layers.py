"""Shared functional layers: norms, RoPE, GQA attention, (gated) MLP, MoE.

Everything is a pure function of ``(params, inputs)``; parameter pytrees are
plain dicts so layer stacks can be scanned with ``jax.lax.scan``. All matmuls
accumulate in float32 (``preferred_element_type``) so bf16 weights are safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, MoEConfig
from repro.models.scan_util import scan as _uscan

Params = Dict[str, Any]
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Mesh axis names for the distributed step builders.

    ``None`` mesh means single-device execution (smoke tests / CPU engine).
    """
    mesh: Any = None
    dp_axes: Tuple[str, ...] = ()     # batch axes, e.g. ('pod', 'data')
    tp_axis: Optional[str] = None     # tensor-parallel axis ('model')
    ep_axis: Optional[str] = None     # expert-parallel axis ('data')
    sp_axis: Optional[str] = None     # KV-sequence-parallel axis for long decode

    @property
    def dp_spec(self):
        return self.dp_axes if self.dp_axes else None


def constrain(x, pctx: Optional[ParallelCtx], *spec):
    """with_sharding_constraint if running under a mesh, else identity."""
    if pctx is None or pctx.mesh is None:
        return x
    return lax.with_sharding_constraint(x, jax.sharding.NamedSharding(pctx.mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return ((1.0 + scale.astype(F32)) * y).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Params, x):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, dim: int, dtype) -> Params:
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.zeros((dim,), dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions[..., None].astype(F32) * freq          # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / softcap), dense-KV formulation
# ---------------------------------------------------------------------------

def attention_scores_mask(q_pos, kv_pos, *, causal: bool, window: Optional[int],
                          kv_valid=None):
    """Boolean mask (..., Sq, Skv); True = attend."""
    m = jnp.ones(q_pos.shape[-1:] + kv_pos.shape[-1:], dtype=bool)
    if causal:
        m = kv_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m = m & (q_pos[..., :, None] - kv_pos[..., None, :] < window)
    if kv_valid is not None:
        m = m & kv_valid[..., None, :]
    return m


def mha(q, k, v, mask, *, softcap: Optional[float] = None, scale: Optional[float] = None):
    """Grouped-query attention without materializing repeated KV.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); mask: (B, Sq, Skv) or (Sq, Skv).
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(F32), k.astype(F32)) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(F32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def _block_mask(q_pos, kv_pos, kv_valid, *, causal: bool,
                window: Optional[int], is_local):
    """Mask for one q block, computed lazily from positions (never a full
    (Sq, Skv) tensor). q_pos: (B, Cq); kv_pos: (B, Skv)."""
    if causal:
        m = kv_pos[:, None, :] <= q_pos[..., None]
    else:
        m = jnp.ones(q_pos.shape + kv_pos.shape[-1:], bool)
    if window is not None:
        w = q_pos[..., None] - kv_pos[:, None, :] < window
        if is_local is not None:
            w = w | (is_local < 0.5)
        m = m & w
    if kv_valid is not None:
        m = m & kv_valid[:, None, :]
    return m


def attention(q, k, v, q_pos, kv_pos, *, kv_valid=None, causal: bool = True,
              window: Optional[int] = None, is_local=None,
              softcap: Optional[float] = None, q_chunk: int = 2048):
    """Position-driven GQA attention, blocked over the query dimension so the
    score/mask working set is O(q_chunk * Skv), not O(Sq * Skv) — the XLA
    analogue of the Pallas flash kernel's tiling (long-prefill memory term).

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); q_pos: (B, Sq); kv_pos: (B, Skv).
    ``is_local``: traced 0/1 scalar toggling the sliding window (gemma2
    alternation under scan-over-layers).
    """
    B, Sq, H, D = q.shape
    if Sq <= q_chunk or Sq % q_chunk != 0:
        mask = _block_mask(q_pos, kv_pos, kv_valid, causal=causal,
                           window=window, is_local=is_local)
        return mha(q, k, v, mask, softcap=softcap)
    nb = Sq // q_chunk
    qb = jnp.moveaxis(q.reshape(B, nb, q_chunk, H, D), 1, 0)
    pb = jnp.moveaxis(q_pos.reshape(B, nb, q_chunk), 1, 0)

    def body(_, xs):
        qi, pi = xs
        mask = _block_mask(pi, kv_pos, kv_valid, causal=causal,
                           window=window, is_local=is_local)
        return None, mha(qi, k, v, mask, softcap=softcap)

    _, ob = _uscan(body, None, (qb, pb))
    return jnp.moveaxis(ob, 0, 1).reshape(B, Sq, H, D)


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           k_new=None, v_new=None, write_pages=None,
                           write_offsets=None):
    """Single-token decode attention over a paged KV pool.

    q: (B, Hq, D); k/v_pages: (P, page, Hkv, D); block_table: (B, max_pages)
    int32 device page ids in token order; lengths: (B,) valid kv tokens.
    Dispatches to the Pallas ``paged_attention`` kernel on TPU (block table
    scalar-prefetched so the page index_map steers HBM->VMEM DMA) and to the
    jnp gather oracle elsewhere. No sliding-window / softcap support — the
    paged layout is gated on configs without them.

    With ``k_new/v_new (B, Hkv, D)`` + ``write_pages/write_offsets (B,)``
    the new token's KV write is fused into the kernel (slot contract:
    position ``lengths - 1``) and the result is ``(o, k_pages, v_pages)``.
    """
    from repro.kernels import ops                  # lazy: keeps layers cheap
    return ops.decode_attention(q, k_pages, v_pages, block_table, lengths,
                                k_new=k_new, v_new=v_new,
                                write_pages=write_pages,
                                write_offsets=write_offsets)


def paged_prefill_attention(q, k_pages, v_pages, block_table, kv_len,
                            q_offset):
    """Gather-free chunked-prefill attention over a paged KV pool.

    q: (B, Sq, Hq, D) **model layout**; k/v_pages: (P, page, Hkv, D);
    block_table: (B, Np) int32 pool pages in token order (scratch-padded);
    kv_len: (B,) valid kv tokens (the chunk's own KV already scattered in);
    q_offset: (B,) absolute position of each row's first query. Returns
    (B, Sq, Hq, D). Kernel path reads pages in place via the prefetched
    table; the CPU oracle reproduces ``mha``'s math bit for bit.
    """
    from repro.kernels import ops                  # lazy: keeps layers cheap
    o = ops.prefill_attention(q.transpose(0, 2, 1, 3), k_pages, v_pages,
                              block_table, kv_len, q_offset)
    return o.transpose(0, 2, 1, 3)


def init_attn(cfg: ModelConfig, key, dtype) -> Params:
    D = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": jax.random.normal(k1, (D, cfg.q_dim), dtype) * s,
        "wk": jax.random.normal(k2, (D, cfg.kv_dim), dtype) * s,
        "wv": jax.random.normal(k3, (D, cfg.kv_dim), dtype) * s,
        "wo": jax.random.normal(k4, (cfg.q_dim, D), dtype) * (cfg.q_dim ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def attn_qkv(cfg: ModelConfig, p: Params, x, positions, *, use_rope=True):
    B, S, D = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bsd,de->bse", x, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,de->bse", x, p["wv"], preferred_element_type=F32)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(F32)
        k = k + p["bk"].astype(F32)
        v = v + p["bv"].astype(F32)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim_).astype(x.dtype)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim_).astype(x.dtype)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim_).astype(x.dtype)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p: Params, o):
    B, S, H, Dh = o.shape
    return jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * Dh), p["wo"],
                      preferred_element_type=F32).astype(o.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "gelu_tanh": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


def init_mlp(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None) -> Params:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": jax.random.normal(k2, (D, F), dtype) * D ** -0.5,
         "w_down": jax.random.normal(k3, (F, D), dtype) * F ** -0.5}
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.normal(k1, (D, F), dtype) * D ** -0.5
    return p


def mlp(cfg: ModelConfig, p: Params, x, pctx: Optional[ParallelCtx] = None):
    act = _act(cfg.act)
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"], preferred_element_type=F32)
    if cfg.gated_mlp:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"], preferred_element_type=F32)
        h = act(gate) * up
    else:
        h = act(up)
    h = h.astype(x.dtype)
    if pctx is not None:
        h = constrain(h, pctx, pctx.dp_spec, None, pctx.tp_axis)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"],
                      preferred_element_type=F32).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE: capacity-based dispatch (GShard-style, scatter formulation)
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff_expert, m.num_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k0, (D, E), dtype) * D ** -0.5,
        "w_gate": jax.random.normal(k1, (E, D, F), dtype) * D ** -0.5,
        "w_up": jax.random.normal(k2, (E, D, F), dtype) * D ** -0.5,
        "w_down": jax.random.normal(k3, (E, F, D), dtype) * F ** -0.5,
    }


def _route(moe: MoEConfig, logits):
    """logits (T, E) -> (topk_idx (T,K), topk_w (T,K) normalized)."""
    probs = jax.nn.softmax(logits.astype(F32), axis=-1)
    w, idx = lax.top_k(probs, moe.top_k)
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    return idx, w


def _dispatch(x, idx, w, num_experts: int, capacity: int):
    """Scatter tokens into per-expert capacity buffers.

    x: (T, D); idx/w: (T, K). Returns buf (E, C, D), and gather metadata.
    Memory O(T*K*E/8 + E*C*D) — no (T, E, C) one-hot tensor.
    """
    T, D = x.shape
    K = idx.shape[1]
    flat_e = idx.reshape(-1)                                   # (T*K,)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    # log-depth scan, NOT jnp.cumsum: XLA lowers big cumsums to a quadratic
    # reduce-window on some backends (O(T^2 E) flops for repo-scale token
    # counts); associative_scan is O(T log T) everywhere.
    ranks = lax.associative_scan(jnp.add, onehot, axis=0) * onehot
    pos = jnp.sum(ranks, axis=-1) - 1                          # (T*K,)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, capacity)                     # overflow -> dumped row
    buf = jnp.zeros((num_experts, capacity + 1, D), x.dtype)
    src = jnp.repeat(x, K, axis=0)                             # (T*K, D)
    buf = buf.at[flat_e, pos_c].add(src)
    return buf[:, :capacity], (flat_e, pos_c, keep)


def _combine(expert_out, meta, w, T: int):
    flat_e, pos_c, keep = meta
    K = w.shape[1]
    E, C, D = expert_out.shape
    padded = jnp.concatenate([expert_out, jnp.zeros((E, 1, D), expert_out.dtype)], axis=1)
    gathered = padded[flat_e, pos_c]                           # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    gathered = gathered.reshape(T, K, D) * w[..., None].astype(expert_out.dtype)
    return jnp.sum(gathered, axis=1)


def _expert_ffn(cfg: ModelConfig, p: Params, buf):
    """buf (E, C, D) -> (E, C, D) through per-expert gated MLP."""
    act = _act(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"], preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"], preferred_element_type=F32)
    h = (act(g) * u).astype(buf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                      preferred_element_type=F32).astype(buf.dtype)


def moe_ffn(cfg: ModelConfig, p: Params, x, pctx=None, token_shard: bool = False):
    """Single-device / TP-sharded MoE FFN. x: (B, S, D).

    ``token_shard``: with replicated expert weights (moe_replicated perf
    toggle), shard the flattened token dim over BOTH dp and model axes so the
    model-axis replicas split the expert work instead of duplicating it."""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if token_shard and pctx is not None and pctx.mesh is not None:
        axes = tuple(pctx.dp_axes) + ((pctx.tp_axis,) if pctx.tp_axis else ())
        xt = constrain(xt, pctx, axes, None)
    logits = jnp.einsum("td,de->te", xt, p["router"], preferred_element_type=F32)
    idx, w = _route(m, logits)
    capacity = max(8, int(B * S * m.top_k / m.num_experts * m.capacity_factor))
    buf, meta = _dispatch(xt, idx, w, m.num_experts, capacity)
    out = _expert_ffn(cfg, p, buf)
    combined = _combine(out, meta, w, B * S)
    if token_shard and pctx is not None and pctx.mesh is not None:
        combined = constrain(combined, pctx, tuple(pctx.dp_axes) or None, None)
    return combined.reshape(B, S, D)


def moe_ffn_ep_local(cfg: ModelConfig, p: Params, x, *, ep_axis: str,
                     tp_axis: Optional[str]):
    """Per-shard body for expert-parallel MoE (runs under shard_map).

    x: (B_local, S, D) local tokens; p['w_*'] are the local expert shards
    (E_local, D, F_local); p['router'] replicated.
    The ``ep_axis`` all_to_all routes capacity buffers so each shard computes
    only its own experts; tp_axis (if set) shards F with a psum on the way out.
    """
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    logits = jnp.einsum("td,de->te", xt, p["router"], preferred_element_type=F32)
    idx, w = _route(m, logits)
    capacity = max(8, int(B * S * m.top_k / m.num_experts * m.capacity_factor))
    buf, meta = _dispatch(xt, idx, w, m.num_experts, capacity)   # (E, C, D)
    # exchange: split E over shards, concat received buffers along C.
    buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1, tiled=True)
    out = _expert_ffn(cfg, p, buf)                               # (E/n, n*C, D)
    if tp_axis is not None:
        out = lax.psum(out, tp_axis)
    out = lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0, tiled=True)
    return _combine(out, meta, w, B * S).reshape(B, S, D)
