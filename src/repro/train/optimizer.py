"""AdamW + cosine schedule (hand-rolled, pytree-native).

Moments are kept in f32 and inherit the parameter shardings (ZeRO-free:
with TP+EP most state is already sharded; a 'zero_dp' flag additionally
shards moments over the data axis for dense-replicated params — the
distributed-optimizer trick for 1000+ node scale)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params))


def lr_at(cfg: OptConfig, step):
    warm = cfg.lr * (step + 1) / max(1, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(F32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    bc1 = 1.0 - b1 ** (step.astype(F32) + 1.0)
    bc2 = 1.0 - b2 ** (step.astype(F32) + 1.0)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step + 1, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}
