"""Synthetic token data pipeline: deterministic, shardable, prefetchable.

Produces packed (tokens, targets) LM batches; the iterator is seeded and
stateless-resumable (``state_dict``/``load_state_dict``) so training restarts
reproduce the exact stream — part of the fault-tolerance story.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLMStream:
    """Zipf-distributed token stream packed into fixed-length rows."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._step = 0

    def state_dict(self) -> Dict:
        return {"step": self._step}

    def load_state_dict(self, d: Dict) -> None:
        self._step = int(d["step"])

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, self._step))
        self._step += 1
        z = rng.zipf(1.3, size=(c.global_batch, c.seq_len + 1))
        toks = (z % (c.vocab_size - 2)) + 1
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()
