"""Pallas TPU paged flash attention (chunked-prefill hot path).

Gather-free chunked prefill: the flash kernel's KV grid dimension walks a
*scalar-prefetched block table* instead of a contiguous cache, so each
(page-sized) KV block is DMA'd HBM->VMEM **in place** from wherever the
page pool holds it — no ``pages[table]`` gather, no O(context) dense copy
per chunk. Per chunk the read side touches ``Np * page`` tokens of KV once
(what the attention math itself needs) instead of three times (gather read
+ dense-copy write + kernel read), which is what the
``prefill_hbm_bytes_per_chunk`` figure in ``benchmarks/paged_runner_bench``
accounts.

Grid and table indirection
    grid = (B, Hkv, n_q_blocks, Np) with ``num_scalar_prefetch=3``
    (``block_table (B, Np)``, ``kv_len (B,)``, ``q_offset (B,)``). The KV
    BlockSpec index_map returns ``(table[b, j], 0, h, 0)`` — the scalar
    prefetch happens before the grid runs, so the DMA engine can steer
    every page fetch directly off the table with no device round trip. The
    innermost (page) dimension is sequential: online-softmax state for the
    current q block lives in VMEM scratch across it, exactly as in
    ``flash_attention``, whose block-update helpers this kernel reuses.

VMEM scratch budget
    m/l: 2 * (G, block_q, 1) f32 and acc: (G, block_q, D) f32 per core —
    for G=4, block_q=128, D=128 that is ~264 KiB, plus the pipelined
    q/k/v/o blocks ((G*block_q + 2*page + G*block_q) * D * itemsize);
    comfortably inside the ~16 MiB/core budget for every config in
    ``configs/`` (the page size of 32 keeps a (page, D) tile VREG-aligned).

Masking rules
    * **causality**: queries sit at absolute kv positions ``q_offset[b] +
      i`` (chunked prefill: the chunk is the tail of the sequence so far);
      a score survives iff ``kv_pos <= q_pos``.
    * **scratch page / stale tail**: ``kv_pos < kv_len[b]`` masks every
      slot past the written prefix — the table is scratch-padded (its last
      entry is always the scratch page) and pool pages may hold stale
      garbage beyond the sequence tail (CoW tails, freed leases). Masked
      scores hit -1e30 before the online max, so garbage never reaches the
      accumulator.
    * **ragged final q block**: ``Sq`` is padded wrapper-side to a multiple
      of ``block_q``; padded rows get q positions past the real tail (all
      kv visible), stay finite through the 1e-30 denominator floor, and are
      sliced off the returned output.

TARGET is TPU; ``interpret=None`` resolves by backend (compiled on TPU,
interpreter elsewhere). Validated on CPU against
``ref.paged_flash_attention_ref`` (which *is* a gather — it is the oracle,
not the hot path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (NEG_INF, F32, online_softmax_block,
                                           online_softmax_finish,
                                           online_softmax_init)


def _kernel(table_ref, len_ref, qoff_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, page: int, block_q: int,
            n_pages: int):
    """Grid: (B, Hkv, n_q_blocks, Np).

    q_ref/o_ref: (G, block_q, D); k_ref/v_ref: (page, D) — one pool page of
    one KV head, steered by the prefetched table; scratch as in flash.
    """
    b = pl.program_id(0)
    qi = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        online_softmax_init(m_scr, l_scr, acc_scr)

    q = q_ref[...].astype(F32) * scale            # (G, bq, D)
    k = k_ref[...].astype(F32)                    # (page, D)
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=F32)   # (G, bq, page)

    q_pos = qoff_ref[b] + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, page), 1)
    kv_pos = j * page + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, page), 2)
    mask = (kv_pos <= q_pos) & (kv_pos < len_ref[b])
    s = jnp.where(mask, s, NEG_INF)

    online_softmax_block(s, v_ref[...].astype(F32), m_scr, l_scr, acc_scr)

    @pl.when(j == n_pages - 1)
    def _finish():
        online_softmax_finish(o_ref, m_scr, l_scr, acc_scr)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def paged_flash_attention(q, k_pages, v_pages, block_table, kv_len, q_offset,
                          *, block_q: int = 128,
                          interpret: Optional[bool] = None):
    """q: (B, Hq, Sq, D); k/v_pages: (P, page, Hkv, D);
    block_table: (B, Np) int32 pool page ids in token order (scratch-padded,
    last entry always the scratch page); kv_len: (B,) int32 valid kv tokens
    (the chunk's own KV must already be scattered into its pages);
    q_offset: (B,) int32 absolute position of each row's first query.
    Returns (B, Hq, Sq, D).

    ``kv_len``/``q_offset`` are traced (scalar-prefetched), so chunk starts
    never trigger recompiles; only shapes do. ``Sq`` may be ragged.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, Sq, D = q.shape
    _, page, Hkv, _ = k_pages.shape
    Np = block_table.shape[1]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    Sq_pad = -(-Sq // block_q) * block_q
    if Sq_pad != Sq:            # ragged final q block: pad, slice off below
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_pad - Sq), (0, 0)))
    n_q = Sq_pad // block_q
    qg = q.reshape(B, Hkv, G, Sq_pad, D)

    def q_map(b, h, i, j, table, kl, qo):
        return (b, h, 0, i, 0)

    def kv_map(b, h, i, j, table, kl, qo):
        return (table[b, j], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, n_q, Np),
        in_specs=[
            pl.BlockSpec((None, None, G, block_q, D), q_map),
            pl.BlockSpec((None, page, None, D), kv_map),
            pl.BlockSpec((None, page, None, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, None, G, block_q, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, block_q, 1), F32),
            pltpu.VMEM((G, block_q, 1), F32),
            pltpu.VMEM((G, block_q, D), F32),
        ],
    )
    kernel = functools.partial(_kernel, scale=D ** -0.5, page=page,
                               block_q=block_q, n_pages=Np)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Sq_pad, D), q.dtype),
        interpret=interpret,
    )(block_table, kv_len, q_offset, qg, k_pages, v_pages)
    out = out.reshape(B, Hq, Sq_pad, D)
    return out[:, :, :Sq] if Sq_pad != Sq else out
