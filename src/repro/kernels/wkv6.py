"""Pallas TPU kernel for the RWKV6 (WKV) recurrence — chunked formulation.

The per-channel decayed recurrence
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t S_{t-1} + (r_t.(u*k_t)) v_t
is computed chunk-by-chunk: intra-chunk terms become two (C,K)x(K,C)
MXU matmuls with a strictly-lower-triangular mask, and the (K,K) state is
carried in VMEM scratch across the sequential chunk-grid dimension — the
TPU-native adaptation of RWKV's CUDA kernel (no warp-level primitives; the
state tile lives in VMEM instead of registers/smem).

TARGET is TPU; validated on CPU with ``interpret=True`` against
``ref.wkv6_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr,
            *, chunk: int, n_chunks: int):
    """Grid: (B, H, n_chunks). r/k/v/lw_ref: (C, K); u_ref: (K,);
    s0_ref/sT_ref: (K, K); o_ref: (C, K); s_scr: (K, K) f32."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(F32)

    S = s_scr[...]
    r = r_ref[...].astype(F32)
    k = k_ref[...].astype(F32)
    v = v_ref[...].astype(F32)
    lw = lw_ref[...].astype(F32)
    u = u_ref[...].astype(F32)

    cum = jnp.cumsum(lw, axis=0)                      # (C, K) inclusive
    half = 0.5 * cum[-1:]
    r_dec = r * jnp.exp(cum - lw)                     # decay excl. current
    o_inter = jax.lax.dot(r_dec, S, preferred_element_type=F32)   # (C, K)
    q_ = r * jnp.exp(cum - lw - half)
    k_ = k * jnp.exp(half - cum)
    att = jax.lax.dot_general(q_, k_, (((1,), (1,)), ((), ())),
                              preferred_element_type=F32)         # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(tj < ti, att, 0.0)                # strictly lower tri
    o_intra = jax.lax.dot(att, v, preferred_element_type=F32)
    bonus = jnp.sum(r * u[None] * k, axis=1, keepdims=True)       # (C, 1)
    o_ref[...] = (o_inter + o_intra + bonus * v).astype(o_ref.dtype)

    total = cum[-1]                                   # (K,)
    k_dec = k * jnp.exp(total[None] - cum)
    s_new = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=F32)
    s_scr[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _finish():
        sT_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, state, *, chunk: int = 32, interpret: bool = True):
    """r/k/v/w: (B, T, H, K) [w in (0,1)]; u: (H, K); state: (B, H, K, K) f32.

    Returns (o (B, T, H, K) f32, final state (B, H, K, K) f32).
    """
    B, T, H, K = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    # layout: (B, H, T, K) so each grid cell reads a contiguous (C, K) tile.
    rt, kt, vt = (jnp.moveaxis(a, 1, 2) for a in (r, k, v))
    lw = jnp.log(jnp.clip(jnp.moveaxis(w, 1, 2).astype(F32), 1e-12, 1.0))

    seq_spec = pl.BlockSpec((None, None, chunk, K), lambda b, h, c: (b, h, c, 0))
    st_spec = pl.BlockSpec((None, None, K, K), lambda b, h, c: (b, h, 0, 0))
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((None, K), lambda b, h, c: (h, 0)),
                  st_spec],
        out_specs=[seq_spec, st_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, T, K), F32),
                   jax.ShapeDtypeStruct((B, H, K, K), F32)],
        scratch_shapes=[pltpu.VMEM((K, K), F32)],
        interpret=interpret,
    )(rt, kt, vt, lw, u, state)
    return jnp.moveaxis(o, 1, 2), sT
