"""Public kernel entry points with platform dispatch.

On TPU the Pallas kernels run compiled (``interpret=False``); everywhere else
they run in interpret mode or fall back to the jnp oracle. Model code calls
these wrappers, never ``pl.pallas_call`` directly.

    attention(...)         prefill/train attention (flash kernel | oracle)
    prefill_attention(...) gather-free paged prefill (paged flash | oracle)
    decode_attention(...)  paged decode attention (paged kernel | oracle),
                           optionally with the KV write fused in
    wkv(...)               RWKV6 recurrence        (wkv6 kernel | oracle)
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import (paged_attention as _paged,
                                           paged_attention_fused as
                                           _paged_fused)
from repro.kernels.paged_flash_attention import (paged_flash_attention as
                                                 _paged_flash)
from repro.kernels.wkv6 import wkv6 as _wkv6


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                              # pragma: no cover
        return False


def _use_kernels(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return _on_tpu()


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, q_offset: int = 0,
              use_kernel: Optional[bool] = None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D)."""
    if _use_kernels(use_kernel):
        return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                      q_offset=q_offset, interpret=not _on_tpu())
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    softcap=softcap, q_offset=q_offset)


def prefill_attention(q, k_pages, v_pages, block_table, kv_len, q_offset, *,
                      use_kernel: Optional[bool] = None, block_q: int = 128):
    """Gather-free chunked-prefill attention over paged KV.

    q: (B, Hq, Sq, D); pages: (P, page, Hkv, D); block_table: (B, Np);
    kv_len/q_offset: (B,) int32. The chunk's own KV must already be
    scattered into its pages. On the kernel path pages are read in place
    (block-table steered DMA); the oracle gathers — it is the ground truth
    and the CPU default, not the hot path.
    """
    if _use_kernels(use_kernel):
        return _paged_flash(q, k_pages, v_pages, block_table, kv_len,
                            q_offset, block_q=block_q,
                            interpret=not _on_tpu())
    return _ref.paged_flash_attention_ref(q, k_pages, v_pages, block_table,
                                          kv_len, q_offset)


def decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                     k_new=None, v_new=None, write_pages=None,
                     write_offsets=None, use_kernel: Optional[bool] = None):
    """q: (B, Hq, D); pages: (P, page, Hkv, D); table: (B, max_pages).

    With ``k_new/v_new/write_pages/write_offsets`` the decode-side KV write
    is fused: the new token's KV (``(B, Hkv, D)``, slot contract: position
    ``lengths - 1``) lands in the pool inside the call and the result is
    ``(o, k_pages, v_pages)``. Without them: plain read-only attention,
    returns ``o``.
    """
    fused = k_new is not None
    if _use_kernels(use_kernel):
        if fused:
            return _paged_fused(q, k_pages, v_pages, block_table, lengths,
                                k_new, v_new, write_pages, write_offsets,
                                interpret=not _on_tpu())
        return _paged(q, k_pages, v_pages, block_table, lengths,
                      interpret=not _on_tpu())
    if fused:
        k_pages = k_pages.at[write_pages, write_offsets].set(k_new)
        v_pages = v_pages.at[write_pages, write_offsets].set(v_new)
        o = _ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                     lengths)
        return o, k_pages, v_pages
    return _ref.paged_attention_ref(q, k_pages, v_pages, block_table, lengths)


def wkv(r, k, v, w, u, state, *, chunk: int = 32,
        use_kernel: Optional[bool] = None):
    if _use_kernels(use_kernel):
        return _wkv6(r, k, v, w, u, state, chunk=chunk,
                     interpret=not _on_tpu())
    return _ref.wkv6_ref(r, k, v, w, u, state)
