"""Public kernel entry points with platform dispatch.

On TPU the Pallas kernels run compiled (``interpret=False``); everywhere else
they run in interpret mode or fall back to the jnp oracle. Model code calls
these wrappers, never ``pl.pallas_call`` directly.

    attention(...)        prefill/train attention (flash kernel | oracle)
    decode_attention(...) paged decode attention (paged kernel | oracle)
    wkv(...)              RWKV6 recurrence        (wkv6 kernel | oracle)
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.wkv6 import wkv6 as _wkv6


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                              # pragma: no cover
        return False


def _use_kernels(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return _on_tpu()


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, q_offset: int = 0,
              use_kernel: Optional[bool] = None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D)."""
    if _use_kernels(use_kernel):
        return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                      q_offset=q_offset, interpret=not _on_tpu())
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    softcap=softcap, q_offset=q_offset)


def decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                     use_kernel: Optional[bool] = None):
    """q: (B, Hq, D); pages: (P, page, Hkv, D); table: (B, max_pages)."""
    if _use_kernels(use_kernel):
        return _paged(q, k_pages, v_pages, block_table, lengths,
                      interpret=not _on_tpu())
    return _ref.paged_attention_ref(q, k_pages, v_pages, block_table, lengths)


def wkv(r, k, v, w, u, state, *, chunk: int = 32,
        use_kernel: Optional[bool] = None):
    if _use_kernels(use_kernel):
        return _wkv6(r, k, v, w, u, state, chunk=chunk,
                     interpret=not _on_tpu())
    return _ref.wkv6_ref(r, k, v, w, u, state)
