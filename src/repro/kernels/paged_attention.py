"""Pallas TPU paged attention (decode hot path).

vLLM-style block-table indirection, adapted to TPU: the block table and
per-request lengths are *scalar-prefetched* so the KV page index_map can
steer HBM->VMEM DMA directly from the table (no gather materialization).
Online-softmax accumulates across the sequential page-grid dimension in
VMEM scratch. Page size defaults to 32 tokens so a (page, head_dim) tile is
VREG-aligned on TPU (the repo-wide adaptation noted in DESIGN.md §3).

TARGET is TPU; validated on CPU with ``interpret=True`` against
``ref.paged_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, page: int, n_pages: int):
    """Grid: (B, max_pages). q_ref/o_ref: (Hkv, G, D); k/v_ref: (page, Hkv, D)."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(F32) * scale                    # (Hkv, G, D)
    k = k_ref[...].astype(F32)                            # (page, Hkv, D)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=F32)   # (Hkv, G, page)
    kv_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = kv_pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                   # (Hkv, G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v_ref[...].astype(F32),
                             (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=F32)  # (Hkv, G, D)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    interpret: bool = None):
    """q: (B, Hq, D); k/v_pages: (P, page, Hkv, D);
    block_table: (B, max_pages) int32; lengths: (B,) int32 -> (B, Hq, D).

    ``interpret`` defaults by backend: compiled on TPU, interpreter
    everywhere else (this is a TPU Mosaic kernel — CPU CI and GPU hosts
    must not try to lower it) — resolved at trace time, so the jit cache
    keys on the resolved static value."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)

    def q_map(b, j, table, lens):
        return (b, 0, 0, 0)

    def kv_map(b, j, table, lens):
        return (table[b, j], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((None, Hkv, G, D), q_map),
            pl.BlockSpec((None, page, Hkv, D), kv_map),
            pl.BlockSpec((None, page, Hkv, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, Hkv, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G, 1), F32),
            pltpu.VMEM((Hkv, G, 1), F32),
            pltpu.VMEM((Hkv, G, D), F32),
        ],
    )
    kernel = functools.partial(_kernel, scale=D ** -0.5, page=page,
                               n_pages=max_pages)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
