"""Pallas TPU paged attention (decode hot path).

vLLM-style block-table indirection, adapted to TPU: the block table and
per-request lengths are *scalar-prefetched* so the KV page index_map can
steer HBM->VMEM DMA directly from the table (no gather materialization).
Online-softmax accumulates across the sequential page-grid dimension in
VMEM scratch. Page size defaults to 32 tokens so a (page, head_dim) tile is
VREG-aligned on TPU (the repo-wide adaptation noted in DESIGN.md §3).

``paged_attention_fused`` additionally folds the decode-side KV *write*
into the kernel prologue: the new token's k/v arrive as VMEM inputs, a
dynamic async copy lands them in their ``(write_page, write_offset)`` pool
slot at the first grid step, and the page pool rides through as aliased
ANY-space outputs — replacing the separate ``cache.at[...].set`` dispatch
(one full read-modify-write of the touched pages) that used to precede the
attention call. The accumulation never trusts the slot being written: page
reads are masked at ``kv_pos < lengths - 1`` and the final token's
contribution is added from the VMEM inputs at the last grid step, so the
in-flight HBM write cannot race the block pipeline's page fetches.

TARGET is TPU; validated on CPU with ``interpret=True`` against
``ref.paged_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, page: int, n_pages: int):
    """Grid: (B, max_pages). q_ref/o_ref: (Hkv, G, D); k/v_ref: (page, Hkv, D)."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(F32) * scale                    # (Hkv, G, D)
    k = k_ref[...].astype(F32)                            # (page, Hkv, D)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=F32)   # (Hkv, G, page)
    kv_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = kv_pos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                   # (Hkv, G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v_ref[...].astype(F32),
                             (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=F32)  # (Hkv, G, D)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                      ).astype(o_ref.dtype)


def _fused_kernel(table_ref, len_ref, wp_ref, wo_ref, q_ref, k_ref, v_ref,
                  kn_ref, vn_ref, o_ref, kp_out, vp_out,
                  m_scr, l_scr, acc_scr, k_sem, v_sem, *, scale: float,
                  page: int, n_pages: int):
    """Grid: (B, max_pages). q_ref/o_ref: (Hkv, G, D); k/v_ref: (page, Hkv,
    D) steered by the table; kn/vn_ref: (Hkv, D) the new token's KV;
    kp/vp_out: the pool in HBM (ANY space, aliased to the blocked k/v page
    inputs — same underlying buffers, written via dynamic async copy).

    Write/read discipline: the new token's pool slot is its own sequence
    position ``lengths[b] - 1`` (the caller's contract), so page reads mask
    ``kv_pos < lengths[b] - 1`` and the new token joins the online softmax
    from VMEM at the last grid step — the async HBM write launched in the
    prologue can land whenever it likes without racing a page fetch.
    """
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        kcp = pltpu.make_async_copy(
            kn_ref, kp_out.at[wp_ref[b], wo_ref[b]], k_sem)
        vcp = pltpu.make_async_copy(
            vn_ref, vp_out.at[wp_ref[b], wo_ref[b]], v_sem)
        kcp.start()
        vcp.start()
        kcp.wait()
        vcp.wait()

    q = q_ref[...].astype(F32) * scale                    # (Hkv, G, D)
    k = k_ref[...].astype(F32)                            # (page, Hkv, D)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=F32)   # (Hkv, G, page)
    kv_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = kv_pos < len_ref[b] - 1
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                   # (Hkv, G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v_ref[...].astype(F32),
                             (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=F32)  # (Hkv, G, D)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finish():
        # the new token, straight from VMEM: one more online-softmax step
        # over a single-entry kv block at position lengths[b] - 1
        kn = kn_ref[...].astype(F32)                      # (Hkv, D)
        s_new = jax.lax.dot_general(q, kn, (((2,), (1,)), ((0,), (0,))),
                                    preferred_element_type=F32)[..., None]
        m_prev2 = m_scr[...]
        m_fin = jnp.maximum(m_prev2, s_new)
        p_new = jnp.exp(s_new - m_fin)                    # (Hkv, G, 1)
        alpha2 = jnp.exp(m_prev2 - m_fin)
        l_fin = alpha2 * l_scr[...] + p_new
        acc = acc_scr[...] * alpha2 + \
            p_new * vn_ref[...].astype(F32)[:, None, :]
        o_ref[...] = (acc / jnp.maximum(l_fin, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_fused(q, k_pages, v_pages, block_table, lengths, k_new,
                          v_new, write_pages, write_offsets, *,
                          interpret: bool = None):
    """Decode attention with the KV write fused into the kernel.

    q: (B, Hq, D); k/v_pages: (P, page, Hkv, D); block_table: (B, max_pages)
    int32; lengths: (B,) int32 valid kv tokens INCLUDING the new token;
    k/v_new: (B, Hkv, D) the new token's KV; write_pages/write_offsets: (B,)
    its pool slot. Contract: the slot is the table position of sequence
    index ``lengths[b] - 1`` (idle lanes: length 1, slot (scratch, 0), an
    all-scratch table row — the contract holds degenerately).

    Returns ``(o (B, Hq, D), k_pages, v_pages)`` with the pools updated in
    place (the inputs are donated to the aliased outputs under jit).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)

    def q_map(b, j, *_):
        return (b, 0, 0, 0)

    def kv_map(b, j, table, *_):
        return (table[b, j], 0, 0, 0)

    def new_map(b, j, *_):
        return (b, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((None, Hkv, G, D), q_map),
            pl.BlockSpec((None, page, Hkv, D), kv_map),
            pl.BlockSpec((None, page, Hkv, D), kv_map),
            pl.BlockSpec((None, Hkv, D), new_map),
            pl.BlockSpec((None, Hkv, D), new_map),
        ],
        out_specs=[
            pl.BlockSpec((None, Hkv, G, D), q_map),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((Hkv, G, 1), F32),
            pltpu.VMEM((Hkv, G, 1), F32),
            pltpu.VMEM((Hkv, G, D), F32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    kernel = functools.partial(_fused_kernel, scale=D ** -0.5, page=page,
                               n_pages=max_pages)
    # operand indices for the aliases count the scalar-prefetch args too:
    # (table, lengths, wp, wo, qg, k_pages, v_pages, k_new, v_new) -> the
    # blocked pool inputs (operands 5 and 6) alias the ANY-space outputs
    o, kp, vp = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
                   jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                   jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)],
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(block_table, lengths, write_pages, write_offsets, qg, k_pages, v_pages,
      k_new, v_new)
    return o.reshape(B, Hq, D), kp, vp


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    interpret: bool = None):
    """q: (B, Hq, D); k/v_pages: (P, page, Hkv, D);
    block_table: (B, max_pages) int32; lengths: (B,) int32 -> (B, Hq, D).

    ``interpret`` defaults by backend: compiled on TPU, interpreter
    everywhere else (this is a TPU Mosaic kernel — CPU CI and GPU hosts
    must not try to lower it) — resolved at trace time, so the jit cache
    keys on the resolved static value."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)

    def q_map(b, j, table, lens):
        return (b, 0, 0, 0)

    def kv_map(b, j, table, lens):
        return (table[b, j], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((None, Hkv, G, D), q_map),
            pl.BlockSpec((None, page, Hkv, D), kv_map),
            pl.BlockSpec((None, page, Hkv, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, Hkv, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G, 1), F32),
            pltpu.VMEM((Hkv, G, 1), F32),
            pltpu.VMEM((Hkv, G, D), F32),
        ],
    )
    kernel = functools.partial(_kernel, scale=D ** -0.5, page=page,
                               n_pages=max_pages)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
