"""Pallas TPU flash attention (prefill/train hot path).

Supports causal masking, sliding-window (gemma2 local layers), logit softcap,
GQA, and chunked-prefill query offsets. Online-softmax accumulation runs in
VMEM scratch across the innermost (sequential) kv-block grid dimension;
block shapes are MXU/VREG aligned (multiples of (8,128) in f32).

The online-softmax block update (``online_softmax_block`` /
``online_softmax_finish``) is shared with the paged variant in
``paged_flash_attention.py`` — the two kernels differ only in how KV blocks
reach VMEM (contiguous grid stride here, scalar-prefetched block-table
indirection there) and in how the mask is built.

Ragged query lengths are handled wrapper-side: ``Sq`` is padded up to a
multiple of ``block_q`` (padded rows attend causally past the real tail and
are sliced off the output), so chunked-prefill callers never have to align
chunk lengths to the block shape. ``Skv`` stays asserted — KV buffers are
cache allocations, always block-aligned.

TARGET is TPU; ``interpret=None`` resolves by backend (compiled on TPU,
interpreter elsewhere — the kernel is validated on CPU against
``ref.flash_attention_ref``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def online_softmax_block(s, v, m_scr, l_scr, acc_scr):
    """One online-softmax accumulation step over a scored KV block.

    s: (G, bq, bk) f32 masked scores; v: (bk, D) f32;
    scratch: m/l (G, bq, 1) f32, acc (G, bq, D) f32 — updated in place.
    """
    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=F32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new


def online_softmax_init(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def online_softmax_finish(o_ref, m_scr, l_scr, acc_scr):
    """Write the normalized accumulator to the output block. The 1e-30
    denominator floor keeps fully-masked (padded) rows finite instead of
    NaN — their garbage is sliced off by the wrapper."""
    denom = jnp.maximum(l_scr[...], 1e-30)
    o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], q_offset: int, block_q: int,
            block_k: int, n_kv_blocks: int):
    """Grid: (B, Hkv, n_q_blocks, n_kv_blocks); each block carries the G
    query heads of one KV head.

    q_ref/o_ref: (G, block_q, D); k_ref/v_ref: (block_k, D);
    scratch: m/l (G, block_q, 1) f32, acc (G, block_q, D) f32.
    """
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        online_softmax_init(m_scr, l_scr, acc_scr)

    q = q_ref[...].astype(F32) * scale            # (G, bq, D)
    k = k_ref[...].astype(F32)                    # (bk, D)
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=F32)   # (G, bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, block_k), 1)
    kv_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_q, block_k), 2)
    mask = jnp.ones((1, block_q, block_k), bool)
    if causal:
        mask = kv_pos <= q_pos
    if window is not None:
        mask = mask & (q_pos - kv_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    online_softmax_block(s, v_ref[...].astype(F32), m_scr, l_scr, acc_scr)

    @pl.when(kj == n_kv_blocks - 1)
    def _finish():
        online_softmax_finish(o_ref, m_scr, l_scr, acc_scr)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    ``Sq`` may be any length (padded to ``block_q`` internally); ``Skv``
    must stay a multiple of ``block_k``. ``interpret`` defaults by backend:
    compiled on TPU, interpreter everywhere else — resolved at trace time,
    so the jit cache keys on the resolved static value.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0, (Skv, block_k)
    Sq_pad = -(-Sq // block_q) * block_q
    if Sq_pad != Sq:            # ragged final q block: pad, slice off below
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_pad - Sq), (0, 0)))
    n_q = Sq_pad // block_q
    n_kv = Skv // block_k
    qg = q.reshape(B, Hkv, G, Sq_pad, D)

    kernel = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, None, G, block_q, D),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, block_q, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Sq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, block_q, 1), F32),
            pltpu.VMEM((G, block_q, 1), F32),
            pltpu.VMEM((G, block_q, D), F32),
        ],
        interpret=interpret,
    )(qg, k, v)
    out = out.reshape(B, Hq, Sq_pad, D)
    return out[:, :, :Sq] if Sq_pad != Sq else out
