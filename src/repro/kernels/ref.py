"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels.py`` and are also the default math path on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        q_offset: int = 0):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D). Returns (B, Hq, Sq, D).

    ``q_offset`` positions the queries at kv index ``q_offset + i``
    (chunked prefill: queries are the tail of the kv sequence).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qf = q.astype(F32).reshape(B, Hkv, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(F32)) * (D ** -0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(k.shape[2])
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask = kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(F32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths):
    """Decode attention over paged KV.

    q: (B, Hq, D); k_pages/v_pages: (P, page, Hkv, D);
    block_table: (B, max_pages) int32; lengths: (B,) int32 (valid kv tokens).
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    max_pages = block_table.shape[1]
    G = Hq // Hkv
    k = k_pages[block_table]                     # (B, max_pages, page, Hkv, D)
    v = v_pages[block_table]
    k = k.reshape(B, max_pages * page, Hkv, D)
    v = v.reshape(B, max_pages * page, Hkv, D)
    qf = q.astype(F32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(F32)) * (D ** -0.5)
    valid = jnp.arange(max_pages * page)[None] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(F32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def paged_flash_attention_ref(q, k_pages, v_pages, block_table, kv_len,
                              q_offset):
    """Chunked-prefill attention over paged KV (oracle for
    ``paged_flash_attention`` — this one *does* gather; it is the ground
    truth and the CPU math path, not the hot path).

    q: (B, Hq, Sq, D); k/v_pages: (P, page, Hkv, D); block_table: (B, Np)
    int32; kv_len: (B,) int32 valid kv tokens; q_offset: (B,) int32 absolute
    position of each row's first query. Returns (B, Hq, Sq, D).

    The math mirrors ``models.layers.mha`` op for op (same einsum
    contractions, post-einsum scale, -1e30 mask, ``jax.nn.softmax``) so the
    paged prefill path stays *bit-identical* on CPU to the legacy
    gather-then-dense-step path: on real rows the causal mask alone already
    bounds kv at the query position, so adding the ``kv_len`` cut (which
    hides scratch-page and stale-tail garbage from padded rows) changes no
    unmasked entry, and masked scores underflow to exactly 0 weight.
    """
    B, Hq, Sq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    Np = block_table.shape[1]
    G = Hq // Hkv
    S = Np * page
    k = k_pages[block_table].reshape(B, S, Hkv, D)   # model (B, Skv, Hkv, D)
    v = v_pages[block_table].reshape(B, S, Hkv, D)
    qm = q.transpose(0, 2, 1, 3)                     # model (B, Sq, Hq, D)
    qg = qm.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(F32),
                        k.astype(F32)) * (D ** -0.5)
    q_pos = q_offset[:, None] + jnp.arange(Sq)[None]          # (B, Sq)
    kv_pos = jnp.arange(S)
    mask = (kv_pos[None, None, :] <= q_pos[:, :, None]) & \
           (kv_pos[None, None, :] < kv_len[:, None, None])    # (B, Sq, S)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(F32))
    out = out.reshape(B, Sq, Hq, D).astype(q.dtype)
    return out.transpose(0, 2, 1, 3)


def wkv6_ref(r, k, v, w, u, state):
    """Sequential WKV6 recurrence (the mathematical definition).

    r/k/v/w: (B, T, H, K); u: (H, K); state: (B, H, K, K) f32.
    Returns (o (B, T, H, K) f32, final state).
        o_t = r_t @ S_{t-1} + (r_t . (u*k_t)) v_t
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    rf, kf, vf, wf = (a.astype(F32) for a in (r, k, v, w))
    uf = u.astype(F32)

    def step(S, xs):
        rt, kt, vt, wt = xs                      # (B, H, K)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S) + \
            jnp.einsum("bhk,bhk->bh", rt, uf[None] * kt)[..., None] * vt
        S = wt[..., None] * S + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    S, os_ = jax.lax.scan(step, state.astype(F32), xs)
    return jnp.moveaxis(os_, 0, 1), S
