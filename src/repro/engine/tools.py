"""Host-side tool execution (the CPU plane).

Both executors implement the ``ToolExecutor`` protocol the engine types
against, and both draw their capacity from a shared ``CpuPool`` (the same
pool the swap/spool staging paths lease from) instead of a private slot
count — tool bursts and KV transfers now contend for the same cores.

``SimToolExecutor`` models co-located tool execution under the virtual
clock: invocations become pool leases, so queueing beyond capacity and
interference-stretched service times come from the pool's documented
model (this backlog is exactly the coupled-pressure signal MARS
consumes). ``RealToolExecutor`` runs actual callables on a thread pool
sized from the pool's cores for the live engine/examples, using the
pool's wall-clock accounting API. Both emit the same unified-info-stream
events; ``TOOL_START`` carries ``queue_wait`` (seconds the invocation
waited for a core) for the tracer's ``cpu_queue_wait`` attribution.

Constructors accept either a core count (builds a private pool —
back-compat) or a ``CpuPool`` to share.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import (Callable, Dict, List, Optional, Protocol, Union,
                    runtime_checkable)

from repro.core import events as ev
from repro.core.cpu_pool import CpuLease, CpuPool, CpuPoolConfig
from repro.core.events import EventBus
from repro.core.session import Session


def _resolve_pool(cpu_slots: Union[int, CpuPool]) -> CpuPool:
    if isinstance(cpu_slots, CpuPool):
        return cpu_slots
    return CpuPool(CpuPoolConfig(cores=int(cpu_slots)))


@runtime_checkable
class ToolExecutor(Protocol):
    """What the engine needs from a tool executor. ``pool`` is the shared
    CPU pool its invocations lease from; ``poll`` returns sessions whose
    tools completed by ``now``; ``cancel`` forgets a session's queued or
    running tool (releasing its pool lease); ``next_event_time`` is the
    earliest completion under the current schedule, queueing delay
    included (None on the wall-clock path)."""

    pool: CpuPool

    def start(self, s: Session, kind: str, duration: float,
              now: float) -> None: ...
    def poll(self, now: float) -> List[Session]: ...
    def cancel(self, sid: int, now: float) -> None: ...
    def next_event_time(self) -> Optional[float]: ...
    @property
    def active(self) -> int: ...
    @property
    def backlog(self) -> int: ...
    def shutdown(self) -> None: ...


class SimToolExecutor:
    def __init__(self, cpu_slots: Union[int, CpuPool], bus: EventBus):
        self.pool = _resolve_pool(cpu_slots)
        self.bus = bus
        self._leases: Dict[int, CpuLease] = {}    # sid -> in-flight lease
        self._sessions: Dict[int, Session] = {}
        self.faults = None     # engine.faults.FaultPlan.install wires this

    @property
    def cpu_slots(self) -> int:
        return self.pool.cores

    def start(self, s: Session, kind: str, duration: float, now: float) -> None:
        # expected_s is the *nominal* duration, stamped before any fault
        # stretch: the obs detectors judge the measured runtime against the
        # promise the engine was given
        self.bus.emit(ev.TOOL_ENQUEUE, now, s.sid, kind=kind,
                      expected_s=duration)
        if self.faults is not None:
            duration = self.faults.tool_duration(s.sid, kind, duration, now)
        lease = self.pool.submit(now, duration, sid=s.sid, kind="tool",
                                 tag=kind, priority=1)
        self._leases[s.sid] = lease
        self._sessions[s.sid] = s

    def poll(self, now: float) -> List[Session]:
        """Tools completed by ``now``. Advancing the shared pool reports
        lease starts (queued tools begin as cores free up — possibly
        between polls, at their exact scheduled times) and completions;
        transfer leases riding the same pool are advanced too, but only
        tool leases this executor issued produce events here."""
        started, completed = self.pool.advance(now)
        for lease in started:
            s = self._sessions.get(lease.sid)
            if lease.kind != "tool" or s is None \
                    or self._leases.get(lease.sid) is not lease:
                continue
            s.tool_started = lease.start
            s.meta["tool_kind_running"] = lease.tag
            s.meta["tool_duration"] = lease.end - lease.start
            self.bus.emit(ev.TOOL_START, lease.start, s.sid, kind=lease.tag,
                          queue_wait=lease.queue_wait)
        done: List[Session] = []
        for lease in completed:
            s = self._sessions.get(lease.sid)
            if lease.kind != "tool" or s is None \
                    or self._leases.get(lease.sid) is not lease:
                continue
            del self._leases[lease.sid]
            del self._sessions[lease.sid]
            self.bus.emit(ev.TOOL_END, lease.end, s.sid, kind=lease.tag,
                          duration=lease.end - lease.start)
            done.append(s)
        return done

    def cancel(self, sid: int, now: float) -> None:
        """Forget a session's queued/running tool (router detach): its
        completion must not resume a session another replica now owns.
        The pool lease is released — a queued lease gives back its slot
        (later waiting work backfills earlier), a running one frees its
        core at ``now``."""
        lease = self._leases.pop(sid, None)
        self._sessions.pop(sid, None)
        if lease is not None:
            self.pool.cancel(lease, now)

    def next_event_time(self) -> Optional[float]:
        """Earliest tool completion under the current pool schedule —
        queued invocations are eagerly placed, so this accounts for
        queueing delay behind both tools and transfer staging."""
        ends = [l.end for l in self._leases.values() if not l.reported_end]
        return min(ends) if ends else None

    @property
    def active(self) -> int:
        return sum(1 for l in self._leases.values() if l.reported_start)

    @property
    def backlog(self) -> int:
        return len(self._leases) - self.active

    def shutdown(self) -> None:
        pass


class RealToolExecutor:
    """Thread-pool executor for live tool callables (wall clock).

    ``Round.tool_seconds`` is honoured via sleep when no callable is given
    in ``session.meta['tool_fns'][round]`` — used by the live-engine
    examples. Worker capacity comes from the shared pool's core count;
    occupancy and queue waits feed the pool's wall-clock accounting."""

    def __init__(self, cpu_slots: Union[int, CpuPool], bus: EventBus):
        self.pool = _resolve_pool(cpu_slots)
        self.bus = bus
        self._exec = ThreadPoolExecutor(max_workers=self.pool.cores)
        self._done: "queue.Queue[Session]" = queue.Queue()
        self._active = 0
        self._cancelled: Dict[int, int] = {}   # sid -> completions to drop
        self._lock = threading.Lock()
        self._t0 = time.monotonic()

    @property
    def cpu_slots(self) -> int:
        return self.pool.cores

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def start(self, s: Session, kind: str, duration: float, now: float) -> None:
        self.bus.emit(ev.TOOL_ENQUEUE, now, s.sid, kind=kind,
                      expected_s=duration)
        self.pool.pending_inc()
        t_enq = self._now()
        fn: Optional[Callable] = None
        fns = s.meta.get("tool_fns")
        if fns:
            fn = fns.get(s.cur_round)

        def _run():
            t_start = self._now()
            with self._lock:
                self._active += 1
                self.pool.pending_dec()
                tok = self.pool.acquire(t_start, "tool")
                self.pool.note_wait("tool", t_start - t_enq)
            s.tool_started = t_start
            self.bus.emit(ev.TOOL_START, t_start, s.sid, kind=kind,
                          queue_wait=t_start - t_enq)
            try:
                if fn is not None:
                    fn()
                else:
                    time.sleep(duration)
            finally:
                t_end = self._now()
                with self._lock:
                    self._active -= 1
                    self.pool.release(t_end, tok)
                self.bus.emit(ev.TOOL_END, t_end, s.sid, kind=kind,
                              duration=t_end - t_start)
                self._done.put(s)

        self._exec.submit(_run)

    def cancel(self, sid: int, now: float) -> None:
        """Suppress the session's pending tool completion (router detach).
        The worker thread itself cannot be interrupted, so the next result
        for this sid is dropped instead of resuming the session."""
        with self._lock:
            self._cancelled[sid] = self._cancelled.get(sid, 0) + 1

    def poll(self, now: float) -> List[Session]:
        out = []
        while True:
            try:
                s = self._done.get_nowait()
            except queue.Empty:
                return out
            with self._lock:
                pending = self._cancelled.get(s.sid, 0)
                if pending:
                    if pending == 1:
                        del self._cancelled[s.sid]
                    else:
                        self._cancelled[s.sid] = pending - 1
                    continue
            out.append(s)

    def next_event_time(self) -> Optional[float]:
        return None

    @property
    def active(self) -> int:
        return self._active

    @property
    def backlog(self) -> int:
        return self.pool.backlog(self._now())

    def shutdown(self) -> None:
        self._exec.shutdown(wait=False)
