"""Host-side tool execution (the CPU plane).

``SimToolExecutor`` models co-located tool execution on a bounded number of
host CPU slots under a virtual clock: invocations beyond capacity *queue*
(this backlog is exactly the coupled-pressure signal MARS consumes).
``RealToolExecutor`` runs actual callables on a thread pool for the live
engine/examples. Both emit the same unified-info-stream events.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.session import Session


class SimToolExecutor:
    def __init__(self, cpu_slots: int, bus: EventBus):
        self.cpu_slots = cpu_slots
        self.bus = bus
        self._running: List[Tuple[float, int, Session]] = []   # (end, seq, s)
        self._waiting: List[Tuple[float, int, Session, float, str]] = []
        self._seq = 0

    def start(self, s: Session, kind: str, duration: float, now: float) -> None:
        self.bus.emit(ev.TOOL_ENQUEUE, now, s.sid, kind=kind)
        self._seq += 1
        seq = self._seq
        if len(self._running) < self.cpu_slots:
            self._begin(s, kind, duration, now, seq)
        else:
            self._waiting.append((now, seq, s, duration, kind))

    def _begin(self, s: Session, kind: str, duration: float, now: float,
               seq: int) -> None:
        # the per-item seq (not the global counter) keeps heap entries unique:
        # a queued tool re-begun from poll() must never collide with a seq
        # already in the heap, or tuple comparison falls through to Session.
        s.tool_started = now
        s.meta["tool_kind_running"] = kind
        s.meta["tool_duration"] = duration
        self.bus.emit(ev.TOOL_START, now, s.sid, kind=kind)
        heapq.heappush(self._running, (now + duration, seq, s))

    def poll(self, now: float) -> List[Session]:
        """Tools completed by ``now``; starts queued tools as slots free up."""
        done: List[Session] = []
        while self._running and self._running[0][0] <= now:
            end, _, s = heapq.heappop(self._running)
            self.bus.emit(ev.TOOL_END, end, s.sid,
                          kind=s.meta.get("tool_kind_running", "default"),
                          duration=s.meta.get("tool_duration", 0.0))
            done.append(s)
            if self._waiting:
                t0, seq, w, dur, kind = self._waiting.pop(0)
                self._begin(w, kind, dur, end, seq)
        return done

    def cancel(self, sid: int, now: float) -> None:
        """Forget a session's queued/running tool (router detach): its
        completion must not resume a session another replica now owns.
        A freed CPU slot immediately starts the oldest queued tool."""
        self._waiting = [w for w in self._waiting if w[2].sid != sid]
        kept = [e for e in self._running if e[2].sid != sid]
        if len(kept) != len(self._running):
            self._running = kept
            heapq.heapify(self._running)
            while self._waiting and len(self._running) < self.cpu_slots:
                _, seq, w, dur, kind = self._waiting.pop(0)
                self._begin(w, kind, dur, now, seq)

    def next_event_time(self) -> Optional[float]:
        return self._running[0][0] if self._running else None

    @property
    def active(self) -> int:
        return len(self._running)

    @property
    def backlog(self) -> int:
        return len(self._waiting)


class RealToolExecutor:
    """Thread-pool executor for live tool callables (wall clock).

    ``Round.tool_seconds`` is honoured via sleep when no callable is given in
    ``session.meta['tool_fns'][round]`` — used by the live-engine examples.
    """

    def __init__(self, cpu_slots: int, bus: EventBus):
        self.cpu_slots = cpu_slots
        self.bus = bus
        self._pool = ThreadPoolExecutor(max_workers=cpu_slots)
        self._done: "queue.Queue[Session]" = queue.Queue()
        self._active = 0
        self._cancelled: Dict[int, int] = {}   # sid -> completions to drop
        self._lock = threading.Lock()
        self._t0 = time.monotonic()

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def start(self, s: Session, kind: str, duration: float, now: float) -> None:
        self.bus.emit(ev.TOOL_ENQUEUE, now, s.sid, kind=kind)
        fn: Optional[Callable] = None
        fns = s.meta.get("tool_fns")
        if fns:
            fn = fns.get(s.cur_round)

        def _run():
            with self._lock:
                self._active += 1
            t_start = self._now()
            s.tool_started = t_start
            self.bus.emit(ev.TOOL_START, t_start, s.sid, kind=kind)
            try:
                if fn is not None:
                    fn()
                else:
                    time.sleep(duration)
            finally:
                t_end = self._now()
                with self._lock:
                    self._active -= 1
                self.bus.emit(ev.TOOL_END, t_end, s.sid, kind=kind,
                              duration=t_end - t_start)
                self._done.put(s)

        self._pool.submit(_run)

    def cancel(self, sid: int, now: float) -> None:
        """Suppress the session's pending tool completion (router detach).
        The worker thread itself cannot be interrupted, so the next result
        for this sid is dropped instead of resuming the session."""
        with self._lock:
            self._cancelled[sid] = self._cancelled.get(sid, 0) + 1

    def poll(self, now: float) -> List[Session]:
        out = []
        while True:
            try:
                s = self._done.get_nowait()
            except queue.Empty:
                return out
            with self._lock:
                pending = self._cancelled.get(s.sid, 0)
                if pending:
                    if pending == 1:
                        del self._cancelled[s.sid]
                    else:
                        self._cancelled[s.sid] = pending - 1
                    continue
            out.append(s)

    def next_event_time(self) -> Optional[float]:
        return None

    @property
    def active(self) -> int:
        return self._active

    def shutdown(self):
        self._pool.shutdown(wait=False)
