"""Paged-KV block pool (capacity plane) — counter-only legacy manager.

The engine now runs on ``repro.kvcache.pool.BlockPool`` (block identity,
refcounts, copy-on-write, radix-cached blocks) behind the same ``probe()``
surface defined here. ``BlockManager`` remains as the minimal count-based
reference implementation (unit tests pin its arithmetic).

``probe()`` is the O(1) read the unified info stream exports — free-list and
usage counters only, no byte math, no device sync (paper §4.1).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class BlockPoolProbe:
    total: int
    free: int
    pinned: int

    @property
    def used(self) -> int:
        return self.total - self.free


class BlockManager:
    def __init__(self, total_blocks: int, block_size: int = 32):
        assert total_blocks > 0
        self.total = total_blocks
        self.block_size = block_size
        self.free = total_blocks
        self.pinned = 0

    # ------------------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size) if n_tokens > 0 else 0

    def can_alloc(self, n: int) -> bool:
        return n <= self.free

    def alloc(self, n: int) -> bool:
        if n > self.free:
            return False
        self.free -= n
        return True

    def release(self, n: int) -> None:
        self.free += n
        assert self.free <= self.total, "double free"

    def pin(self, n: int) -> None:
        """Mark n held blocks as pinned (retained across a tool phase)."""
        self.pinned += n

    def unpin(self, n: int) -> None:
        self.pinned -= n
        assert self.pinned >= 0

    def probe(self) -> BlockPoolProbe:
        return BlockPoolProbe(self.total, self.free, self.pinned)
