"""Continuous-batching engine with MARS-style decoupled control.

One ``tick`` is one engine **iteration**. Under the default
``scheduler="mixed"`` (token-level continuous batching) a tick forms one
*mixed* batch: every in-flight decode session contributes exactly one
token and new sessions' chunked-prefill tokens ride along in the same
backend dispatch, so batch membership changes — sessions join, leave, are
preempted — at token granularity. ``scheduler="round"`` keeps the legacy
round-granular loop (``decode_granularity``-token decode quanta, prefills
fill whatever budget the decodes left) as the parity baseline.

    1. drain tool completions (unified info stream)      -> sessions resume
    2. O(1) block-pool + host-tier + backlog probe       -> telemetry
    3. external admission (policy.admit; MARS = Alg. 1);
       cold prefills attach to shared radix-indexed prefix blocks
    4. pin re-evaluation (adaptive four-way retention / TTL expiry):
       revoked pins drop, or demote to host DRAM or the NVMe cold tier;
       tiered-store upkeep demotes cold host entries to NVMe
    5. batch formation: decode continuations first (priority order, one
       token each in mixed mode), then chunked prefills under the
       policy's prefill/decode token-budget split (mixed mode caps the
       prefill share per iteration so a prefill wave can never inflate
       the inter-token latency of running decodes); chunk shrinking;
       pinned KV is reclaimed (drop or offload) before any running victim
       is preempted; completed host transfers drain back as swap-ins
       (NVMe entries promote back through host DRAM first — the staged
       restore)
    6. backend.run_batch — ONE dispatch for the whole mixed batch (sim:
       modeled seconds; jax: wall seconds, prefill packs + decode lanes
       fused into a single jitted call on the paged layout)
    7. bookkeeping: TTFT per round, per-iteration MLFQ service charging
       (quantum-by-token), tool yields + retention decisions, completion
       accounting

The same loop drives the discrete-event simulator and the live JAX engine —
only the backend, the tool executor, and the clock differ.

KV capacity is governed by the tiered subsystem (``repro.kvcache``): a
block-identity pool with refcounts/copy-on-write, a radix prefix index for
cross-session sharing, and a host-DRAM + NVMe offload hierarchy orchestrated
by ``TieredStore``.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple

from repro.core import events as ev
from repro.core.cpu_pool import CpuPool, CpuPoolConfig
from repro.core.events import EventBus
from repro.core.policies import (KVAction, MARSConfig, Policy, Services,
                                 make_policy)
from repro.core.session import KVState, Phase, Round, Session
from repro.core.telemetry import Telemetry, TelemetryConfig
from repro.engine.backend import BatchWork
from repro.engine.tools import SimToolExecutor, ToolExecutor
from repro.kvcache import (BlockPool, DiskTier, DiskTierConfig, HostTier,
                           HostTierConfig, RadixIndex, TieredStore)


@dataclass
class EngineConfig:
    total_kv_blocks: int = 8192
    block_size: int = 32
    token_budget: int = 8192          # per-tick prefill+decode token budget
    max_decode_batch: int = 64
    decode_granularity: int = 8       # round mode only; mixed always uses 1
    # "mixed" = iteration-level continuous batching (default): one token
    # per decode lane per tick, prefill chunks ride along under the
    # policy's prefill/decode budget split, one fused backend dispatch.
    # "round" = legacy round-granular scheduling (parity baseline).
    scheduler: str = "mixed"
    cpu_slots: int = 16
    # shared host-CPU pool (core/cpu_pool): queueing + interference model
    # every CPU consumer (tools, swap staging, spool I/O) leases from.
    # None => derived from cpu_slots with the documented defaults.
    cpu_pool: CpuPoolConfig = None
    telem: TelemetryConfig = None     # derived from cpu_slots if None
    enable_prefix_sharing: bool = True  # radix index over prefix chunk hashes
    host_tier_blocks: int = -1        # host-DRAM tier capacity; -1 => 4x HBM
    host_pcie_bw: float = 24e9        # batched-DMA effective bytes/s
    # NVMe cold tier (kvcache.disk_tier): 0 => off (three-way retention),
    # -1 => 16x HBM. Requires a host tier (staged restores route through it).
    disk_tier_blocks: int = 0
    disk_read_bw: float = 3.5e9       # sequential read bytes/s
    disk_write_bw: float = 1.8e9      # sustained sequential write bytes/s
    disk_op_latency_s: float = 1e-4   # per-op NVMe latency
    disk_queue_depth: int = 16        # concurrent modeled device ops
    disk_demote_after_s: float = 30.0  # host entry idle time before demotable
    disk_demote_watermark: float = 0.5  # host occupancy that starts demotion

    def __post_init__(self):
        if self.telem is None:
            self.telem = TelemetryConfig(cpu_slots=self.cpu_slots)
        if self.cpu_pool is None:
            self.cpu_pool = CpuPoolConfig(cores=self.cpu_slots)
        if self.scheduler not in ("mixed", "round"):
            raise ValueError(
                f"scheduler must be 'mixed' or 'round', got "
                f"{self.scheduler!r}")


class Engine:
    def __init__(self, cfg: EngineConfig, policy_name: str, backend, *,
                 bus: Optional[EventBus] = None, tool_exec=None,
                 mars_cfg: Optional[MARSConfig] = None):
        self.cfg = cfg
        self.bus = bus or EventBus()
        self.backend = backend
        self.blocks = BlockPool(cfg.total_kv_blocks, cfg.block_size)
        # physical backends bind to the pool so block ids map onto device
        # pages (paged live runner); sim/slot-dense backends have no hook
        bind = getattr(backend, "bind_kv_pool", None)
        if bind is not None:
            bind(self.blocks)
        # prefix sharing swaps block references, never KV bytes, so it
        # requires a backend whose KV state lives in the block accounting
        # (sim) or whose physical placement follows the block ids (the
        # paged live runner); the slot-dense live layout is neither
        self.radix: Optional[RadixIndex] = (
            RadixIndex(self.blocks, chunk_tokens=cfg.block_size)
            if (cfg.enable_prefix_sharing
                and getattr(backend, "supports_prefix_sharing", False))
            else None)
        # shared CPU core pool: tools, swap staging, and spool I/O all
        # lease from it. An externally built executor brings its own pool
        # (the engine adopts it so the transfer paths contend with its
        # tools); otherwise one is built from the config.
        if tool_exec is not None and getattr(tool_exec, "pool", None) \
                is not None:
            self.cpu_pool: CpuPool = tool_exec.pool
        else:
            self.cpu_pool = CpuPool(cfg.cpu_pool)
        # live backends track swap-stream worker CPU against the same pool
        bind_cpu = getattr(backend, "bind_cpu_pool", None)
        if bind_cpu is not None:
            bind_cpu(self.cpu_pool)
        host_blocks = (4 * cfg.total_kv_blocks if cfg.host_tier_blocks < 0
                       else cfg.host_tier_blocks)
        bpt_fn = getattr(backend, "kv_bytes_per_token", None)
        bpt = bpt_fn() if bpt_fn else 64 * 1024
        self.host: Optional[HostTier] = (
            HostTier(HostTierConfig(capacity_blocks=host_blocks,
                                    pcie_bw=cfg.host_pcie_bw),
                     bytes_per_token=bpt, block_size=cfg.block_size)
            if host_blocks > 0 else None)
        # NVMe cold tier + the TieredStore orchestrator over host+disk.
        # The engine always talks to the store (it delegates transparently
        # when no disk tier is configured); `self.host`/`self.disk` stay
        # exposed for tests and telemetry.
        disk_blocks = (16 * cfg.total_kv_blocks if cfg.disk_tier_blocks < 0
                       else cfg.disk_tier_blocks)
        self.disk: Optional[DiskTier] = (
            DiskTier(DiskTierConfig(capacity_blocks=disk_blocks,
                                    read_bw=cfg.disk_read_bw,
                                    write_bw=cfg.disk_write_bw,
                                    op_latency_s=cfg.disk_op_latency_s,
                                    queue_depth=cfg.disk_queue_depth),
                     bytes_per_token=bpt, block_size=cfg.block_size)
            if disk_blocks > 0 and self.host is not None else None)
        self.tiers: Optional[TieredStore] = (
            TieredStore(self.host, self.disk,
                        recompute_time=backend.recompute_time,
                        demote_after_s=cfg.disk_demote_after_s,
                        demote_watermark=cfg.disk_demote_watermark,
                        bus=self.bus, cpu_pool=self.cpu_pool)
            if self.host is not None else None)
        if self.tiers is not None and self.disk is not None:
            spill = getattr(backend, "spill_host", None)
            unspill = getattr(backend, "fill_host", None)
            if spill is not None and unspill is not None:
                self.tiers.bind_backend(spill=spill, unspill=unspill)
        self.telem = Telemetry(cfg.telem, self.bus)
        # async swap stream: the backend drains swap-outs and prefetches
        # swap-ins on a background worker; the engine then gates restores
        # on real transfer futures and defers (never stalls on) sessions
        # whose swap-in is still in flight. Sim backends stay on the
        # modeled clock — their behaviour is bit-identical.
        self._async_swap = bool(getattr(backend, "supports_async_swap",
                                        False))
        self.policy: Policy = make_policy(policy_name, self.telem, self.bus,
                                          backend, mars_cfg)
        self.policy.bind(Services(
            host_tier=self.tiers,
            swap_size_fn=self._private_swap_size,
            async_swap=self._async_swap,
            prefix_lookup=(self._indexed_prefix_blocks
                           if self.radix is not None else None),
            disk_tier=self.disk,
            cpu_pool=self.cpu_pool))
        self.tools: ToolExecutor = (tool_exec
                                    or SimToolExecutor(self.cpu_pool,
                                                       self.bus))
        self.waiting: List[Session] = []
        self.active: List[Session] = []
        self.pinned: List[Session] = []
        self.finished: List[Session] = []
        self.rejected: List[Session] = []
        self._pending_swapouts: List[Tuple[Session, int]] = []
        # benchmark counters (kvcache_bench reads these)
        self.prefill_tokens_computed = 0
        self.prefix_hit_tokens = 0
        # observability (repro.obs.Tracer.install flips this): per-tick
        # phase wall timings + retention audit records are only worth their
        # perf_counter calls when something is listening
        self.trace_ticks = False
        # deterministic fault injection (engine.faults.FaultPlan.install):
        # None in production — every hook is a single identity check
        self.faults = None

    # ------------------------------------------------------------------
    def submit(self, s: Session) -> None:
        # admission-reject sessions that can never fit the KV pool (their
        # full context exceeds capacity): a 4xx in a real deployment. Without
        # this they would livelock in the stall hatch forever.
        total_tokens = sum(r.new_input_tokens + r.decode_tokens
                           for r in s.rounds)
        if self.blocks.blocks_for(total_tokens) > 0.98 * self.blocks.total:
            s.phase = Phase.FINISHED
            s.meta["rejected"] = True
            self.rejected.append(s)
            self.bus.emit(ev.REJECT, s.arrival_time, s.sid,
                          tokens=total_tokens)
            return
        self.bus.emit(ev.SUBMIT, s.arrival_time, s.sid, tokens=total_tokens,
                      rounds=len(s.rounds),
                      # SLO contract rides the stream so obs.slo can track
                      # against it live or from a replayed dump alike
                      slo_class=s.meta.get("slo_class"),
                      slo_alpha=s.slo_alpha, ideal_s=s.ideal_time)
        hashes = s.meta.get("prefix_hashes")
        if hashes is not None:
            # the radix assumes one chunk == one KV block; a workload
            # chunked at a different granularity must not attach (block
            # accounting would drift) — disable sharing for the session
            bs = self.cfg.block_size
            if (not hashes or any(n != bs for _, n in hashes[:-1])
                    or not 0 < hashes[-1][1] <= bs):
                s.meta.pop("prefix_hashes")
        s.phase = Phase.WAITING_ADMIT
        self.waiting.append(s)

    def done(self) -> bool:
        return not self.waiting and not self.active

    def next_timer_event(self, now: float = float("-inf")) -> Optional[float]:
        """Earliest pinned-KV TTL expiry (finite TTLs only) and earliest
        *future* host-tier transfer completion — the sim driver must not
        jump the clock past policy timers or in-flight DMA. Completed
        transfers are not timers: their sessions restore whenever the tool
        ends and blocks free up."""
        ts = [s.pinned_since + s.pin_ttl for s in self.pinned
              if s.pin_ttl != float("inf")]
        if self.tiers is not None:
            t_tier = self.tiers.next_event_time(now)
            if t_tier is not None:
                ts.append(t_tier)
        return min(ts) if ts else None

    def check_invariants(self) -> None:
        """Block-, refcount- and state-machine invariants (used by tests).

        With prefix sharing, per-session logical holdings (lease entries)
        can exceed physical occupancy; the exact identities are:
        ``free + physical_in_use == total`` and ``sum(refcounts) ==
        sum(session.kv_blocks)``."""
        held = sum(s.kv_blocks for s in self.active)
        p = self.blocks.probe()
        assert p.free + p.physical == p.total, \
            f"physical leak: free={p.free} physical={p.physical} " \
            f"total={p.total}"
        assert p.leased == held, \
            f"lease accounting: leased={p.leased} held={held}"
        assert held >= p.physical or held == 0, "refcount underflow"
        self.blocks.check_consistency()
        for s in self.active:
            assert s.kv_blocks == self.blocks.lease_len(s.sid), \
                f"sid {s.sid}: kv_blocks={s.kv_blocks} " \
                f"lease={self.blocks.lease_len(s.sid)}"
        pinned = sum(s.kv_blocks for s in self.pinned)
        assert self.blocks.pinned == pinned, \
            f"pin accounting: {self.blocks.pinned} != {pinned}"
        for s in self.pinned:
            assert s.kv_state == KVState.PINNED and s.phase == Phase.TOOL
        for s in self.active:
            assert s.kv_blocks >= 0
            assert s.resident_len <= s.kv_blocks * self.cfg.block_size
        for s in self.finished:
            assert s.kv_blocks == 0 and s.phase == Phase.FINISHED
        if self.tiers is not None:
            tiered = [s for s in self.active
                      if s.kv_state == KVState.SWAPPED
                      and s.meta.get("host_tier")]
            for s in tiered:
                assert self.tiers.holds(s.sid), f"lost tier entry {s.sid}"
            want = sum(            # per-block offload: only private blocks
                s.meta.get("host_blocks",      # occupy the tiers
                           self.blocks.blocks_for(s.meta.get("swapped_len", 0)))
                for s in tiered)
            used = self.host.used_blocks + \
                (self.disk.used_blocks if self.disk is not None else 0)
            assert used == want, \
                f"tier occupancy: host+disk {used} != {want}"
            assert self.host.used_blocks <= self.host.capacity_blocks, \
                "host tier over capacity"
            if self.disk is not None:
                assert self.disk.used_blocks <= self.disk.capacity_blocks, \
                    "disk tier over capacity"

    # ------------------------------------------------------------------
    def tick(self, now: float) -> Tuple[float, bool]:
        """Returns (elapsed_seconds, progressed)."""
        trace = self.trace_ticks
        t0 = time.perf_counter() if trace else 0.0
        progressed = False
        if self.faults is not None:
            self.faults.apply(self, now)
        # 1. tool completions
        for s in self.tools.poll(now):
            if s not in self.active:
                continue             # detached mid-tool: owned elsewhere now
            self._resume_from_tool(s, now)
            progressed = True
        # 2. telemetry probe; hysteresis/churn advance once per tick
        self._probe()
        self.telem.tick()
        t1 = time.perf_counter() if trace else 0.0
        # 3. admission
        if self.waiting and not (self.faults is not None and
                                 self.faults.active("frozen_admission", now)):
            admitted = self.policy.admit(self.waiting, now)
            for s in admitted:
                self.waiting.remove(s)
                self.active.append(s)
                s.phase = Phase.READY_PREFILL
                s.admitted_at = s.last_service = now
                s.round_submit = now
                self.bus.emit(ev.GPU_SUBMIT, now, s.sid, round=s.cur_round,
                              tokens=s.pending_prefill)
                progressed = True
            if admitted:
                self._probe()
        # 3.5 cross-session prefix sharing: round-0 prefills (cold, or mid-
        # build at a block-aligned boundary) attach to radix-indexed blocks
        # of sessions that already built the shared context
        if self.radix is not None:
            for s in self.active:
                if (s.phase == Phase.READY_PREFILL and s.cur_round == 0
                        and s.decoded == 0
                        and s.kv_state in (KVState.NONE, KVState.RESIDENT)
                        and s.resident_len % self.cfg.block_size == 0
                        and self._attach_prefix(s, now)):
                    progressed = True
        # 4. pin re-evaluation (four-way: keep / offload / demote / drop)
        for s, action in list(self.policy.revoke_actions(self.pinned, now)):
            self._revoke_pin(s, now, action, reason="pin_revoked")
            progressed = True
        # 4.5 tiered-store upkeep: demote cold host entries to NVMe by the
        # net-benefit score; sessions already back from their tool are
        # vetoed (demoting an entry about to restore would ping-pong)
        if self.tiers is not None and self.disk is not None:
            idle = {s.sid for s in self.active
                    if s.phase == Phase.TOOL
                    and s.kv_state == KVState.SWAPPED}
            self.tiers.maintain(now, demotable=idle.__contains__)
        t2 = time.perf_counter() if trace else 0.0
        # 5-6. batch formation + execution
        work = self._form_batch(now)
        t3 = time.perf_counter() if trace else 0.0
        elapsed = self.backend.run_batch(work, now)
        t4 = time.perf_counter() if trace else 0.0
        # swap-completion handshake: bind the D2H drains the backend just
        # launched to their tier entries — from here on, ready() answers
        # from the real transfer, not the modeled completion time (a
        # direct-to-disk entry chains its spool write behind the drain)
        if self.tiers is not None and work.swap_futures:
            for sid, fut in work.swap_futures.items():
                self.tiers.attach_future(sid, fut)
        # 7. bookkeeping
        if not work.empty:
            self._apply(work, now, now + elapsed, elapsed)
            progressed = True
        if trace:
            t5 = time.perf_counter()
            extra = {}
            # live-backend prefill HBM traffic counters (gather-free win):
            # cumulative, so the Perfetto counter track shows the spread
            dst = getattr(self.backend, "dispatch_stats", None)
            if dst is not None and "prefill_gather_bytes" in dst:
                extra["prefill_gather_bytes"] = dst["prefill_gather_bytes"]
                extra["prefill_inplace_bytes"] = dst["prefill_inplace_bytes"]
            self.bus.emit(
                ev.TICK, now, -1,
                elapsed=elapsed, wall_s=t5 - t0,
                phases={"tools_control": t1 - t0, "upkeep": t2 - t1,
                        "form_batch": t3 - t2, "run_batch": t4 - t3,
                        "bookkeep": t5 - t4},
                n_decodes=len(work.decodes), n_prefills=len(work.prefills),
                n_swapins=len(work.swapins), n_swapouts=len(work.swapouts),
                # MIXED_BATCH fields: scheduler mode + token composition of
                # this iteration's dispatch (decode lanes vs prefill chunks)
                mixed=work.mixed,
                decode_tokens=sum(g for _, g in work.decodes),
                prefill_tokens=sum(cch for _, cch in work.prefills),
                active=len(self.active), waiting=len(self.waiting),
                free_blocks=self.blocks.free,
                total_blocks=self.blocks.total,
                active_tools=self.telem.active_tools,
                cpu_busy=self.cpu_pool.busy_cores(now),
                cpu_backlog=self.cpu_pool.backlog(now),
                host_used=self.host.used_blocks if self.host else 0,
                disk_used=self.disk.used_blocks if self.disk else 0,
                **extra)
        return elapsed, progressed

    # ------------------------------------------------------------------
    def _probe(self) -> None:
        p = self.blocks.probe()
        waiting_blocks = sum(
            self.blocks.blocks_for(s.pending_prefill)
            for s in self.waiting)
        waiting_blocks += sum(
            self.blocks.blocks_for(s.pending_prefill) - s.kv_blocks
            for s in self.active if s.phase == Phase.READY_PREFILL)
        n_dec = sum(1 for s in self.active if s.phase == Phase.DECODING)
        self.telem.probe_gpu(p.total, p.free, p.pinned, len(self.active),
                             n_dec, max(0, waiting_blocks))
        if self.host is not None:
            self.telem.probe_host(self.host.used_blocks,
                                  self.host.capacity_blocks,
                                  self.host.stores, self.host.hits)
        if self.tiers is not None:
            self.telem.probe_tiers(self.tiers.stats())
        if self.radix is not None:
            self.telem.probe_prefix(self.radix.queries, self.radix.hits,
                                    self.radix.hit_tokens)
            self.telem.probe_digest(self.radix.digest())

    # --- cross-replica prefix reuse ------------------------------------
    def radix_digest(self, top_k: int = 16) -> Optional[dict]:
        """Compact radix-root digest for the cluster router's heartbeat
        (None when prefix sharing is off — a digest-blind replica). Cached
        per index version, so per-tick callers pay a dict lookup."""
        return self.radix.digest(top_k) if self.radix is not None else None

    def _indexed_prefix_blocks(self, s: Session) -> int:
        """Blocks of ``s``'s round-0 chunk-key prefix already indexed on
        this replica (exact ``RadixIndex.match``) — admission sizes family
        members net of the shared context they will attach to, not build.
        The match is cached against the index *structure* (insert count +
        node count, which eviction shrinks): pack_queue re-estimates every
        queued session several times per admission cycle, every tick —
        without the stamp that is O(queue x prefix) tree walks of pure
        recomputation (same trouble the attach path's radix_stale_at
        stamp exists for)."""
        if self.radix is None or s.cur_round != 0 or s.decoded:
            return 0
        hashes = s.meta.get("prefix_hashes")
        if not hashes:
            return 0
        key = (self.radix.inserted_blocks, len(self.radix))
        cached = s.meta.get("radix_admission_est")
        if cached is not None and cached[0] == key:
            return cached[1]
        n = len(self.radix.match(hashes))
        s.meta["radix_admission_est"] = (key, n)
        return n

    # --- tiered KV helpers ---------------------------------------------
    def _swap_record(self, s: Session):
        """Per-block offload plan for ``s``'s current lease: the full
        (bid, gen, private) record plus the private block/token counts —
        only private blocks (content lost at release) cross PCIe."""
        bs = self.cfg.block_size
        rec = []
        host_blocks = host_tokens = 0
        for i, bid in enumerate(self.blocks.lease(s.sid)):
            private = not self.blocks.survives_release(bid)
            rec.append((bid, self.blocks.gen(bid), private))
            if private:
                host_blocks += 1
                host_tokens += min(bs, s.resident_len - i * bs)
        return rec, host_blocks, host_tokens

    def _private_swap_size(self, s: Session):
        """(tokens, blocks) that would actually move if ``s`` offloaded now
        — the policy prices retention with this, so radix-shared contexts
        (cheap to park per-block) are not charged the full-context PCIe
        cost the pre-paged swapper would have paid."""
        _rec, blocks, tokens = self._swap_record(s)
        return tokens, blocks

    def _attach_prefix(self, s: Session, now: float) -> bool:
        """Attach to the longest indexed prefix of this session's chunk
        hashes beyond what it already built (shared physical blocks, no
        recompute). Works cold *and* mid-prefill at block-aligned
        boundaries, so a family member that started before the canonical
        builder finished still catches up to freshly indexed blocks.
        Reviving cached blocks consumes free capacity, so the match is
        trimmed to what fits above the decode watermark."""
        hashes = s.meta.get("prefix_hashes")
        if not hashes:
            return False
        # skip the O(context/block) root re-match unless the index grew
        # since the last fully-consumed lookup (inserted_blocks is monotone;
        # capacity-trimmed matches don't stamp, so they retry as space frees)
        if s.meta.get("radix_stale_at") == self.radix.inserted_blocks:
            return False
        held = s.kv_blocks
        if held * self.cfg.block_size != s.resident_len:
            return False          # partial tail block: not chunk-aligned
        if not s.meta.get("radix_queried"):
            s.meta["radix_queried"] = True
            self.radix.record_query(anchor=hashes[0][0])
        matched = self.radix.match(hashes)
        if len(matched) <= held:
            s.meta["radix_stale_at"] = self.radix.inserted_blocks
            return False
        matched = matched[held:]  # the already-built prefix stays private
        avail = max(0, self.blocks.free - self._watermark())
        n_revive = sum(1 for bid, _ in matched if self.blocks.is_cached(bid))
        while matched and n_revive > avail:
            bid, _ = matched.pop()
            if self.blocks.is_cached(bid):
                n_revive -= 1
        # a backend that really decodes needs the last prompt token's
        # logits to seed decoding (vLLM semantics: a full prefix hit still
        # computes >= 1 token), so never let the match cover the entire
        # pending prefill — the tail chunk is recomputed privately
        if getattr(self.backend, "requires_last_token_compute", False):
            while matched and s.resident_len + sum(
                    n for _, n in matched) >= s.prefill_target:
                matched.pop()
        if not matched:
            return False
        bids = [b for b, _ in matched]
        toks = sum(n for _, n in matched)
        self.blocks.acquire(s.sid, bids)
        s.kv_blocks += len(bids)
        s.resident_len += toks
        s.context_len = max(s.context_len, s.resident_len)
        s.kv_state = KVState.RESIDENT
        self.prefix_hit_tokens += toks
        self.radix.record_hit(toks, first=not s.meta.get("radix_hit"),
                              anchor=hashes[0][0])
        s.meta["radix_hit"] = True
        self.bus.emit(ev.PREFIX_HIT, now, s.sid, tokens=toks,
                      blocks=len(bids))
        if s.pending_prefill <= 0:       # full duplicate: nothing to build
            s.phase = Phase.DECODING
        return True

    def _insert_prefix_progress(self, s: Session) -> None:
        """Index every fully-built round-0 chunk so far (vLLM/sglang style
        incremental prefix caching): later family members attach to the
        shared context *while* the first builder is still prefilling. The
        partial tail chunk is indexed only once round 0 completes."""
        hashes = s.meta.get("prefix_hashes")
        if not hashes:
            return
        done = s.meta.get("prefix_chunks_indexed", 0)
        if s.pending_prefill <= 0:
            m = len(hashes)          # completion: partial tail included
        else:
            m, cum = 0, 0
            for _, n_tok in hashes:
                if cum + n_tok > s.resident_len or n_tok < self.cfg.block_size:
                    break
                cum += n_tok
                m += 1
        if m <= done:
            return
        lease = self.blocks.lease(s.sid)
        if len(lease) < m:
            return
        self.radix.insert(hashes[:m], lease[:m])
        s.meta["prefix_chunks_indexed"] = m
        if m == len(hashes):
            s.meta["radix_inserted"] = True

    def _offload_kv(self, s: Session, now: float,
                    target: str = "host") -> bool:
        """Demote resident KV to the tiered store, *per block*: only
        private blocks (content lost at release) cross PCIe and occupy the
        target tier; shared/indexed prefix blocks stay physically on device
        and are re-referenced at restore by their (bid, gen) certificate.
        Device blocks free immediately; the (asynchronous) transfer of the
        private suffix gates restorability. ``target="disk"`` routes the
        entry straight to the NVMe cold tier (staged write: the D2H leg
        stages through the stream's bounded buffers, then the spool write
        lands — restores promote back through host DRAM)."""
        if self.tiers is None or s.kv_blocks <= 0:
            return False
        if target == "disk" and self.disk is None:
            target = "host"
        rec, host_blocks, host_tokens = self._swap_record(s)
        can = (self.tiers.can_store_disk(host_blocks) if target == "disk"
               else self.tiers.can_store(host_blocks))
        if not can:
            return False
        self.tiers.store(s.sid, host_tokens, host_blocks, now,
                         target=target, context_tokens=s.resident_len)
        if self._async_swap:
            # the D2H drain is launched by run_batch next tick; until its
            # future is attached the entry must not look restorable (the
            # modeled ready_at may pass while nothing has been copied)
            self.tiers.mark_in_flight(s.sid)
        s.meta["swapped_len"] = s.resident_len
        s.meta["host_tier"] = True
        s.meta["kv_tier"] = target
        s.meta["swap_pages"] = rec
        s.meta["host_blocks"] = host_blocks
        s.meta["host_tokens"] = host_tokens
        self._pending_swapouts.append((s, host_tokens))
        freed = self.blocks.release_all(s.sid)
        assert freed == s.kv_blocks
        self.bus.emit(ev.SWAP_OUT, now, s.sid, blocks=s.kv_blocks,
                      copied=host_blocks, tier=target)
        s.kv_blocks = 0
        s.resident_len = 0
        s.kv_state = KVState.SWAPPED
        return True

    def _revoke_pin(self, s: Session, now: float, action: KVAction,
                    reason: str) -> None:
        self.blocks.unpin(s.kv_blocks)
        if s in self.pinned:
            self.pinned.remove(s)
        s.kv_state = KVState.RESIDENT
        if action in (KVAction.OFFLOAD, KVAction.OFFLOAD_DISK):
            target = "disk" if action == KVAction.OFFLOAD_DISK else "host"
            if self._offload_kv(s, now, target=target):
                self.bus.emit(ev.UNPIN, now, s.sid, warm=False, to=target)
                return
        self._release_kv(s, now, reason=reason)

    def _drop_host_copy(self, s: Session) -> None:
        """Abandon host-side KV (recompute fallback / release): the tier
        entry if present, and the live backend's copy unconditionally —
        legacy-SWAP sessions also park K/V host-side via _swap_out and
        would otherwise leak it for the life of the server."""
        if s.meta.pop("host_tier", None) and self.tiers is not None:
            self.tiers.drop(s.sid)
        for k in ("swap_pages", "restore_positions", "host_blocks",
                  "host_tokens", "kv_tier", "swap_in_future", "swap_cost_s",
                  "swap_cpu_wait_s"):
            s.meta.pop(k, None)
        drop = getattr(self.backend, "drop_host", None)
        if drop is not None:
            drop(s.sid)

    def _resume_from_tool(self, s: Session, now: float) -> None:
        if s in self.pinned:
            self.pinned.remove(s)
            self.blocks.unpin(s.kv_blocks)
            s.kv_state = KVState.RESIDENT
            self.bus.emit(ev.UNPIN, now, s.sid, warm=True)
        s.cur_round += 1
        s.decoded = 0
        s.first_token_seen = False
        s.phase = Phase.READY_PREFILL
        s.round_submit = now
        self.bus.emit(ev.GPU_SUBMIT, now, s.sid, round=s.cur_round,
                      tokens=s.pending_prefill)

    def detach_session(self, s: Session, now: float) -> None:
        """Hand a session off this replica (router drain / failover):
        release its device lease, pin accounting, host-side copies, and any
        in-flight tool, and forget it. The engine stays reusable —
        ``check_invariants`` holds after detach, so a recovered replica can
        keep ticking without resuming a session it no longer owns."""
        if s.phase == Phase.TOOL:
            # protocol-guaranteed: both executors release the session's
            # pool lease (queued or running) on cancel
            self.tools.cancel(s.sid, now)
        self._release_kv(s, now, reason="detach")
        for lst in (self.waiting, self.active, self.pinned):
            if s in lst:
                lst.remove(s)

    def _release_kv(self, s: Session, now: float, reason: str) -> None:
        if s.kv_state == KVState.PINNED:
            self.blocks.unpin(s.kv_blocks)
            if s in self.pinned:
                self.pinned.remove(s)
        if s.kv_state == KVState.SWAPPED:
            self._drop_host_copy(s)
            s.meta["swapped_len"] = 0
        if s.kv_blocks:
            freed = self.blocks.release_all(s.sid)
            assert freed == s.kv_blocks, \
                f"lease mismatch on release: {freed} != {s.kv_blocks}"
            self.bus.emit(ev.EVICT, now, s.sid, blocks=s.kv_blocks,
                          reason=reason)
        s.kv_blocks = 0
        s.resident_len = 0
        s.kv_state = KVState.NONE
        # the attach-skip stamp is only valid while the attached state is
        # intact: a released (preempted/reclaimed) round-0 session must be
        # free to re-attach even if the index hasn't grown since
        s.meta.pop("radix_stale_at", None)
        release = getattr(self.backend, "release_session", None)
        if release is not None:
            release(s.sid)

    def _preempt(self, s: Session, now: float) -> None:
        s.preemptions += 1
        s.recomputed_tokens += s.resident_len
        if s.phase == Phase.DECODING:
            s.phase = Phase.READY_PREFILL
        self.bus.emit(ev.PREEMPT, now, s.sid, tokens=s.resident_len,
                      blocks=s.kv_blocks)
        self._release_kv(s, now, reason="preempt")

    def _ensure_blocks(self, n: int, now: float, in_batch: Set[int],
                       requester: Session, allow_preempt: bool) -> bool:
        """Free >= n blocks: reclaim pinned contexts first (policy order);
        preempt running/resident victims only if ``allow_preempt`` (decode
        extensions and the stall escape hatch — waiting prefills otherwise
        never preempt, matching vLLM semantics)."""
        if self.blocks.free >= n:
            return True
        for s in self.policy.reclaim_order(list(self.pinned), now):
            self._revoke_pin(s, now, self.policy.reclaim_action(s, now),
                             reason="reclaim")
            if self.blocks.free >= n:
                return True
        if not allow_preempt:
            return False
        victims = [v for v in self.active
                   if v.kv_blocks > 0 and v.sid != requester.sid
                   and v.sid not in in_batch and v.phase != Phase.TOOL]
        for v in self.policy.eviction_order(victims, now, requester):
            self._preempt(v, now)
            if self.blocks.free >= n:
                return True
        return self.blocks.free >= n

    def _restore_lease(self, s: Session) -> bool:
        """Rebuild a swapped-out session's lease in recorded order: shared
        blocks are re-referenced on device iff their (bid, gen) certificate
        still holds; private blocks get fresh pages (the backend fills them
        from the host copy at the positions in ``meta["restore_positions"]``).
        Returns False — with the partial lease rolled back — when any shared
        block's content is gone; the caller falls back to recompute.
        Capacity for ``blocks_for(swapped_len)`` must already be ensured
        (reacquire consumes at most one free block per entry, via revive)."""
        rec = s.meta.get("swap_pages")
        if rec is None:        # no placement record (externally built meta)
            need = self.blocks.blocks_for(s.meta.get("swapped_len", 0))
            ok = self.blocks.alloc(s.sid, need)
            assert ok, "restore alloc failed despite ensured capacity"
            s.kv_blocks += need
            s.meta["restore_positions"] = list(range(need))
            return True
        restore: List[int] = []
        for i, (bid, gen, private) in enumerate(rec):
            if private:
                ok = self.blocks.alloc(s.sid, 1)
                assert ok, "restore alloc failed despite ensured capacity"
                restore.append(i)
            elif not self.blocks.reacquire(s.sid, bid, gen):
                self.blocks.release_all(s.sid)       # roll back partial lease
                s.meta.pop("restore_positions", None)
                return False
        s.kv_blocks += len(rec)
        s.meta["restore_positions"] = restore
        return True

    def _write_need(self, s: Session, new_tokens: int) -> Tuple[int, int]:
        """(new blocks, CoW blocks) to extend ``s`` by ``new_tokens``:
        writing into a shared/indexed partial tail block requires a private
        copy first (one extra physical block while the original keeps its
        content for the other referents / future prefix matchers)."""
        need = self.blocks.blocks_for(s.resident_len + new_tokens) \
            - s.kv_blocks
        cow = 1 if (s.resident_len % self.cfg.block_size != 0
                    and self.blocks.tail_needs_cow(s.sid)) else 0
        return need, cow

    def _grow_lease(self, s: Session, need: int, cow: int) -> None:
        """Commit a write reservation (capacity for need + cow must already
        be ensured). CoW runs while the shared block is still the lease
        tail — alloc() appends private blocks, after which copy_on_write
        would re-check the wrong block and silently no-op."""
        if cow:
            assert self.blocks.copy_on_write(s.sid), \
                "copy-on-write failed despite ensured capacity"
        if need > 0:
            self.blocks.alloc(s.sid, need)
            s.kv_blocks += need

    # ------------------------------------------------------------------
    def _form_batch(self, now: float) -> BatchWork:
        c = self.cfg
        mixed = c.scheduler == "mixed"
        ready = [s for s in self.active
                 if s.phase in (Phase.READY_PREFILL, Phase.DECODING)]
        order = self.policy.order(ready, now)
        if self.faults is not None:
            # freeze_decode: the targeted session silently never makes the
            # batch — DECODING phase, no more DECODE_STEPs (the livelock
            # signature the obs detectors must catch). Only sessions that
            # have already stepped qualify for the untargeted latch: a
            # frozen lane is "stopped decoding", not "never started".
            order = [s for s in order
                     if not (s.phase == Phase.DECODING and s.decoded > 0
                             and self.faults.freezes(s.sid, now))]
        decodes: List[Tuple[Session, int]] = []
        prefills: List[Tuple[Session, int]] = []
        swapins: List[Tuple[Session, int]] = []
        in_batch: Set[int] = set()
        budget = c.token_budget
        # mixed mode: every decode lane advances exactly one token per
        # iteration, so batch membership (join/leave/preempt) is decided
        # at token granularity; round mode bursts decode_granularity-token
        # quanta (the parity baseline).
        quantum = 1 if mixed else c.decode_granularity

        # decodes first: latency-sensitive continuations. Decode extensions
        # may preempt (they must make progress to ever release memory).
        for s in order:
            if s.phase != Phase.DECODING or len(decodes) >= c.max_decode_batch:
                continue
            g = min(quantum, s.cur.decode_tokens - s.decoded, budget)
            if g <= 0:
                continue
            need, cow = self._write_need(s, g)
            if need + cow > 0:
                if not self._ensure_blocks(need + cow, now, in_batch, s,
                                           allow_preempt=True):
                    continue
                self._grow_lease(s, need, cow)
            decodes.append((s, g))
            in_batch.add(s.sid)
            budget -= g

        # prefills / swap-ins fill the remaining budget from free blocks and
        # reclaimable pins only (no preemption). Mixed mode additionally
        # caps the prefill share of this iteration via the policy's
        # prefill/decode budget split, so a prefill-heavy arrival wave can
        # never inflate the inter-token latency of the decode lanes riding
        # in the same dispatch.
        if mixed:
            decode_toks = sum(g for _, g in decodes)
            budget = min(budget,
                         self.policy.prefill_budget(c.token_budget,
                                                    decode_toks))
        for s in order:
            if s.phase != Phase.READY_PREFILL or budget <= 0:
                continue
            before = len(prefills)
            self._try_prefill(s, now, in_batch, budget, prefills, swapins,
                              allow_preempt=False)
            if len(prefills) > before:
                budget -= prefills[-1][1]
        # stall escape hatch: pool exhausted by partial holders and nothing
        # scheduled -> serve the single top-priority ready session, allowing
        # preemption of strictly junior work (deadlock freedom). Uses the
        # full token budget: with an empty batch there are no decode lanes
        # to protect, so the split does not apply.
        if not decodes and not prefills and not swapins:
            for s in order:
                if s.phase != Phase.READY_PREFILL:
                    continue
                if self._try_prefill(s, now, in_batch, c.token_budget,
                                     prefills, swapins, allow_preempt=True):
                    break
        swapouts, self._pending_swapouts = self._pending_swapouts, []
        work = BatchWork(decodes, prefills, swapins, swapouts, mixed=mixed)
        # placement snapshot: the backend executes from these tables (and
        # the tick's CoW copy list), never from live pool state — swapped-
        # out leases are already released, and a bid freed here may be
        # re-leased to another batch member within this very tick
        for s, _ in decodes:
            work.leases[s.sid] = tuple(self.blocks.lease(s.sid))
        for s, _ in prefills:
            work.leases[s.sid] = tuple(self.blocks.lease(s.sid))
        for s, _ in swapins:
            work.leases[s.sid] = tuple(self.blocks.lease(s.sid))
        work.cow_copies = self.blocks.drain_cow_log()
        return work

    def _watermark(self) -> int:
        """Block reserve prefills may not dip into: active decodes extend by
        ~1 block each within a few ticks; without this reserve, greedy chunked
        prefills starve decode extensions into preemption storms (vLLM keeps
        the same kind of allocation watermark)."""
        n_dec = sum(1 for s in self.active if s.phase == Phase.DECODING)
        return max(self.blocks.total // 100, 2 * n_dec)

    def _stamp_swap_cost(self, s: Session, toks: int, now: float) -> None:
        """``meta["swap_cost_s"]`` accounting, future-aware: the engineered-
        DMA restore time covers the private suffix only (shared prefix
        blocks were re-referenced on device, no PCIe traffic). When the
        async stream already crossed that suffix in the background (the
        swap-in future resolved before the session was batched, or there
        was nothing private to move), the restore serializes *nothing* —
        the stamp is 0.0. Sim path: no futures, modeled cost, plus any
        CPU-side delay of the H2D staging copy — the restore's bounce
        buffers lease from the shared core pool, so a tool burst pushes
        the restore out (``swap_cpu_wait_s``, surfaced on SWAP_IN for the
        tracer's ``cpu_queue_wait`` attribution)."""
        fut = s.meta.pop("swap_in_future", None)
        if self._async_swap and (fut is None or fut.done()):
            s.meta["swap_cost_s"] = 0.0
        else:
            swap_s = self.tiers.swap_seconds(s.meta.get("host_tokens", toks))
            cpu_extra = 0.0
            frac = self.cpu_pool.cfg.transfer_cpu_frac
            if frac > 0.0 and swap_s > 0.0:
                lease = self.cpu_pool.submit(now, frac * swap_s, sid=s.sid,
                                             kind="swap", tag="h2d",
                                             priority=0)
                cpu_extra = max(0.0, lease.end - now - swap_s)
            s.meta["swap_cost_s"] = swap_s + cpu_extra
            s.meta["swap_cpu_wait_s"] = cpu_extra

    def _abandon_swap(self, s: Session, now: float) -> None:
        """Give up on a swapped-out session's host copy (stale certificate
        or capacity deadlock): rebuild by recompute."""
        self.bus.emit(ev.SWAP_ABANDON, now, s.sid,
                      tokens=s.meta.get("swapped_len", 0))
        self._drop_host_copy(s)
        s.kv_state = KVState.NONE
        s.meta["swapped_len"] = 0

    def _swap_in_blocked(self, s: Session, now: float) -> bool:
        """Async swap stream: is this tiered session's restore still gated?
        Issues the H2D prefetch on first call (the crossing then overlaps
        this tick's other sessions' compute) and answers True while the
        prefetch future is unresolved — the engine *defers* the session,
        it never stalls the batch on the transfer. Applies the (bid, gen)
        certificate check first: a record that went stale while in flight
        falls back to recompute immediately (not blocked, not restorable —
        the caller re-checks ``kv_state``)."""
        rec = s.meta.get("swap_pages") or []
        if not self.blocks.certify(
                [(bid, gen) for bid, gen, private in rec if not private]):
            # a shared block was CoW'd / evicted / re-leased while the
            # transfer was in flight: the certificate is void before any
            # pages were touched — discard the prefetch with the host copy
            self._abandon_swap(s, now)
            return False
        if "swap_in_future" not in s.meta:
            fut = self.backend.prefetch_swap_in(s.sid)
            s.meta["swap_in_future"] = fut
            if fut is not None:
                return True            # H2D launched: deferred, not stalled
        fut = s.meta["swap_in_future"]
        return fut is not None and not fut.done()

    def _try_prefill(self, s: Session, now: float, in_batch: Set[int],
                     budget: int, prefills, swapins, allow_preempt: bool) -> bool:
        c = self.cfg
        reserve = 0 if allow_preempt else self._watermark()
        avail = max(0, self.blocks.free - reserve)
        if s.kv_state == KVState.SWAPPED:
            tiered = bool(s.meta.get("host_tier")) and self.tiers is not None
            if tiered:
                # tier access: promotes a disk-resident entry back through
                # host DRAM (staged first hop) on first request. False =>
                # a transfer gates the restore: a modeled entry completes
                # at a known future time (exported via next_timer_event),
                # a future-gated one resolves on the background stream —
                # waiting is strictly cheaper than abandoning to recompute.
                # None => the restore can never proceed (entry lost, or a
                # promotion starved of host capacity under the stall
                # hatch): abandon to recompute.
                r = self.tiers.request(s.sid, now, urgent=allow_preempt)
                if r is None:
                    self._abandon_swap(s, now)
                elif not r:
                    return False
            if (s.kv_state == KVState.SWAPPED and tiered
                    and self._async_swap and self._swap_in_blocked(s, now)):
                return False
        if s.kv_state == KVState.SWAPPED:   # may have fallen to recompute
            toks = s.meta.get("swapped_len", 0)
            tiered = bool(s.meta.get("host_tier")) and self.tiers is not None
            need = self.blocks.blocks_for(toks)
            if need <= avail or self._ensure_blocks(
                    need + reserve, now, in_batch, s, allow_preempt):
                if self._restore_lease(s):
                    if tiered:
                        self._stamp_swap_cost(s, toks, now)
                    swapins.append((s, toks))
                    in_batch.add(s.sid)
                    return True
                # a shared block recorded at swap-out lost its content
                # (cache-evicted / rewritten): the restore certificate is
                # void — abandon the host copy and rebuild by recompute
                self._abandon_swap(s, now)
            elif not allow_preempt:
                return False
            else:
                # stall escape hatch: restore blocked on *capacity* with
                # nothing else schedulable — no timer will fix that, so
                # abandon the host copy and rebuild by recompute (deadlock
                # freedom).
                self._abandon_swap(s, now)
        want = min(s.pending_prefill, budget)
        if want <= 0:
            return False
        chunk = self.policy.prefill_chunk(want, avail, c.block_size)
        if chunk <= 0:
            need = self.blocks.blocks_for(want)
            if not self._ensure_blocks(need + reserve, now, in_batch, s,
                                       allow_preempt):
                return False
            avail = max(0, self.blocks.free - reserve)
            chunk = self.policy.prefill_chunk(want, avail, c.block_size)
            if chunk <= 0:
                return False
        need, cow = self._write_need(s, chunk)
        if need + cow > self.blocks.free:
            return False
        self._grow_lease(s, need, cow)
        s.kv_state = KVState.RESIDENT
        prefills.append((s, chunk))
        in_batch.add(s.sid)
        return True

    # ------------------------------------------------------------------
    def _apply(self, work: BatchWork, start: float, end: float,
               elapsed: float) -> None:
        total_tokens = max(1, sum(g for _, g in work.decodes)
                           + sum(cch for _, cch in work.prefills))
        for s, toks in work.swapins:
            s.resident_len = toks
            s.kv_state = KVState.RESIDENT
            s.meta["swapped_len"] = 0
            origin = s.meta.pop("kv_tier", "host")
            cpu_wait = s.meta.pop("swap_cpu_wait_s", 0.0)
            for k in ("swap_pages", "restore_positions", "host_blocks",
                      "host_tokens", "swap_in_future",
                      "swap_cost_s"):        # consumed by run_batch above
                s.meta.pop(k, None)
            if s.meta.pop("host_tier", None) and self.tiers is not None:
                # tier hit: occupancy freed. None (hardened sentinel) means
                # the entry vanished between batch formation and commit
                # (detach race) — the restore already executed from the
                # snapshot, so only the hit accounting is skipped.
                loaded = self.tiers.load(s.sid, end)
                self.bus.emit(ev.SWAP_IN, end, s.sid, tokens=toks,
                              tier=origin, start=start,
                              cpu_wait_s=cpu_wait,
                              accounted=loaded is not None)
            else:
                self.bus.emit(ev.SWAP_IN, end, s.sid, tokens=toks,
                              start=start)
            if s.pending_prefill <= 0:
                s.phase = Phase.DECODING
        for s, chunk in work.prefills:
            s.resident_len += chunk
            s.context_len = max(s.context_len, s.resident_len)
            self.prefill_tokens_computed += chunk
            self._account(s, chunk, elapsed, total_tokens, end)
            self.bus.emit(ev.PREFILL_CHUNK, end, s.sid, start=start,
                          tokens=chunk, round=s.cur_round,
                          resident=s.resident_len)
            if (self.radix is not None and s.cur_round == 0
                    and not s.meta.get("radix_inserted")):
                self._insert_prefix_progress(s)
            if s.pending_prefill <= 0:
                s.phase = Phase.DECODING
        for s, g in work.decodes:
            s.decoded += g
            s.resident_len += g
            s.context_len = max(s.context_len, s.resident_len)
            self._account(s, g, elapsed, total_tokens, end)
            self.bus.emit(ev.DECODE_STEP, end, s.sid, start=start,
                          tokens=g, round=s.cur_round, decoded=s.decoded)
            if not s.first_token_seen:
                s.first_token_seen = True
                s.ttfts.append(end - s.round_submit)
                self.bus.emit(ev.GPU_FIRST_TOKEN, end, s.sid,
                              round=s.cur_round,
                              ttft=end - s.round_submit)
            if s.decoded >= s.cur.decode_tokens:
                self._finish_round(s, end)

    def _account(self, s: Session, tokens: int, elapsed: float,
                 total_tokens: int, end: float) -> None:
        # service charging goes through the policy so the MLFQ sees the
        # actual tokens dispatched this iteration (quantum-by-token) the
        # moment they land, not a round-granular aggregate
        self.policy.charge_service(s, tokens, end)
        s.service_seconds += elapsed * tokens / total_tokens
        s.last_service = end

    def _finish_round(self, s: Session, now: float) -> None:
        self.bus.emit(ev.GPU_END, now, s.sid, round=s.cur_round,
                      blocks=s.kv_blocks)
        if s.cur_round == len(s.rounds) - 1:
            s.phase = Phase.FINISHED
            s.finish_time = now
            self._release_kv(s, now, reason="finished")
            self.active.remove(s)
            self.finished.append(s)
            self.bus.emit(ev.FINISH, now, s.sid, latency=s.e2e_latency)
            return
        # yield to tool; retention decision (four-way under MARS: PIN keeps
        # HBM, OFFLOAD parks in host DRAM, OFFLOAD_DISK parks on NVMe with
        # a staged two-hop restore, FREE recomputes)
        r = s.cur
        action, ttl = self.policy.on_tool_yield(s, now)
        if self.trace_ticks:
            # audit record: the chosen retention action next to the priced
            # alternatives it beat (None fields for policies that don't
            # price) — trace_report surfaces near-miss decisions from these
            audit = getattr(self.policy, "retention_audit", None)
            prices = audit(s, now) if audit is not None else {}
            self.bus.emit(ev.RETENTION, now, s.sid, action=action.name,
                          ttl=ttl, blocks=s.kv_blocks,
                          tokens=s.resident_len, **prices)
        if action == KVAction.PIN and s.kv_blocks > 0:
            s.kv_state = KVState.PINNED
            s.pinned_since = now
            s.pin_ttl = ttl
            self.blocks.pin(s.kv_blocks)
            self.pinned.append(s)
            self.bus.emit(ev.PIN, now, s.sid, blocks=s.kv_blocks, ttl=ttl)
        elif action == KVAction.SWAP and s.kv_blocks > 0:
            # legacy path (InferCept baseline): stock-swapper timing, no
            # tier accounting — the backend charges swap_time() per side.
            # Every block is flagged private (whole-context copy): the
            # stock swapper is blind to sharing.
            s.meta["swapped_len"] = s.resident_len
            s.meta["swap_pages"] = [(bid, self.blocks.gen(bid), True)
                                    for bid in self.blocks.lease(s.sid)]
            freed = self.blocks.release_all(s.sid)
            assert freed == s.kv_blocks
            self.bus.emit(ev.SWAP_OUT, now, s.sid, blocks=s.kv_blocks)
            self._pending_swapouts.append((s, s.resident_len))
            s.kv_blocks = 0
            s.resident_len = 0
            s.kv_state = KVState.SWAPPED
        elif (action in (KVAction.OFFLOAD, KVAction.OFFLOAD_DISK)
              and s.kv_blocks > 0
              and self._offload_kv(s, now,
                                   target=("disk"
                                           if action == KVAction.OFFLOAD_DISK
                                           else "host"))):
            pass
        else:
            self._release_kv(s, now, reason="tool_free")
        s.phase = Phase.TOOL
        s.tool_started = now
        self.tools.start(s, r.tool_kind or "default", r.tool_seconds, now)


# ---------------------------------------------------------------------------
# simulation driver
# ---------------------------------------------------------------------------

def run_sim(engine: Engine, sessions: List[Session], *, max_time: float = 1e7,
            max_ticks: int = 2_000_000, idle_step: float = 0.5
            ) -> Tuple[List[Session], float]:
    """Discrete-event run: injects arrivals, jumps the clock over idle gaps.

    Returns (finished sessions, horizon = last finish or final clock)."""
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i = 0
    now = 0.0
    ticks = 0
    while ticks < max_ticks and now < max_time:
        ticks += 1
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            engine.submit(arrivals[i])
            i += 1
        elapsed, progressed = engine.tick(now)
        if elapsed > 0:
            now += elapsed
            continue
        if progressed:
            continue
        if engine.done() and i >= len(arrivals):
            break
        # idle: jump to the next event
        candidates = []
        t_tool = engine.tools.next_event_time()
        if t_tool is not None:
            candidates.append(t_tool)
        t_timer = engine.next_timer_event(now)
        if t_timer is not None:
            candidates.append(t_timer)
        if i < len(arrivals):
            candidates.append(arrivals[i].arrival_time)
        if engine.waiting:
            candidates.append(now + idle_step)   # let AIMD window recover
        if not candidates:
            break
        now = max(now + 1e-9, min(candidates))
    horizon = max((s.finish_time for s in engine.finished), default=now)
    return engine.finished, horizon


def run_live(engine: Engine, sessions: List[Session], *, timeout: float = 300.0,
             idle_sleep: float = 0.005) -> Tuple[List[Session], float]:
    """Wall-clock run with the live backend + RealToolExecutor.

    ``Session.arrival_time`` is interpreted as seconds from start."""
    import time as _time
    t0 = _time.monotonic()
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i = 0
    while _time.monotonic() - t0 < timeout:
        now = _time.monotonic() - t0
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            engine.submit(arrivals[i])
            i += 1
        elapsed, progressed = engine.tick(now)
        if engine.done() and i >= len(arrivals):
            break
        if not progressed and elapsed == 0.0:
            _time.sleep(idle_sleep)
    horizon = max((s.finish_time for s in engine.finished),
                  default=_time.monotonic() - t0)
    return engine.finished, horizon
