"""Continuous-batching engine with MARS-style decoupled control.

One ``tick`` is one engine iteration:

    1. drain tool completions (unified info stream)      -> sessions resume
    2. O(1) block-pool + backlog probe                   -> telemetry
    3. external admission (policy.admit; MARS = Alg. 1)
    4. pin re-evaluation (adaptive retention / TTL expiry)
    5. batch formation: decodes first (priority order), then chunked
       prefills under the token budget; chunk shrinking; pinned KV is
       reclaimed before any running victim is preempted
    6. backend.run_batch (sim: modeled seconds; jax: wall seconds)
    7. bookkeeping: TTFT per round, tool yields + retention decisions,
       completion accounting

The same loop drives the discrete-event simulator and the live JAX engine —
only the backend, the tool executor, and the clock differ.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.policies import KVAction, MARSConfig, Policy, make_policy
from repro.core.session import KVState, Phase, Round, Session
from repro.core.telemetry import Telemetry, TelemetryConfig
from repro.engine.backend import BatchWork
from repro.engine.block_manager import BlockManager
from repro.engine.tools import SimToolExecutor


@dataclass
class EngineConfig:
    total_kv_blocks: int = 8192
    block_size: int = 32
    token_budget: int = 8192          # per-tick prefill+decode token budget
    max_decode_batch: int = 64
    decode_granularity: int = 8
    cpu_slots: int = 16
    telem: TelemetryConfig = None     # derived from cpu_slots if None

    def __post_init__(self):
        if self.telem is None:
            self.telem = TelemetryConfig(cpu_slots=self.cpu_slots)


class Engine:
    def __init__(self, cfg: EngineConfig, policy_name: str, backend, *,
                 bus: Optional[EventBus] = None, tool_exec=None,
                 mars_cfg: Optional[MARSConfig] = None):
        self.cfg = cfg
        self.bus = bus or EventBus()
        self.backend = backend
        self.blocks = BlockManager(cfg.total_kv_blocks, cfg.block_size)
        self.telem = Telemetry(cfg.telem, self.bus)
        self.policy: Policy = make_policy(policy_name, self.telem, self.bus,
                                          backend, mars_cfg)
        self.tools = tool_exec or SimToolExecutor(cfg.cpu_slots, self.bus)
        self.waiting: List[Session] = []
        self.active: List[Session] = []
        self.pinned: List[Session] = []
        self.finished: List[Session] = []
        self.rejected: List[Session] = []
        self._pending_swapouts: List[Tuple[Session, int]] = []

    # ------------------------------------------------------------------
    def submit(self, s: Session) -> None:
        # admission-reject sessions that can never fit the KV pool (their
        # full context exceeds capacity): a 4xx in a real deployment. Without
        # this they would livelock in the stall hatch forever.
        total_tokens = sum(r.new_input_tokens + r.decode_tokens
                           for r in s.rounds)
        if self.blocks.blocks_for(total_tokens) > 0.98 * self.blocks.total:
            s.phase = Phase.FINISHED
            s.meta["rejected"] = True
            self.rejected.append(s)
            self.bus.emit("reject", s.arrival_time, s.sid,
                          tokens=total_tokens)
            return
        s.phase = Phase.WAITING_ADMIT
        self.waiting.append(s)

    def done(self) -> bool:
        return not self.waiting and not self.active

    def next_timer_event(self) -> Optional[float]:
        """Earliest pinned-KV TTL expiry (finite TTLs only) — the sim driver
        must not jump the clock past policy timers."""
        ts = [s.pinned_since + s.pin_ttl for s in self.pinned
              if s.pin_ttl != float("inf")]
        return min(ts) if ts else None

    def check_invariants(self) -> None:
        """Block-accounting and state-machine invariants (used by tests)."""
        held = sum(s.kv_blocks for s in self.active)
        assert self.blocks.free + held == self.blocks.total, \
            f"block leak: free={self.blocks.free} held={held} " \
            f"total={self.blocks.total}"
        pinned = sum(s.kv_blocks for s in self.pinned)
        assert self.blocks.pinned == pinned, \
            f"pin accounting: {self.blocks.pinned} != {pinned}"
        for s in self.pinned:
            assert s.kv_state == KVState.PINNED and s.phase == Phase.TOOL
        for s in self.active:
            assert s.kv_blocks >= 0
            assert s.resident_len <= s.kv_blocks * self.cfg.block_size
        for s in self.finished:
            assert s.kv_blocks == 0 and s.phase == Phase.FINISHED

    # ------------------------------------------------------------------
    def tick(self, now: float) -> Tuple[float, bool]:
        """Returns (elapsed_seconds, progressed)."""
        progressed = False
        # 1. tool completions
        for s in self.tools.poll(now):
            self._resume_from_tool(s, now)
            progressed = True
        # 2. telemetry probe
        self._probe()
        # 3. admission
        if self.waiting:
            admitted = self.policy.admit(self.waiting, now)
            for s in admitted:
                self.waiting.remove(s)
                self.active.append(s)
                s.phase = Phase.READY_PREFILL
                s.admitted_at = s.last_service = now
                s.round_submit = now
                self.bus.emit(ev.GPU_SUBMIT, now, s.sid, round=s.cur_round,
                              tokens=s.pending_prefill)
                progressed = True
            if admitted:
                self._probe()
        # 4. pin re-evaluation
        for s in list(self.policy.tick_pinned(self.pinned, now)):
            self._release_kv(s, now, reason="pin_revoked")
            progressed = True
        # 5-6. batch formation + execution
        work = self._form_batch(now)
        elapsed = self.backend.run_batch(work, now)
        # 7. bookkeeping
        if not work.empty:
            self._apply(work, now, now + elapsed, elapsed)
            progressed = True
        return elapsed, progressed

    # ------------------------------------------------------------------
    def _probe(self) -> None:
        p = self.blocks.probe()
        waiting_blocks = sum(
            self.blocks.blocks_for(s.pending_prefill)
            for s in self.waiting)
        waiting_blocks += sum(
            self.blocks.blocks_for(s.pending_prefill) - s.kv_blocks
            for s in self.active if s.phase == Phase.READY_PREFILL)
        n_dec = sum(1 for s in self.active if s.phase == Phase.DECODING)
        self.telem.probe_gpu(p.total, p.free, p.pinned, len(self.active),
                             n_dec, max(0, waiting_blocks))

    def _resume_from_tool(self, s: Session, now: float) -> None:
        if s in self.pinned:
            self.pinned.remove(s)
            self.blocks.unpin(s.kv_blocks)
            s.kv_state = KVState.RESIDENT
            self.bus.emit(ev.UNPIN, now, s.sid, warm=True)
        s.cur_round += 1
        s.decoded = 0
        s.first_token_seen = False
        s.phase = Phase.READY_PREFILL
        s.round_submit = now
        self.bus.emit(ev.GPU_SUBMIT, now, s.sid, round=s.cur_round,
                      tokens=s.pending_prefill)

    def _release_kv(self, s: Session, now: float, reason: str) -> None:
        if s.kv_state == KVState.PINNED:
            self.blocks.unpin(s.kv_blocks)
            if s in self.pinned:
                self.pinned.remove(s)
        if s.kv_blocks:
            self.blocks.release(s.kv_blocks)
            self.bus.emit(ev.EVICT, now, s.sid, blocks=s.kv_blocks,
                          reason=reason)
        s.kv_blocks = 0
        s.resident_len = 0
        s.kv_state = KVState.NONE
        release = getattr(self.backend, "release_session", None)
        if release is not None:
            release(s.sid)

    def _preempt(self, s: Session, now: float) -> None:
        s.preemptions += 1
        s.recomputed_tokens += s.resident_len
        if s.phase == Phase.DECODING:
            s.phase = Phase.READY_PREFILL
        self.bus.emit(ev.PREEMPT, now, s.sid, tokens=s.resident_len,
                      blocks=s.kv_blocks)
        self._release_kv(s, now, reason="preempt")

    def _ensure_blocks(self, n: int, now: float, in_batch: Set[int],
                       requester: Session, allow_preempt: bool) -> bool:
        """Free >= n blocks: reclaim pinned contexts first (policy order);
        preempt running/resident victims only if ``allow_preempt`` (decode
        extensions and the stall escape hatch — waiting prefills otherwise
        never preempt, matching vLLM semantics)."""
        if self.blocks.free >= n:
            return True
        for s in self.policy.reclaim_order(list(self.pinned), now):
            self._release_kv(s, now, reason="reclaim")
            if self.blocks.free >= n:
                return True
        if not allow_preempt:
            return False
        victims = [v for v in self.active
                   if v.kv_blocks > 0 and v.sid != requester.sid
                   and v.sid not in in_batch and v.phase != Phase.TOOL]
        for v in self.policy.eviction_order(victims, now, requester):
            self._preempt(v, now)
            if self.blocks.free >= n:
                return True
        return self.blocks.free >= n

    # ------------------------------------------------------------------
    def _form_batch(self, now: float) -> BatchWork:
        c = self.cfg
        ready = [s for s in self.active
                 if s.phase in (Phase.READY_PREFILL, Phase.DECODING)]
        order = self.policy.order(ready, now)
        decodes: List[Tuple[Session, int]] = []
        prefills: List[Tuple[Session, int]] = []
        swapins: List[Tuple[Session, int]] = []
        in_batch: Set[int] = set()
        budget = c.token_budget

        # decodes first: latency-sensitive continuations. Decode extensions
        # may preempt (they must make progress to ever release memory).
        for s in order:
            if s.phase != Phase.DECODING or len(decodes) >= c.max_decode_batch:
                continue
            g = min(c.decode_granularity, s.cur.decode_tokens - s.decoded, budget)
            if g <= 0:
                continue
            need = self.blocks.blocks_for(s.resident_len + g) - s.kv_blocks
            if need > 0:
                if not self._ensure_blocks(need, now, in_batch, s,
                                           allow_preempt=True):
                    continue
                self.blocks.alloc(need)
                s.kv_blocks += need
            decodes.append((s, g))
            in_batch.add(s.sid)
            budget -= g

        # prefills / swap-ins fill the remaining budget from free blocks and
        # reclaimable pins only (no preemption).
        for s in order:
            if s.phase != Phase.READY_PREFILL or budget <= 0:
                continue
            before = len(prefills)
            self._try_prefill(s, now, in_batch, budget, prefills, swapins,
                              allow_preempt=False)
            if len(prefills) > before:
                budget -= prefills[-1][1]
        # stall escape hatch: pool exhausted by partial holders and nothing
        # scheduled -> serve the single top-priority ready session, allowing
        # preemption of strictly junior work (deadlock freedom).
        if not decodes and not prefills and not swapins:
            for s in order:
                if s.phase != Phase.READY_PREFILL:
                    continue
                if self._try_prefill(s, now, in_batch, c.token_budget,
                                     prefills, swapins, allow_preempt=True):
                    break
        swapouts, self._pending_swapouts = self._pending_swapouts, []
        return BatchWork(decodes, prefills, swapins, swapouts)

    def _watermark(self) -> int:
        """Block reserve prefills may not dip into: active decodes extend by
        ~1 block each within a few ticks; without this reserve, greedy chunked
        prefills starve decode extensions into preemption storms (vLLM keeps
        the same kind of allocation watermark)."""
        n_dec = sum(1 for s in self.active if s.phase == Phase.DECODING)
        return max(self.blocks.total // 100, 2 * n_dec)

    def _try_prefill(self, s: Session, now: float, in_batch: Set[int],
                     budget: int, prefills, swapins, allow_preempt: bool) -> bool:
        c = self.cfg
        reserve = 0 if allow_preempt else self._watermark()
        avail = max(0, self.blocks.free - reserve)
        if s.kv_state == KVState.SWAPPED:
            toks = s.meta.get("swapped_len", 0)
            need = self.blocks.blocks_for(toks)
            if need > avail and not self._ensure_blocks(
                    need + reserve, now, in_batch, s, allow_preempt):
                if allow_preempt:        # cannot restore: fall back to recompute
                    s.kv_state = KVState.NONE
                    s.meta["swapped_len"] = 0
                return False
            self.blocks.alloc(need)
            s.kv_blocks += need
            swapins.append((s, toks))
            in_batch.add(s.sid)
            return True
        want = min(s.pending_prefill, budget)
        if want <= 0:
            return False
        chunk = self.policy.prefill_chunk(want, avail, c.block_size)
        if chunk <= 0:
            need = self.blocks.blocks_for(want)
            if not self._ensure_blocks(need + reserve, now, in_batch, s,
                                       allow_preempt):
                return False
            avail = max(0, self.blocks.free - reserve)
            chunk = self.policy.prefill_chunk(want, avail, c.block_size)
            if chunk <= 0:
                return False
        need = self.blocks.blocks_for(s.resident_len + chunk) - s.kv_blocks
        if need > self.blocks.free:
            return False
        if need > 0:
            self.blocks.alloc(need)
            s.kv_blocks += need
        s.kv_state = KVState.RESIDENT
        prefills.append((s, chunk))
        in_batch.add(s.sid)
        return True

    # ------------------------------------------------------------------
    def _apply(self, work: BatchWork, start: float, end: float,
               elapsed: float) -> None:
        total_tokens = max(1, sum(g for _, g in work.decodes)
                           + sum(cch for _, cch in work.prefills))
        for s, toks in work.swapins:
            s.resident_len = toks
            s.kv_state = KVState.RESIDENT
            s.meta["swapped_len"] = 0
            self.bus.emit(ev.SWAP_IN, end, s.sid, tokens=toks)
            if s.pending_prefill <= 0:
                s.phase = Phase.DECODING
        for s, chunk in work.prefills:
            s.resident_len += chunk
            s.context_len = max(s.context_len, s.resident_len)
            self._account(s, chunk, elapsed, total_tokens, end)
            if s.pending_prefill <= 0:
                s.phase = Phase.DECODING
        for s, g in work.decodes:
            s.decoded += g
            s.resident_len += g
            s.context_len = max(s.context_len, s.resident_len)
            self._account(s, g, elapsed, total_tokens, end)
            if not s.first_token_seen:
                s.first_token_seen = True
                s.ttfts.append(end - s.round_submit)
                self.bus.emit(ev.GPU_FIRST_TOKEN, end, s.sid,
                              round=s.cur_round,
                              ttft=end - s.round_submit)
            if s.decoded >= s.cur.decode_tokens:
                self._finish_round(s, end)

    def _account(self, s: Session, tokens: int, elapsed: float,
                 total_tokens: int, end: float) -> None:
        s.service_tokens += tokens
        s.service_seconds += elapsed * tokens / total_tokens
        s.last_service = end

    def _finish_round(self, s: Session, now: float) -> None:
        self.bus.emit(ev.GPU_END, now, s.sid, round=s.cur_round,
                      blocks=s.kv_blocks)
        if s.cur_round == len(s.rounds) - 1:
            s.phase = Phase.FINISHED
            s.finish_time = now
            self._release_kv(s, now, reason="finished")
            self.active.remove(s)
            self.finished.append(s)
            self.bus.emit(ev.FINISH, now, s.sid, latency=s.e2e_latency)
            return
        # yield to tool; retention decision
        r = s.cur
        action, ttl = self.policy.on_tool_yield(s, now)
        if action == KVAction.PIN and s.kv_blocks > 0:
            s.kv_state = KVState.PINNED
            s.pinned_since = now
            s.pin_ttl = ttl
            self.blocks.pin(s.kv_blocks)
            self.pinned.append(s)
            self.bus.emit(ev.PIN, now, s.sid, blocks=s.kv_blocks, ttl=ttl)
        elif action == KVAction.SWAP and s.kv_blocks > 0:
            s.meta["swapped_len"] = s.resident_len
            self.blocks.release(s.kv_blocks)
            self.bus.emit(ev.SWAP_OUT, now, s.sid, blocks=s.kv_blocks)
            self._pending_swapouts.append((s, s.resident_len))
            s.kv_blocks = 0
            s.resident_len = 0
            s.kv_state = KVState.SWAPPED
        else:
            self._release_kv(s, now, reason="tool_free")
        s.phase = Phase.TOOL
        s.tool_started = now
        self.tools.start(s, r.tool_kind or "default", r.tool_seconds, now)


# ---------------------------------------------------------------------------
# simulation driver
# ---------------------------------------------------------------------------

def run_sim(engine: Engine, sessions: List[Session], *, max_time: float = 1e7,
            max_ticks: int = 2_000_000, idle_step: float = 0.5
            ) -> Tuple[List[Session], float]:
    """Discrete-event run: injects arrivals, jumps the clock over idle gaps.

    Returns (finished sessions, horizon = last finish or final clock)."""
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i = 0
    now = 0.0
    ticks = 0
    while ticks < max_ticks and now < max_time:
        ticks += 1
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            engine.submit(arrivals[i])
            i += 1
        elapsed, progressed = engine.tick(now)
        if elapsed > 0:
            now += elapsed
            continue
        if progressed:
            continue
        if engine.done() and i >= len(arrivals):
            break
        # idle: jump to the next event
        candidates = []
        t_tool = engine.tools.next_event_time()
        if t_tool is not None:
            candidates.append(t_tool)
        t_timer = engine.next_timer_event()
        if t_timer is not None:
            candidates.append(t_timer)
        if i < len(arrivals):
            candidates.append(arrivals[i].arrival_time)
        if engine.waiting:
            candidates.append(now + idle_step)   # let AIMD window recover
        if not candidates:
            break
        now = max(now + 1e-9, min(candidates))
    horizon = max((s.finish_time for s in engine.finished), default=now)
    return engine.finished, horizon


def run_live(engine: Engine, sessions: List[Session], *, timeout: float = 300.0,
             idle_sleep: float = 0.005) -> Tuple[List[Session], float]:
    """Wall-clock run with the live backend + RealToolExecutor.

    ``Session.arrival_time`` is interpreted as seconds from start."""
    import time as _time
    t0 = _time.monotonic()
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i = 0
    while _time.monotonic() - t0 < timeout:
        now = _time.monotonic() - t0
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            engine.submit(arrivals[i])
            i += 1
        elapsed, progressed = engine.tick(now)
        if engine.done() and i >= len(arrivals):
            break
        if not progressed and elapsed == 0.0:
            _time.sleep(idle_sleep)
    horizon = max((s.finish_time for s in engine.finished),
                  default=_time.monotonic() - t0)
    return engine.finished, horizon
