"""Deterministic fault injection for the sim engine and tool executors.

The online incident plane (``repro.obs.detect``) is only credible if its
detectors are proven against *known* faults: ``benchmarks/slo_bench.py``
injects each fault class at a scripted sim time and measures detection
latency and precision/recall. Everything here runs on the modeled clock,
so a seeded workload plus a ``FaultPlan`` reproduces the same incident
stream bit-for-bit.

Fault kinds and where they bite:

``stuck_tool``
    The next tool invocation at/after ``at_s`` (or every invocation of a
    targeted ``sid``) runs ``stretch``x its nominal duration — a hung
    build / wedged subprocess. Injected in ``SimToolExecutor.start``
    *after* the honest ``expected_s`` is stamped on ``TOOL_ENQUEUE``, so
    the detector sees the promised duration, not the fault.
``frozen_admission``
    Admission simply stops running between ``at_s`` and ``until_s`` — a
    wedged control plane. Waiting sessions queue; KV frees up; nothing is
    admitted.
``slowed_swap``
    Host-tier PCIe bandwidth divided by ``factor`` inside the window — a
    saturated/degraded link. Swap-ins/-outs serialize for seconds instead
    of milliseconds (the io-plane storm signature).
``freeze_decode``
    A targeted (or the first currently-decoding) session is silently
    excluded from batch formation from ``at_s`` on — the scheduler-bug
    livelock: DECODING phase, never another DECODE_STEP.
``cpu_flood``
    ``n_leases`` foreign leases of ``cpu_work_s`` seconds each land on the
    shared core pool at ``at_s`` — a co-tenant burst. Tool and transfer
    staging work queues behind them (``cpu_backlog`` climbs).

``FaultPlan.install(engine)`` wires the plan into the engine and its sim
tool executor; engines without a plan pay one ``is None`` check per tick.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

FAULT_KINDS = ("stuck_tool", "frozen_admission", "slowed_swap",
               "freeze_decode", "cpu_flood")


@dataclass
class Fault:
    kind: str
    at_s: float                     # activation (modeled seconds)
    until_s: float = math.inf       # deactivation (windowed kinds)
    sid: int = -1                   # target session; -1 = first applicable
    factor: float = 100.0           # slowed_swap bw divisor
    stretch: float = 1e6            # stuck_tool duration multiplier
    cpu_work_s: float = 900.0       # cpu_flood per-lease seconds
    n_leases: int = 64              # cpu_flood lease count
    # bookkeeping
    applied: bool = field(default=False, repr=False)
    hits: int = field(default=0, repr=False)
    _saved: Optional[float] = field(default=None, repr=False)

    def window(self, now: float) -> bool:
        return self.at_s <= now < self.until_s

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


class FaultPlan:
    """A scripted set of faults consulted by the engine's hooks."""

    def __init__(self, faults: List[Fault]):
        self.faults = list(faults)
        # freeze_decode late binding: sid -1 resolves to the first session
        # observed decoding at/after at_s (stamped by the engine hook)
        self._frozen_sids: Dict[int, int] = {}

    # -- wiring ----------------------------------------------------------
    def install(self, engine) -> "FaultPlan":
        engine.faults = self
        tools = getattr(engine, "tools", None)
        if tools is not None and hasattr(tools, "faults"):
            tools.faults = self
        return self

    # -- queries the engine hooks make -----------------------------------
    def active(self, kind: str, now: float) -> bool:
        return any(f.kind == kind and f.window(now) for f in self.faults)

    def freezes(self, sid: int, now: float) -> bool:
        """Is ``sid`` freeze_decode-targeted right now? A -1 target latches
        onto the first sid asked about while the fault is active (the
        caller iterates the decode order, so that is the top decoding
        session at activation) and stays latched."""
        for i, f in enumerate(self.faults):
            if f.kind != "freeze_decode" or not f.window(now):
                continue
            tgt = f.sid if f.sid >= 0 else self._frozen_sids.get(i, -1)
            if tgt < 0:
                self._frozen_sids[i] = tgt = sid
            if tgt == sid:
                f.hits += 1
                return True
        return False

    def tool_duration(self, sid: int, kind: str, duration: float,
                      now: float) -> float:
        """Actual (possibly stretched) service time for a tool invocation.
        A -1 target sticks to the first invocation inside the window."""
        for f in self.faults:
            if f.kind != "stuck_tool" or not f.window(now):
                continue
            if f.sid >= 0 and f.sid != sid:
                continue
            if f.sid < 0 and f.hits > 0:
                continue               # -1 target: first invocation only
            f.hits += 1
            return duration * f.stretch
        return duration

    # -- state transitions the engine applies every tick ------------------
    def apply(self, engine, now: float) -> None:
        for f in self.faults:
            if f.kind == "slowed_swap" and engine.host is not None:
                if f.window(now) and not f.applied:
                    f.applied = True
                    f._saved = engine.host.cfg.pcie_bw
                    engine.host.cfg.pcie_bw = f._saved / max(1.0, f.factor)
                elif not f.window(now) and f.applied and f._saved is not None:
                    f.applied = False
                    engine.host.cfg.pcie_bw = f._saved
                    f._saved = None
            elif f.kind == "cpu_flood":
                if now >= f.at_s and not f.applied:
                    f.applied = True
                    for _ in range(f.n_leases):
                        engine.cpu_pool.submit(now, f.cpu_work_s, sid=-9,
                                               kind="tool", tag="fault_flood",
                                               priority=1)

    def summary(self) -> List[dict]:
        return [{"kind": f.kind, "at_s": f.at_s, "until_s": f.until_s,
                 "sid": f.sid, "hits": f.hits} for f in self.faults]
