"""Live JAX execution backend: the same Engine/tick loop, but ``run_batch``
really runs jit'd prefill/decode steps of a (reduced) model on this host and
returns wall-clock seconds.

Two cache layouts, behind one ``_CacheLayout`` strategy surface:

* **paged** (default) — a *global pool* of KV pages ``(L, P+1, page, Hkv, D)``
  driven end-to-end by ``kvcache.pool.BlockPool`` block tables: the engine
  snapshots each batched session's lease into ``BatchWork.leases`` and the
  backend executes placement from those tables — prefill scatters chunk KV
  into leased pages and attends **gather-free** over the lease (the
  scalar-prefetched table steers the paged flash kernel's page reads in
  place; no dense ``pages[table]`` copy per chunk), decode feeds
  ``(B, max_pages)`` tables to the Pallas ``paged_attention`` kernel with
  the new token's KV write fused into its prologue (via
  ``ops.decode_attention``), copy-on-write events are mirrored as device
  page copies, and host offload moves KV *per block* (only private,
  non-shared blocks cross PCIe; shared prefix blocks are re-referenced on
  device at restore). Radix-shared prefix
  blocks are therefore **physically shared**: a K-session family over one
  repository context occupies ~ceil(L/page) + K*(private tail) pages. Page
  id P (one past the pool) is scratch: padded prefill lanes and idle decode
  lanes park their writes there.

* **dense** — the legacy slot-dense layout (R fixed slots, each a dense
  ``max_len``-token region; position ``max_len - 1`` is the slot's scratch).
  Kept for greedy-decode parity testing against the paged path, and as the
  fallback for attention variants the paged kernel doesn't cover
  (sliding-window alternation, logit softcaps).

Chunks and lane counts are bucketed to powers of two to bound
recompilation. The PerfOracle (recompute_time / prefill_rate / swap_time)
is *calibrated* at startup by timing one prefill chunk, one decode step and
one page/slot round trip — the live analogue of the simulator's analytic
model.
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.session import Session
from repro.engine.backend import BatchWork
from repro.kvcache.disk_tier import DiskFileStore
from repro.kvcache.pool import DeviceBindingMap
from repro.kvcache.swap_stream import (SwapStream, TransferFuture,
                                       resolved_future)
from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.models.transformer import (KVCache, PagedKVCache, lm_decode_paged,
                                      lm_mixed_paged, lm_prefill_paged,
                                      lm_step, supports_paged)


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class JaxBackend:
    name = "jax"

    def __init__(self, cfg: ModelConfig, *, layout: str = "paged",
                 max_slots: int = 8, max_len: int = 1024,
                 total_pages: Optional[int] = None, page_size: int = 32,
                 seed: int = 0, dtype=jnp.float32, async_swap: bool = True,
                 disk_spool: Optional[str] = None):
        assert cfg.family in ("dense", "moe"), "live runner serves LM families"
        assert layout in ("paged", "dense")
        if layout == "paged" and not supports_paged(cfg):
            layout = "dense"          # window/softcap: kernel not applicable
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.dtype = dtype
        self.params = model_zoo.init(cfg, jax.random.PRNGKey(seed), dtype)
        self.layout = layout
        if layout == "paged":
            if total_pages is None:
                total_pages = max(1, max_slots * max_len // page_size)
            self._impl: "_CacheLayout" = _PagedLayout(self, total_pages,
                                                      page_size,
                                                      async_swap=async_swap,
                                                      disk_spool=disk_spool)
        else:
            self._impl = _DenseLayout(self)
        # prefix sharing needs placement to follow block ids physically;
        # a real decoder also needs the last prompt token's logits, so a
        # full prefix hit must still leave >= 1 token to compute
        self.supports_prefix_sharing = (layout == "paged")
        self.requires_last_token_compute = (layout == "paged")
        # async swap stream: D2H drains and H2D prefetches run on a
        # background worker; the engine gates restores on transfer futures
        # and defers sessions whose swap-in is unresolved (dense stays
        # synchronous — it is the serialized parity baseline)
        self.supports_async_swap = (layout == "paged" and async_swap)
        # live-path dispatch timing (repro.obs collects this): cumulative
        # wall seconds per run_batch phase + call counts, so the metrics
        # plane can attribute live tick time to swap launch / CoW mirror /
        # restore / prefill / decode dispatch without tracing every tick
        self.dispatch_stats: Dict[str, float] = {
            "batches": 0, "wall_s": 0.0,
            "swap_out_s": 0.0, "cow_s": 0.0, "swap_in_s": 0.0,
            "prefill_s": 0.0, "decode_s": 0.0,
            "prefill_calls": 0, "decode_calls": 0,
            # fused iteration-level ticks (mixed scheduler on the paged
            # layout): one dispatch covers both prefill packs + decode lanes
            "mixed_s": 0.0, "mixed_calls": 0,
            # analytic prefill HBM traffic: bytes the legacy gather path
            # would have touched vs bytes the in-place (block-table
            # steered) path touches; the paged layout accumulates both per
            # chunk so traces/benches can show the gather-free win
            "prefill_gather_bytes": 0.0, "prefill_inplace_bytes": 0.0,
        }
        self._impl.calibrate()

    # --- engine binding ---------------------------------------------------
    def bind_kv_pool(self, pool) -> None:
        """Engine handshake: validates that the engine's BlockPool fits the
        physical page pool (paged layout) — placement itself always arrives
        through ``BatchWork.leases`` snapshots, never live pool state."""
        self._impl.bind_kv_pool(pool)

    def release_session(self, sid: int) -> None:
        self._impl.release_session(sid)

    def drop_host(self, sid: int) -> None:
        self._impl.drop_host(sid)

    def prefetch_swap_in(self, sid: int) -> Optional[TransferFuture]:
        """Launch the H2D crossing of ``sid``'s private host blocks on the
        background stream (None when nothing private was offloaded). The
        engine defers the session until the returned future resolves, so
        the transfer overlaps the other sessions' compute."""
        return self._impl.prefetch_swap_in(sid)

    def spill_host(self, sid: int) -> Optional[TransferFuture]:
        """NVMe demotion data plane: write ``sid``'s host KV copy to the
        spool directory (freeing the DRAM copy) on the background stream.
        The TieredStore gates the disk entry on the returned future."""
        return self._impl.spill_host(sid)

    def fill_host(self, sid: int) -> Optional[TransferFuture]:
        """NVMe promotion data plane: read ``sid``'s spool file back into
        the host copy ahead of the PCIe swap-in."""
        return self._impl.fill_host(sid)

    def close(self) -> None:
        """Stop the background swap stream (benchmarks create several
        backends per process; daemon threads would otherwise pile up)."""
        self._impl.close()

    # --- oracle (calibrated) ----------------------------------------------
    def _time_once(self, fn) -> float:
        fn()                                      # compile
        t0 = time.monotonic()
        fn()
        return max(1e-6, time.monotonic() - t0)

    def recompute_time(self, n_tokens: int) -> float:
        return n_tokens * self._prefill_s_per_tok

    def prefill_rate(self) -> float:
        return 1.0 / self._prefill_s_per_tok

    def swap_time(self, n_tokens: int) -> float:
        """Measured host<->device KV bandwidth for the copy path."""
        return 1e-3 + n_tokens * self.kv_bytes_per_token() / self._h2d_bw

    def kv_bytes_per_token(self) -> float:
        return self._impl.kv_bytes_per_token()

    # --- execution --------------------------------------------------------
    def run_batch(self, work: BatchWork, now: float) -> float:
        if work.empty:
            return 0.0
        st = self.dispatch_stats
        t0 = time.monotonic()
        impl = self._impl
        # device-write ordering within a tick: D2H reads of swapped-out
        # pages first (their ids may be re-leased to this very batch), then
        # CoW page copies (their sources may be about to be overwritten),
        # then H2D restores, then compute writes. With the async stream the
        # D2H *snapshot* still happens here, in dispatch order (that is
        # what keeps re-leased page ids safe); only the host crossing moves
        # to the worker, and its future joins the swap-completion handshake
        for s, _toks in work.swapouts:
            fut = impl.swap_out(s)
            if fut is not None:
                work.swap_futures[s.sid] = fut
        t1 = time.monotonic()
        impl.apply_cow(work.cow_copies)
        t2 = time.monotonic()
        for s, _toks in work.swapins:
            impl.swap_in(s, work.leases.get(s.sid, ()))
        t3 = time.monotonic()
        fused = (work.mixed and (work.prefills or work.decodes)
                 and hasattr(impl, "run_mixed"))
        if fused:
            # iteration-level tick on the paged layout: prefill packs +
            # decode lanes share ONE jitted dispatch (attributed to
            # mixed_s; the phase split below keeps its legacy buckets for
            # the round path and non-paged layouts)
            impl.run_mixed(work)
            t4 = t5 = time.monotonic()
            st["mixed_s"] += t4 - t3
            st["mixed_calls"] += 1
        else:
            for s, chunk in work.prefills:
                impl.prefill(s, chunk, work.leases.get(s.sid, ()))
            t4 = time.monotonic()
            if work.decodes:
                impl.decodes(work.decodes, work.leases)
            t5 = time.monotonic()
            st["prefill_s"] += t4 - t3
            st["decode_s"] += t5 - t4
        st["batches"] += 1
        st["swap_out_s"] += t1 - t0
        st["cow_s"] += t2 - t1
        st["swap_in_s"] += t3 - t2
        st["wall_s"] += t5 - t0
        st["prefill_calls"] += len(work.prefills)
        st["decode_calls"] += len(work.decodes)
        return t5 - t0

    def swap_stream_stats(self) -> Optional[Dict]:
        """Background stream counters (None for the dense layout, which
        swaps synchronously) — absorbed by the metrics registry."""
        stream = getattr(self._impl, "stream", None)
        return stream.stats() if stream is not None else None

    def bind_cpu_pool(self, pool) -> None:
        """Engine hook: the swap stream's worker holds a core from the
        shared pool while a crossing executes, so pool gauges account real
        transfer CPU next to the tool threads. No-op without a stream."""
        stream = getattr(self._impl, "stream", None)
        if stream is not None:
            stream.cpu_pool = pool

    # --- deterministic synthetic context ----------------------------------
    def _context_ids(self, s: Session) -> List[int]:
        """Token ids are *content-addressed*: round-0 chunks derive from
        their prefix-hash keys (same chunk key => same tokens, so physically
        shared prefix pages hold exactly the bytes every family member would
        have computed) and everything beyond draws by (sid, absolute
        position) — re-entrant, so growing ``prefill_target`` after decode
        appends never re-draws earlier positions from the stream start."""
        ids = s.meta.setdefault("context_ids", [])
        target = s.prefill_target
        V = self.cfg.vocab_size
        hashes = s.meta.get("prefix_hashes")
        if hashes:
            round0 = sum(n for _, n in hashes)
            if len(ids) < round0:          # (no decode happened yet: round 0
                ids.clear()                #  must fully prefill first)
                for key, n in hashes:
                    rng = np.random.default_rng(
                        zlib.crc32(repr(key).encode()))
                    ids.extend(int(x) for x in rng.integers(2, V, size=n))
        while len(ids) < target:
            pos = len(ids)
            ids.append(int(np.random.default_rng((s.sid, pos))
                           .integers(2, V)))
        return ids


# ---------------------------------------------------------------------------
# layout strategies
# ---------------------------------------------------------------------------

class _CacheLayout:
    """Physical KV placement strategy: prefill/decode/swap/CoW execution.

    ``swap_out`` may return the transfer future of an asynchronously
    launched D2H drain (None == completed synchronously); ``prefetch_swap_in``
    launches the H2D crossing ahead of the restore (None == nothing private
    to move)."""

    def bind_kv_pool(self, pool) -> None: ...
    def calibrate(self) -> None: ...
    def kv_bytes_per_token(self) -> float: ...
    def release_session(self, sid: int) -> None: ...
    def drop_host(self, sid: int) -> None: ...
    def swap_out(self, s: Session) -> Optional[TransferFuture]: ...
    def swap_in(self, s: Session, lease) -> None: ...
    def prefetch_swap_in(self, sid: int) -> Optional[TransferFuture]:
        return None
    def spill_host(self, sid: int) -> Optional[TransferFuture]:
        return None           # layouts without an NVMe data plane: modeled
    def fill_host(self, sid: int) -> Optional[TransferFuture]:
        return None
    def apply_cow(self, copies) -> None: ...
    def prefill(self, s: Session, chunk: int, lease) -> None: ...
    def decodes(self, decodes, leases) -> None: ...
    def close(self) -> None: ...


class _PagedLayout(_CacheLayout):
    """Global page pool driven by BlockPool block tables.

    With ``async_swap`` (default) the host crossings run on a background
    :class:`SwapStream`: ``swap_out`` gathers the private pages into a
    device-side staging snapshot (in dispatch order — safe against this
    very tick re-leasing the ids) and hands the D2H drain to the worker;
    ``prefetch_swap_in`` uploads the host copy to standalone device buffers
    ahead of the restore, so ``swap_in`` only pays a device-side scatter.
    """

    def __init__(self, backend: JaxBackend, total_pages: int, page: int,
                 async_swap: bool = True, disk_spool: Optional[str] = None):
        self.b = backend
        self.page = page
        self.total_pages = total_pages
        # NVMe spill data plane (kvcache.disk_tier.DiskFileStore), created
        # lazily on the first spill so host-only runs never touch disk
        self._spool_dir = disk_spool
        self._filestore: Optional[DiskFileStore] = None
        self.binding = DeviceBindingMap(total_pages)
        self.scratch = self.binding.scratch_page
        cfg, dtype = backend.cfg, backend.dtype
        self.cache = PagedKVCache.zeros(cfg, total_pages + 1, page, dtype)
        # host copies of offloaded private blocks:
        # sid -> (k (L, n, page, Hkv, D), v (...)) in swap-record order
        self._host: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.stream: Optional[SwapStream] = (SwapStream(n_buffers=2)
                                             if async_swap else None)
        # async state, all guarded by _mu: in-flight D2H futures (drained
        # by a same-tick swap_in), prefetched device buffers, and the sids
        # whose host state was dropped while a transfer was in flight (the
        # straggler job must not resurrect them). FIFO on the stream keeps
        # a drop -> re-offload sequence correct: the stale drain lands
        # before the fresh one.
        self._mu = threading.Lock()
        self._d2h: Dict[int, TransferFuture] = {}
        self._prefetch: Dict[int, Tuple[jax.Array, jax.Array]] = {}
        self._dropped: set = set()

        def _decode(params, cache, tokens, positions, tables, lengths,
                    wpid, woff):
            logits, cache = lm_decode_paged(cfg, params, cache, tokens,
                                            positions, tables, lengths,
                                            wpid, woff)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        def _prefill(params, cache, tokens, positions, table, wpid, woff,
                     kv_len, last_idx):
            logits, cache = lm_prefill_paged(cfg, params, cache, tokens,
                                             positions, table, wpid, woff,
                                             kv_len)
            nxt = jnp.argmax(logits[0, last_idx], axis=-1).astype(jnp.int32)
            return nxt, cache

        def _copy_page(cache, src, dst):
            return PagedKVCache(cache.k.at[:, dst].set(cache.k[:, src]),
                                cache.v.at[:, dst].set(cache.v[:, src]))

        def _mixed(params, cache, p_toks, p_pos, p_tables, p_wpid, p_woff,
                   p_kvlen, p_last, d_toks, d_pos, d_tables, d_lens,
                   d_wpid, d_woff):
            return lm_mixed_paged(cfg, params, cache, p_toks, p_pos,
                                  p_tables, p_wpid, p_woff, p_kvlen, p_last,
                                  d_toks, d_pos, d_tables, d_lens, d_wpid,
                                  d_woff)

        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))
        self._prefill_fn = jax.jit(_prefill, donate_argnums=(1,))
        self._copy_fn = jax.jit(_copy_page, donate_argnums=(0,))
        self._mixed_fn = jax.jit(_mixed, donate_argnums=(1,))

    # --- binding / oracle -------------------------------------------------
    def bind_kv_pool(self, pool) -> None:
        assert pool.block_size == self.page, \
            f"pool block_size {pool.block_size} != page {self.page}"
        assert pool.total <= self.total_pages, \
            f"pool of {pool.total} blocks exceeds {self.total_pages} pages"

    def kv_bytes_per_token(self) -> float:
        k = self.cache.k             # (L, P, page, Hkv, D)
        per_tok = 2 * k.size // (k.shape[1] * k.shape[2]) * k.dtype.itemsize
        return float(per_tok)

    def calibrate(self) -> None:
        b = self.b
        C = 64
        toks = np.zeros((1, C), np.int32)
        pos = np.arange(C, dtype=np.int32)[None]
        Np = _bucket(C // self.page + 1, lo=2)
        table = np.full((Np,), self.scratch, np.int32)
        wpid = np.full((C,), self.scratch, np.int32)
        woff = np.arange(C, dtype=np.int32) % self.page

        def pf():
            nxt, self.cache = self._prefill_fn(
                b.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(table), jnp.asarray(wpid), jnp.asarray(woff),
                jnp.asarray(C, jnp.int32), C - 1)
            nxt.block_until_ready()

        b._prefill_s_per_tok = b._time_once(pf) / C
        B = _bucket(b.max_slots, lo=1)
        tok1 = np.zeros((B,), np.int32)
        pos1 = np.zeros((B,), np.int32)
        tables = np.full((B, 2), self.scratch, np.int32)
        lens = np.ones((B,), np.int32)
        wp = np.full((B,), self.scratch, np.int32)
        wo = np.zeros((B,), np.int32)

        def df():
            nxt, self.cache = self._decode_fn(
                b.params, self.cache, jnp.asarray(tok1), jnp.asarray(pos1),
                jnp.asarray(tables), jnp.asarray(lens), jnp.asarray(wp),
                jnp.asarray(wo))
            nxt.block_until_ready()

        b._decode_s_per_step = b._time_once(df)
        page_bytes = 2 * self.cache.k[:, 0].size * self.cache.k.dtype.itemsize

        def xfer():
            host = (jax.device_get(self.cache.k[:, 0]),
                    jax.device_get(self.cache.v[:, 0]))
            dev = (jax.device_put(host[0]), jax.device_put(host[1]))
            dev[0].block_until_ready()
            dev[1].block_until_ready()

        # round trip moves page_bytes each way; swap_time charges one
        # direction per call, so price it at the two-direction average
        b._h2d_bw = max(1e6, 2 * page_bytes / b._time_once(xfer))

    # --- session / host state ---------------------------------------------
    def release_session(self, sid: int) -> None:
        pass                         # placement is the engine's lease state

    def drop_host(self, sid: int) -> None:
        with self._mu:
            self._host.pop(sid, None)
            self._prefetch.pop(sid, None)
            self._d2h.pop(sid, None)
            if self.stream is not None:
                self._dropped.add(sid)   # in-flight jobs must not resurrect
        if self._filestore is not None:
            self._filestore.delete(sid)

    def close(self) -> None:
        if self.stream is not None:
            self.stream.close()
        if self._filestore is not None:
            self._filestore.close()
            self._filestore = None

    # --- NVMe spill/fill (TieredStore data plane) -------------------------
    def _store(self) -> DiskFileStore:
        if self._filestore is None:
            self._filestore = DiskFileStore(self._spool_dir)
        return self._filestore

    def spill_host(self, sid: int) -> Optional[TransferFuture]:
        """Write ``sid``'s host KV copy to the spool and free the DRAM
        copy. Submitted on the stream when one runs (FIFO: a demotion
        chained behind this tick's D2H drain lands after the bytes do);
        synchronous otherwise. Empty records (nothing private crossed
        PCIe) keep their (None, None) marker in DRAM — there is nothing
        to free and the restore path expects the marker."""
        store = self._store()

        def write() -> bool:
            with self._mu:
                if self.stream is not None and sid in self._dropped:
                    return False
                host = self._host.get(sid)
            if host is None or host[0] is None:
                return False           # nothing private: marker stays
            store.write(sid, host[0], host[1])
            with self._mu:
                if self.stream is not None and sid in self._dropped:
                    store.delete(sid)  # raced a detach: no resurrection
                    return False
                self._host.pop(sid, None)
            return True

        if self.stream is None:
            write()
            return None
        return self.stream.submit(write, sid=sid, direction="h2n")

    def fill_host(self, sid: int) -> Optional[TransferFuture]:
        """Read ``sid``'s spool file back into the host copy (promotion
        first hop); the engine's normal prefetch/swap-in path then moves
        it over PCIe."""
        store = self._store()

        def read() -> bool:
            data = store.read(sid)
            if data is None:
                return False           # empty record: marker never spilled
            with self._mu:
                if self.stream is not None and sid in self._dropped:
                    store.delete(sid)
                    return False
                self._host[sid] = data
            store.delete(sid)
            return True

        if self.stream is None:
            read()
            return None
        return self.stream.submit(read, sid=sid, direction="n2h")

    # --- swap: per-block host offload -------------------------------------
    def swap_out(self, s: Session) -> Optional[TransferFuture]:
        """D2H-copy only the blocks flagged private in the engine's swap
        record; shared/indexed prefix blocks stay resident on device. With
        the stream, the page gather (a device-side snapshot, ordered by
        dispatch before any later cache writes) happens here and the host
        crossing drains on the worker; the returned future joins the
        engine's swap-completion handshake."""
        rec = s.meta.get("swap_pages")
        if rec is None:
            return None
        sid = s.sid
        pids = [self.binding.page_of(bid) for bid, _gen, private in rec
                if private]
        if not pids:
            if self.stream is None:
                self._host[sid] = (None, None)
                return None

            def mark_empty():
                # through the FIFO, not inline: a stale drain for this sid
                # still queued from a dropped earlier offload must land
                # (and be discarded) before the guard is disarmed
                with self._mu:
                    self._dropped.discard(sid)
                    self._host[sid] = (None, None)

            return self.stream.submit(mark_empty, sid=sid, direction="d2h")
        # pad the gather to a power-of-two page count with the scratch page
        # (whose content is garbage by design): swap records grow a little
        # every round, and an unbucketed gather/scatter would XLA-compile a
        # fresh shape per round — in the tick, on the critical path
        idx = self._swap_index(pids)
        if self.stream is None:
            self._host[sid] = (jax.device_get(self.cache.k[:, idx]),
                               jax.device_get(self.cache.v[:, idx]))
            return None
        slot = self.stream.staging.acquire()     # double-buffer backpressure
        k_snap = self.cache.k[:, idx]            # device-side staging gather
        v_snap = self.cache.v[:, idx]
        with self._mu:
            self._dropped.discard(sid)

        def drain():
            try:
                host = (np.asarray(k_snap), np.asarray(v_snap))
                with self._mu:
                    if sid not in self._dropped:
                        self._host[sid] = host
                return host
            finally:
                self.stream.staging.release(slot)

        fut = self.stream.submit(drain, sid=sid, direction="d2h")
        with self._mu:
            self._d2h[sid] = fut
        return fut

    def prefetch_swap_in(self, sid: int) -> Optional[TransferFuture]:
        """Upload ``sid``'s private host blocks to standalone device
        buffers on the worker; the later ``swap_in`` then scatters them
        into the freshly leased pages device-side. Only callable once the
        D2H drain resolved (``HostTier.ready`` gates the engine)."""
        with self._mu:
            host = self._host.get(sid)
        if self.stream is None or host is None or host[0] is None:
            return None
        # slot acquired on the submitting thread (both directions): every
        # slot holder is then a job already in the FIFO ahead of any
        # waiter, so the worker never blocks on a slot it must itself free
        slot = self.stream.staging.acquire()

        def upload():
            try:
                dk = jax.device_put(host[0])
                dv = jax.device_put(host[1])
                dk.block_until_ready()
                dv.block_until_ready()
                with self._mu:
                    if sid not in self._dropped:
                        self._prefetch[sid] = (dk, dv)
                return (dk, dv)
            finally:
                self.stream.staging.release(slot)

        return self.stream.submit(upload, sid=sid, direction="h2d")

    def swap_in(self, s: Session, lease) -> None:
        """Restore private blocks into the freshly allocated pages at
        ``meta["restore_positions"]``; reacquired shared blocks need no
        transfer — their pages were never rewritten (gen-certified). A
        prefetched restore scatters device-resident buffers; otherwise the
        H2D upload happens inline (after waiting out a same-tick D2H)."""
        sid = s.sid
        with self._mu:
            d2h = self._d2h.pop(sid, None)
        if d2h is not None and not d2h.done():
            d2h.result()      # same-tick out->in: restore behind the drain
        with self._mu:
            pre = self._prefetch.pop(sid, None)
            host = self._host.pop(sid, None)
        if host is None or host[0] is None:
            return
        restore = s.meta.get("restore_positions", [])
        pids = [self.binding.page_of(lease[i]) for i in restore]
        assert _bucket(len(pids), lo=2) == host[0].shape[1], \
            f"restore mismatch: {len(pids)} pages, {host[0].shape[1]} copies"
        # scatter through the same scratch-padded bucket the drain gathered
        # (pad lanes dump their garbage back onto the scratch page)
        idx = self._swap_index(pids)
        dk, dv = pre if pre is not None else (jnp.asarray(host[0]),
                                              jnp.asarray(host[1]))
        self.cache = PagedKVCache(self.cache.k.at[:, idx].set(dk),
                                  self.cache.v.at[:, idx].set(dv))

    def _swap_index(self, pids: List[int]) -> np.ndarray:
        """Swap gather/scatter page index, padded to a power-of-two width
        with the scratch page so the eager ops compile O(log) shapes."""
        out = np.full((_bucket(len(pids), lo=2),), self.scratch, np.int32)
        out[:len(pids)] = pids
        return out

    def apply_cow(self, copies) -> None:
        """Mirror the tick's copy-on-write events as device page copies, in
        log order (a later copy may source a page an earlier one freed)."""
        for _sid, src, dst in copies:
            self.cache = self._copy_fn(self.cache,
                                       self.binding.page_of(src),
                                       self.binding.page_of(dst))

    # --- compute ----------------------------------------------------------
    def prefill(self, s: Session, chunk: int, lease) -> None:
        b, page = self.b, self.page
        ids = b._context_ids(s)
        start = s.resident_len
        segment = ids[start:start + chunk]
        C = _bucket(len(segment))
        # gathered view must cover the lease and end in a scratch page (the
        # padded lanes' parking position)
        n_need = max(len(lease), -(-(start + C) // page)) + 1
        Np = _bucket(n_need, lo=2)
        table = self.binding.table(lease, width=Np)
        toks = np.zeros((1, C), np.int32)
        toks[0, :len(segment)] = segment
        pos = np.full((C,), Np * page - 1, np.int32)
        pos[:len(segment)] = np.arange(start, start + len(segment))
        wpid = np.full((C,), self.scratch, np.int32)
        woff = np.zeros((C,), np.int32)
        for i in range(len(segment)):
            wpid[i] = self.binding.page_of(lease[(start + i) // page])
            woff[i] = (start + i) % page
        nxt, self.cache = self._prefill_fn(
            b.params, self.cache, jnp.asarray(toks), jnp.asarray(pos[None]),
            jnp.asarray(table), jnp.asarray(wpid), jnp.asarray(woff),
            jnp.asarray(start + len(segment), jnp.int32), len(segment) - 1)
        s.meta["next_token"] = int(nxt)
        # analytic HBM bytes-touched accounting for this chunk (surfaced as
        # dispatch_stats counters -> metrics probe / Perfetto counter track
        # / bench figure): the legacy gather path pays 3x the gathered view
        # (gather read + dense-copy write + attention read) plus ~3x the
        # chunk (dense write, slice, scatter); the in-place path pays the
        # view once (attention read) plus the chunk scatter
        tok_bytes = self.kv_bytes_per_token()
        ctx_toks, chunk_toks = Np * page, C
        st = b.dispatch_stats
        st["prefill_gather_bytes"] += \
            (3 * ctx_toks + 3 * chunk_toks) * tok_bytes
        st["prefill_inplace_bytes"] += (ctx_toks + chunk_toks) * tok_bytes

    def decodes(self, decodes, leases) -> None:
        b, page = self.b, self.page
        live = [(s, leases[s.sid], g) for s, g in decodes]
        B = _bucket(len(live), lo=1)
        maxp = _bucket(max(len(l) for _, l, _ in live), lo=1)
        tables = np.full((B, maxp), self.scratch, np.int32)
        for i, (_s, lease, _g) in enumerate(live):
            tables[i, :len(lease)] = [self.binding.page_of(x) for x in lease]
        g_max = max(g for _, _, g in live)
        jtables = jnp.asarray(tables)
        for step in range(g_max):
            toks = np.zeros((B,), np.int32)
            pos = np.zeros((B,), np.int32)
            lens = np.ones((B,), np.int32)
            wpid = np.full((B,), self.scratch, np.int32)
            woff = np.zeros((B,), np.int32)
            active: List[Tuple[Session, int]] = []
            for i, (s, lease, g) in enumerate(live):
                if step >= g:
                    continue
                p = s.resident_len + step
                toks[i] = s.meta.get("next_token", 1)
                pos[i] = p
                lens[i] = p + 1
                wpid[i] = self.binding.page_of(lease[p // page])
                woff[i] = p % page
                active.append((s, i))
            if not active:
                break
            nxt, self.cache = self._decode_fn(
                b.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
                jtables, jnp.asarray(lens), jnp.asarray(wpid),
                jnp.asarray(woff))
            nxt = np.asarray(nxt)
            for s, i in active:
                tok = int(nxt[i])
                s.meta.setdefault("generated", []).append(tok)
                s.meta["next_token"] = tok
                s.meta.setdefault("context_ids", []).append(tok)

    # --- fused mixed iteration --------------------------------------------
    def run_mixed(self, work: BatchWork) -> None:
        """One iteration-level tick as a SINGLE jitted dispatch: every
        prefill chunk becomes a pack of the scanned prefill stage and every
        decode lane advances one token, over one shared cache round-trip —
        the per-session prefill dispatches and the sequential decode-step
        loop of the round path collapse into one ``lm_mixed_paged`` call.
        Pack shape (C, Np), lane count B and pack count P are all bucketed
        to powers of two; slack packs/lanes park on the scratch page (the
        same construction ``calibrate`` warms)."""
        b, page = self.b, self.page
        leases = work.leases
        packs = []
        for s, chunk in work.prefills:
            ids = b._context_ids(s)
            start = s.resident_len
            packs.append((s, ids[start:start + chunk], start,
                          leases.get(s.sid, ())))
        assert all(g == 1 for _, g in work.decodes), \
            "mixed tick: decode lanes carry exactly one token"
        decodes = [(s, leases[s.sid]) for s, _g in work.decodes]

        C = _bucket(max((len(seg) for _, seg, _, _ in packs), default=1))
        n_need = 2
        for _, seg, start, lease in packs:
            n_need = max(n_need,
                         max(len(lease), -(-(start + C) // page)) + 1)
        Np = _bucket(n_need, lo=2)
        P = _bucket(len(packs), lo=1) if packs else 0
        # slack packs mirror the calibrate construction: all-scratch table,
        # full-C scratch write, kv_len C — garbage in, garbage discarded
        p_toks = np.zeros((P, 1, C), np.int32)
        p_pos = np.full((P, 1, C), Np * page - 1, np.int32)
        p_tables = np.full((P, Np), self.scratch, np.int32)
        p_wpid = np.full((P, C), self.scratch, np.int32)
        p_woff = np.tile(np.arange(C, dtype=np.int32) % page, (P, 1))
        p_kvlen = np.full((P,), C, np.int32)
        p_last = np.full((P,), C - 1, np.int32)
        for j, (s, seg, start, lease) in enumerate(packs):
            p_toks[j, 0, :len(seg)] = seg
            p_pos[j, 0, :len(seg)] = np.arange(start, start + len(seg))
            p_tables[j] = self.binding.table(lease, width=Np)
            p_woff[j] = 0
            for i in range(len(seg)):
                p_wpid[j, i] = self.binding.page_of(lease[(start + i) // page])
                p_woff[j, i] = (start + i) % page
            p_kvlen[j] = start + len(seg)
            p_last[j] = len(seg) - 1

        B = _bucket(len(decodes), lo=1) if decodes else 0
        maxp = _bucket(max((len(l) for _, l in decodes), default=1), lo=1)
        d_toks = np.zeros((B,), np.int32)
        d_pos = np.zeros((B,), np.int32)
        d_tables = np.full((B, maxp), self.scratch, np.int32)
        d_lens = np.ones((B,), np.int32)
        d_wpid = np.full((B,), self.scratch, np.int32)
        d_woff = np.zeros((B,), np.int32)
        for i, (s, lease) in enumerate(decodes):
            p = s.resident_len
            d_tables[i, :len(lease)] = [self.binding.page_of(x)
                                        for x in lease]
            d_toks[i] = s.meta.get("next_token", 1)
            d_pos[i] = p
            d_lens[i] = p + 1
            d_wpid[i] = self.binding.page_of(lease[p // page])
            d_woff[i] = p % page

        p_next, d_next, self.cache = self._mixed_fn(
            b.params, self.cache, jnp.asarray(p_toks), jnp.asarray(p_pos),
            jnp.asarray(p_tables), jnp.asarray(p_wpid), jnp.asarray(p_woff),
            jnp.asarray(p_kvlen), jnp.asarray(p_last), jnp.asarray(d_toks),
            jnp.asarray(d_pos), jnp.asarray(d_tables), jnp.asarray(d_lens),
            jnp.asarray(d_wpid), jnp.asarray(d_woff))
        if packs:
            p_next = np.asarray(p_next)
            for j, (s, _seg, _start, _lease) in enumerate(packs):
                s.meta["next_token"] = int(p_next[j])
        if decodes:
            d_next = np.asarray(d_next)
            for i, (s, _lease) in enumerate(decodes):
                tok = int(d_next[i])
                s.meta.setdefault("generated", []).append(tok)
                s.meta["next_token"] = tok
                s.meta.setdefault("context_ids", []).append(tok)
        # per-chunk analytic HBM accounting, same model as prefill()
        tok_bytes = self.kv_bytes_per_token()
        st = b.dispatch_stats
        for _ in packs:
            st["prefill_gather_bytes"] += \
                (3 * Np * page + 3 * C) * tok_bytes
            st["prefill_inplace_bytes"] += (Np * page + C) * tok_bytes


class _DenseLayout(_CacheLayout):
    """Slot-dense legacy layout: R fixed slots of ``max_len`` dense tokens.

    Position ``max_len - 1`` of every slot is scratch: idle decode lanes
    park their writes there, so sessions may use at most ``max_len - 1``
    tokens. Host offload copies whole slots (no block granularity)."""

    def __init__(self, backend: JaxBackend):
        self.b = backend
        cfg, dtype = backend.cfg, backend.dtype
        self.cache = model_zoo.cache_zeros(cfg, backend.max_slots,
                                           backend.max_len, dtype)
        self._slots: Dict[int, int] = {}          # sid -> slot
        self._free_slots = list(range(backend.max_slots))
        self._host_kv: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

        def _decode(params, cache, tokens, positions):
            logits, cache = lm_step(cfg, params, cache, tokens[:, None],
                                    positions[:, None])
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return nxt, cache

        def _prefill(params, cache, tokens, positions, slot, last_idx):
            # single-slot chunked prefill: slice the slot's cache region,
            # step, write back. tokens/positions: (1, C).
            ks = jax.lax.dynamic_slice_in_dim(cache.k, slot, 1, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(cache.v, slot, 1, axis=1)
            logits, sub = lm_step(cfg, params, KVCache(ks, vs), tokens,
                                  positions)
            k = jax.lax.dynamic_update_slice_in_dim(cache.k, sub.k, slot,
                                                    axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(cache.v, sub.v, slot,
                                                    axis=1)
            nxt = jnp.argmax(logits[0, last_idx], axis=-1).astype(jnp.int32)
            return nxt, KVCache(k, v)

        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))
        self._prefill_fn = jax.jit(_prefill, donate_argnums=(1,))

    # --- oracle -----------------------------------------------------------
    def kv_bytes_per_token(self) -> float:
        k = self.cache.k
        # (L, S, T, H, D) slot-dense layout: bytes/token = all-but-T dims
        per_tok = 2 * k.size // (k.shape[1] * k.shape[2]) * k.dtype.itemsize
        return float(per_tok)

    def calibrate(self) -> None:
        b = self.b
        toks = jnp.zeros((1, 64), jnp.int32)
        pos = jnp.arange(64, dtype=jnp.int32)[None]

        def pf():
            nxt, self.cache = self._prefill_fn(b.params, self.cache, toks,
                                               pos, 0, 63)
            nxt.block_until_ready()

        b._prefill_s_per_tok = b._time_once(pf) / 64
        tok1 = jnp.zeros((b.max_slots,), jnp.int32)
        pos1 = jnp.full((b.max_slots,), b.max_len - 1, jnp.int32)

        def df():
            nxt, self.cache = self._decode_fn(b.params, self.cache, tok1,
                                              pos1)
            nxt.block_until_ready()

        b._decode_s_per_step = b._time_once(df)
        slot_bytes = 2 * self.cache.k[:, 0].size * self.cache.k.dtype.itemsize

        def xfer():
            host = (jax.device_get(self.cache.k[:, 0]),
                    jax.device_get(self.cache.v[:, 0]))
            dev = (jax.device_put(host[0]), jax.device_put(host[1]))
            dev[0].block_until_ready()
            dev[1].block_until_ready()

        b._h2d_bw = max(1e6, 2 * slot_bytes / b._time_once(xfer))

    # --- slots ------------------------------------------------------------
    def _slot_of(self, sid: int) -> int:
        if sid not in self._slots:
            assert self._free_slots, "live runner out of slots"
            self._slots[sid] = self._free_slots.pop()
        return self._slots[sid]

    def release_session(self, sid: int) -> None:
        slot = self._slots.pop(sid, None)
        if slot is not None:
            self._free_slots.append(slot)

    def drop_host(self, sid: int) -> None:
        self._host_kv.pop(sid, None)

    # --- whole-slot host offload ------------------------------------------
    def swap_out(self, s: Session) -> None:
        slot = self._slots.get(s.sid)
        if slot is None:
            return
        self._host_kv[s.sid] = (jax.device_get(self.cache.k[:, slot]),
                                jax.device_get(self.cache.v[:, slot]))
        self.release_session(s.sid)

    def swap_in(self, s: Session, lease) -> None:
        host = self._host_kv.pop(s.sid, None)
        if host is None:
            return
        slot = self._slot_of(s.sid)
        k = self.cache.k.at[:, slot].set(jnp.asarray(host[0]))
        v = self.cache.v.at[:, slot].set(jnp.asarray(host[1]))
        self.cache = KVCache(k, v)

    def apply_cow(self, copies) -> None:
        pass                  # no physical sharing: nothing aliases a slot

    # --- compute ----------------------------------------------------------
    def prefill(self, s: Session, chunk: int, lease) -> None:
        b = self.b
        slot = self._slot_of(s.sid)
        ids = b._context_ids(s)
        start = s.resident_len
        segment = ids[start:start + chunk]
        bk = _bucket(len(segment))
        toks = np.zeros((1, bk), np.int32)
        toks[0, :len(segment)] = segment
        pos = np.arange(start, start + bk, dtype=np.int32)
        # padded lanes park at the scratch position
        pos[len(segment):] = b.max_len - 1
        nxt, self.cache = self._prefill_fn(
            b.params, self.cache, jnp.asarray(toks),
            jnp.asarray(pos[None]), slot, len(segment) - 1)
        s.meta["next_token"] = int(nxt)

    def decodes(self, decodes, leases) -> None:
        b = self.b
        g_max = max(g for _, g in decodes)
        scratch = b.max_len - 1
        for step in range(g_max):
            toks = np.zeros((b.max_slots,), np.int32)
            pos = np.full((b.max_slots,), scratch, np.int32)
            live = []
            for s, g in decodes:
                if step >= g:
                    continue
                slot = self._slot_of(s.sid)
                toks[slot] = s.meta.get("next_token", 1)
                pos[slot] = s.resident_len + step
                live.append((s, slot))
            if not live:
                break
            nxt, self.cache = self._decode_fn(
                b.params, self.cache, jnp.asarray(toks), jnp.asarray(pos))
            nxt = np.asarray(nxt)
            for s, slot in live:
                tok = int(nxt[slot])
                s.meta.setdefault("generated", []).append(tok)
                s.meta["next_token"] = tok
                s.meta.setdefault("context_ids", []).append(tok)
