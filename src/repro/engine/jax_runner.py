"""Live JAX execution backend: the same Engine/tick loop, but ``run_batch``
really runs jit'd prefill/decode steps of a (reduced) model on this host and
returns wall-clock seconds.

Slot model: R fixed sequence slots, each with a dense per-slot KV region of
``max_len`` tokens (jit-stable shapes). The BlockManager still governs
*capacity* in blocks; physical placement here is slot-dense (the Pallas
``paged_attention`` kernel demonstrates block-table placement at the kernel
level — see DESIGN.md §3). Prefill chunks are bucketed to powers of two to
bound recompilation, and chunked prefill attends to the previously cached
prefix via ``lm_step`` (exact semantics, not chunk-local attention).

The PerfOracle (recompute_time / prefill_rate / swap_time) is *calibrated* at
startup by timing one prefill chunk and one decode step — the live analogue
of the simulator's analytic model.

Position ``max_len - 1`` of every slot is scratch: idle decode lanes park
their writes there, so sessions may use at most ``max_len - 1`` tokens.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.session import Session
from repro.engine.backend import BatchWork
from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.models.transformer import KVCache, lm_step


def _bucket(n: int) -> int:
    b = 32
    while b < n:
        b *= 2
    return b


class JaxBackend:
    name = "jax"

    def __init__(self, cfg: ModelConfig, *, max_slots: int = 8,
                 max_len: int = 1024, seed: int = 0, dtype=jnp.float32):
        assert cfg.family in ("dense", "moe"), "live runner serves LM families"
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.dtype = dtype
        self.params = model_zoo.init(cfg, jax.random.PRNGKey(seed), dtype)
        self.cache = model_zoo.cache_zeros(cfg, max_slots, max_len, dtype)
        self._slots: Dict[int, int] = {}          # sid -> slot
        self._free_slots = list(range(max_slots))
        self._host_kv: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

        def _decode(params, cache, tokens, positions):
            logits, cache = lm_step(cfg, params, cache, tokens[:, None],
                                    positions[:, None])
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return nxt, cache

        def _prefill(params, cache, tokens, positions, slot, last_idx):
            # single-slot chunked prefill: slice the slot's cache region,
            # step, write back. tokens/positions: (1, C).
            ks = jax.lax.dynamic_slice_in_dim(cache.k, slot, 1, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(cache.v, slot, 1, axis=1)
            logits, sub = lm_step(cfg, params, KVCache(ks, vs), tokens,
                                  positions)
            k = jax.lax.dynamic_update_slice_in_dim(cache.k, sub.k, slot, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(cache.v, sub.v, slot, axis=1)
            nxt = jnp.argmax(logits[0, last_idx], axis=-1).astype(jnp.int32)
            return nxt, KVCache(k, v)

        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))
        self._prefill_fn = jax.jit(_prefill, donate_argnums=(1,))
        self._calibrate()

    # --- slots ------------------------------------------------------------
    def _slot_of(self, sid: int) -> int:
        if sid not in self._slots:
            assert self._free_slots, "live runner out of slots"
            self._slots[sid] = self._free_slots.pop()
        return self._slots[sid]

    def release_session(self, sid: int) -> None:
        slot = self._slots.pop(sid, None)
        if slot is not None:
            self._free_slots.append(slot)

    # --- oracle (calibrated) -----------------------------------------------
    def _time_once(self, fn) -> float:
        fn()                                      # compile
        t0 = time.monotonic()
        fn()
        return max(1e-6, time.monotonic() - t0)

    def _calibrate(self) -> None:
        toks = jnp.zeros((1, 64), jnp.int32)
        pos = jnp.arange(64, dtype=jnp.int32)[None]

        def pf():
            nxt, self.cache = self._prefill_fn(self.params, self.cache, toks,
                                               pos, 0, 63)
            nxt.block_until_ready()

        self._prefill_s_per_tok = self._time_once(pf) / 64
        tok1 = jnp.zeros((self.max_slots,), jnp.int32)
        pos1 = jnp.full((self.max_slots,), self.max_len - 1, jnp.int32)

        def df():
            nxt, self.cache = self._decode_fn(self.params, self.cache, tok1,
                                              pos1)
            nxt.block_until_ready()

        self._decode_s_per_step = self._time_once(df)
        # host<->device bandwidth for the offload tier: one slot round trip
        slot_bytes = 2 * self.cache.k[:, 0].size * self.cache.k.dtype.itemsize

        def xfer():
            host = (jax.device_get(self.cache.k[:, 0]),
                    jax.device_get(self.cache.v[:, 0]))
            dev = (jax.device_put(host[0]), jax.device_put(host[1]))
            dev[0].block_until_ready()
            dev[1].block_until_ready()

        # full round trip moves slot_bytes each way; swap_time charges one
        # direction per call, so price it at the two-direction average rather
        # than extrapolating D2H bandwidth onto H2D transfers
        self._h2d_bw = max(1e6, 2 * slot_bytes / self._time_once(xfer))

    def recompute_time(self, n_tokens: int) -> float:
        return n_tokens * self._prefill_s_per_tok

    def prefill_rate(self) -> float:
        return 1.0 / self._prefill_s_per_tok

    def swap_time(self, n_tokens: int) -> float:
        """Measured host<->device KV bandwidth for the slot-copy path."""
        return 1e-3 + n_tokens * self.kv_bytes_per_token() / self._h2d_bw

    def kv_bytes_per_token(self) -> float:
        k = self.cache.k
        # (L, S, T, H, D) slot-dense layout: bytes per token = all-but-T dims
        per_tok = 2 * k.size // (k.shape[1] * k.shape[2]) * k.dtype.itemsize
        return float(per_tok)

    # --- host offload (the live analogue of kvcache.host_tier) -----------
    def _swap_out(self, s: Session) -> None:
        slot = self._slots.get(s.sid)
        if slot is None:
            return
        self._host_kv[s.sid] = (jax.device_get(self.cache.k[:, slot]),
                                jax.device_get(self.cache.v[:, slot]))
        self.release_session(s.sid)

    def _swap_in(self, s: Session) -> None:
        host = self._host_kv.pop(s.sid, None)
        if host is None:
            return
        slot = self._slot_of(s.sid)
        k = self.cache.k.at[:, slot].set(jnp.asarray(host[0]))
        v = self.cache.v.at[:, slot].set(jnp.asarray(host[1]))
        self.cache = KVCache(k, v)

    def drop_host(self, sid: int) -> None:
        self._host_kv.pop(sid, None)

    # --- execution ------------------------------------------------------------
    def run_batch(self, work: BatchWork, now: float) -> float:
        if work.empty:
            return 0.0
        t0 = time.monotonic()
        for s, _toks in work.swapouts:
            self._swap_out(s)
        for s, _toks in work.swapins:
            self._swap_in(s)
        for s, chunk in work.prefills:
            self._run_prefill(s, chunk)
        if work.decodes:
            self._run_decodes(work.decodes)
        return time.monotonic() - t0

    # ------------------------------------------------------------------
    def _context_ids(self, s: Session) -> List[int]:
        ids = s.meta.setdefault("context_ids", [])
        target = s.prefill_target
        rng = np.random.default_rng(s.sid)
        while len(ids) < target:
            ids.append(int(rng.integers(2, self.cfg.vocab_size)))
        return ids

    def _run_prefill(self, s: Session, chunk: int) -> None:
        slot = self._slot_of(s.sid)
        ids = self._context_ids(s)
        start = s.resident_len
        segment = ids[start:start + chunk]
        b = _bucket(len(segment))
        toks = np.zeros((1, b), np.int32)
        toks[0, :len(segment)] = segment
        pos = np.arange(start, start + b, dtype=np.int32)
        # padded lanes park at the scratch position
        pos[len(segment):] = self.max_len - 1
        nxt, self.cache = self._prefill_fn(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(pos[None]), slot, len(segment) - 1)
        s.meta["next_token"] = int(nxt)

    def _run_decodes(self, decodes: List[Tuple[Session, int]]) -> None:
        g_max = max(g for _, g in decodes)
        scratch = self.max_len - 1
        for step in range(g_max):
            toks = np.zeros((self.max_slots,), np.int32)
            pos = np.full((self.max_slots,), scratch, np.int32)
            live = []
            for s, g in decodes:
                if step >= g:
                    continue
                slot = self._slot_of(s.sid)
                toks[slot] = s.meta.get("next_token", 1)
                pos[slot] = s.resident_len + step
                live.append((s, slot))
            if not live:
                break
            nxt, self.cache = self._decode_fn(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos))
            nxt = np.asarray(nxt)
            for s, slot in live:
                tok = int(nxt[slot])
                s.meta.setdefault("generated", []).append(tok)
                s.meta["next_token"] = tok
                s.meta.setdefault("context_ids", []).append(tok)
