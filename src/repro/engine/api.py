"""OpenAI-style serving front-end (paper §5 Implementation).

The paper integrates MARS by augmenting the OpenAI-compatible request schema
with stable per-session metadata (persistent ``job_id``, tool-transition
markers) propagated into the engine. This module is that layer for the live
engine: an in-process API that accepts chat-completion-shaped requests tagged
with a ``job_id``, maintains session continuity across rounds (the KV
residency decisions key off the same session), and returns futures.

    api = ServingAPI(engine)
    fut = api.submit(job_id="task-1", prompt_tokens=[...], max_tokens=32,
                     tool_call={"kind": "terminal", "fn": run_tests})
    api.pump(now);  result = fut.result()   # {'tokens': [...], 'ttft': ...}

A deployment would put this behind HTTP; the schema and session plumbing are
the substance, transport is not.
"""
from __future__ import annotations

import itertools
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core import events as ev
from repro.core.session import Phase, Round, Session, make_session
from repro.engine.engine import Engine


@dataclass
class ChatRequest:
    """One LLM round of an agentic job (OpenAI-compatible shape + MARS
    session metadata extensions)."""
    job_id: str
    prompt_tokens: List[int]          # tokenized new context this round
    max_tokens: int = 64
    tool_call: Optional[Dict[str, Any]] = None   # {'kind', 'fn'|'seconds'}
    final: bool = False               # last round of the job


class ServingAPI:
    """Session-continuity front-end over a live Engine.

    Each ``job_id`` maps to one engine Session whose rounds are appended as
    requests arrive — this is what lets the scheduler treat the multi-round
    job as one stateful workflow (warm KV across rounds) instead of
    independent requests.
    """

    def __init__(self, engine: Engine):
        self.engine = engine
        self._jobs: Dict[str, Session] = {}
        self._futures: Dict[tuple, Future] = {}
        self._lock = threading.Lock()
        engine.bus.subscribe(ev.GPU_END, self._on_round_end)
        engine.bus.subscribe("reject", self._on_reject)

    # ------------------------------------------------------------------
    def submit(self, req: ChatRequest, now: float = 0.0) -> Future:
        """Queue one round; returns a Future of {'tokens', 'ttft', 'round'}."""
        with self._lock:
            fut: Future = Future()
            tool_kind = None
            tool_seconds = 0.0
            if req.tool_call is not None and not req.final:
                tool_kind = req.tool_call.get("kind", "default")
                tool_seconds = float(req.tool_call.get("seconds", 0.0))
            rnd = Round(new_input_tokens=max(1, len(req.prompt_tokens)),
                        decode_tokens=req.max_tokens,
                        tool_kind=tool_kind, tool_seconds=tool_seconds)
            s = self._jobs.get(req.job_id)
            fresh = s is None
            if fresh:
                s = make_session(now, [rnd], ideal_time=1.0)
                s.meta["job_id"] = req.job_id
                s.meta["context_ids"] = list(req.prompt_tokens)
                s.meta["tool_fns"] = {}
                self._jobs[req.job_id] = s
            else:
                # append the next round to the live session (continuity)
                assert s.phase != Phase.FINISHED, f"job {req.job_id} finished"
                s.rounds.append(rnd)
                s.meta.setdefault("context_ids", []).extend(req.prompt_tokens)
            round_idx = len(s.rounds) - 1
            if req.tool_call is not None and "fn" in req.tool_call:
                s.meta["tool_fns"][round_idx] = req.tool_call["fn"]
            # register the future before submission: capacity rejection fires
            # synchronously inside engine.submit
            self._futures[(req.job_id, round_idx)] = fut
            if fresh:
                self.engine.submit(s)
            return fut

    # ------------------------------------------------------------------
    def _on_round_end(self, e) -> None:
        s = self._sid_session(e.sid)
        if s is None:
            return
        key = (s.meta.get("job_id"), e.data.get("round"))
        fut = self._futures.pop(key, None)
        if fut is not None and not fut.done():
            gen = s.meta.get("generated", [])
            r = e.data.get("round", 0)
            n = s.rounds[r].decode_tokens
            fut.set_result({
                "job_id": key[0], "round": r,
                "tokens": gen[-n:] if gen else [],
                "ttft": s.ttfts[r] if r < len(s.ttfts) else None,
            })

    def _on_reject(self, e) -> None:
        s = self._sid_session(e.sid)
        if s is None:
            return
        for key, fut in list(self._futures.items()):
            if key[0] == s.meta.get("job_id") and not fut.done():
                fut.set_exception(RuntimeError(
                    f"job {key[0]} rejected: context exceeds KV capacity"))
                self._futures.pop(key, None)

    def _sid_session(self, sid: int) -> Optional[Session]:
        for s in self._jobs.values():
            if s.sid == sid:
                return s
        return None

    # ------------------------------------------------------------------
    def active_jobs(self) -> List[str]:
        return [j for j, s in self._jobs.items() if s.phase != Phase.FINISHED]
