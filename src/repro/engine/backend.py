"""Execution backends.

``SimBackend`` — analytic service-time model under a virtual clock. One
engine tick executes a *mixed batch* (Sarathi-style: decode quanta piggyback
prefill chunks); its service time is the max of the compute term (all FLOPs)
and the memory term (weight read once + KV traffic), which naturally models
prefill/decode interference and the benefit of chunking.

``JaxBackend`` lives in ``jax_runner.py`` (real jit'd steps, wall clock).
Both expose the ``PerfOracle`` the policies need (recompute/swap times).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core.session import Session
from repro.models import perf_model as pm
from repro.models.config import ModelConfig


@dataclass
class BatchWork:
    """One engine tick's worth of GPU work.

    ``leases`` snapshots every batched session's KV placement (sid -> block
    ids in lease order == token order) at formation time, and ``cow_copies``
    lists the tick's copy-on-write events (sid, src_bid, dst_bid) in order —
    a physical backend executes placement straight from these and never
    re-derives it from the pool (whose state may already have moved on,
    e.g. swap-out releases the lease before the bytes are copied off).

    ``swap_futures`` is the swap-completion handshake: an async backend
    fills it during ``run_batch`` with the transfer future of each swap-out
    it launched on its background stream (sid -> future), and the engine
    attaches those to the host tier right after the batch returns so
    ``HostTier.ready`` gates restores on the real drain, not the model.

    ``mixed`` marks an iteration-level continuous-batching tick (decode
    lanes carry exactly one token each): a physical backend should fuse the
    prefill chunks and decode lanes into a single dispatch rather than
    looping per-session.
    """
    decodes: List[Tuple[Session, int]]        # (session, n_tokens this quantum)
    prefills: List[Tuple[Session, int]]       # (session, chunk_tokens)
    swapins: List[Tuple[Session, int]]        # (session, tokens restored)
    swapouts: List[Tuple[Session, int]] = None  # (session, tokens offloaded)
    leases: Dict[int, Tuple[int, ...]] = None   # sid -> block table snapshot
    cow_copies: List[Tuple[int, int, int]] = None  # (sid, src, dst) in order
    swap_futures: Dict[int, object] = None      # sid -> TransferFuture (D2H)
    mixed: bool = False                         # iteration-level tick

    def __post_init__(self):
        if self.swapouts is None:
            self.swapouts = []
        if self.leases is None:
            self.leases = {}
        if self.cow_copies is None:
            self.cow_copies = []
        if self.swap_futures is None:
            self.swap_futures = {}

    @property
    def empty(self) -> bool:
        return not (self.decodes or self.prefills or self.swapins
                    or self.swapouts)


class SimBackend:
    name = "sim"
    # block accounting IS the KV state in the sim, so attaching to shared
    # radix blocks needs no data movement; a live backend must copy the
    # prefix KV into the attaching session's cache to claim this
    supports_prefix_sharing = True

    def __init__(self, cfg: ModelConfig, hw: pm.HardwareSpec, tp: int = 1):
        self.cfg = cfg
        self.hw = hw
        self.tp = tp
        # cache analytic constants
        self._w_bytes = 2.0 * cfg.param_count(active_only=True)
        self._flops_tok_base = pm.flops_per_token(cfg, 0)

    # --- PerfOracle -----------------------------------------------------------
    def recompute_time(self, n_tokens: int) -> float:
        if n_tokens <= 0:
            return 0.0
        return pm.prefill_time(self.cfg, self.hw, n_tokens, 0, self.tp)

    def swap_time(self, n_tokens: int) -> float:
        return pm.swap_time(self.cfg, self.hw, n_tokens)

    def kv_bytes_per_token(self) -> float:
        """KV footprint per token — sizes the host tier's PCIe cost model."""
        return float(pm.kv_bytes_per_token(self.cfg))

    def prefill_rate(self) -> float:
        """Sustainable prefill tokens/s at a typical agentic context."""
        f = pm.flops_per_token(self.cfg, 64_000)
        return self.hw.peak_flops * self.tp * self.hw.mfu_prefill / f

    # --- execution ---------------------------------------------------------------
    def run_batch(self, work: BatchWork, now: float) -> float:
        """Modeled seconds for one mixed continuous-batching iteration."""
        if work.empty:
            return 0.0
        hw, cfg, tp = self.hw, self.cfg, self.tp
        flops = 0.0
        kv_read = 0.0
        kv_write = 0.0
        for s, g in work.decodes:
            flops += g * pm.flops_per_token(cfg, s.resident_len)
            kv_read += g * pm.kv_cache_bytes(cfg, s.resident_len)
            kv_write += g * pm.kv_bytes_per_token(cfg)
        for s, chunk in work.prefills:
            flops += chunk * pm.flops_per_token(cfg, s.resident_len + chunk // 2)
            kv_write += chunk * pm.kv_bytes_per_token(cfg)
            kv_read += pm.kv_cache_bytes(cfg, s.resident_len)   # attend prefix
        t_compute = flops / (hw.peak_flops * tp * hw.mfu_prefill)
        t_memory = (self._w_bytes / tp + kv_read + kv_write) / \
            (hw.hbm_bw * tp * hw.mbu_decode)
        t = max(t_compute, t_memory)
        # Host<->device KV transfers: the legacy swap path (stock vLLM
        # swapper) serializes with the engine step in both directions. The
        # host tier's batched-DMA path overlaps swap-OUT with the tool
        # phase (HostTier.ready gates restorability), while swap-IN still
        # serializes (decode needs the KV) at the tier's engineered rate
        # (engine stamps ``meta["swap_cost_s"]``).
        for s, toks in work.swapins:
            cost = s.meta.pop("swap_cost_s", None)
            t += self.swap_time(toks) if cost is None else cost
        for s, toks in work.swapouts:
            if not s.meta.get("host_tier"):
                t += self.swap_time(toks)
        return t
