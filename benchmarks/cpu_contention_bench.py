"""CPU-contention benchmark: goodput collapse under a tool-heavy mix and
its recovery with CPU-aware admission.

The workload is the profile where host cores, not the GPU, are the scarce
resource: sessions whose rounds draw from ``TOOL_HEAVY_MIX`` (test suites
and dense shell activity) while the engine's shared :class:`CpuPool` has
only a handful of cores. Every tool execution, swap staging copy and spool
I/O leases from that one pool, so a tool burst queues transfers behind it
and vice versa.

Two MARS configurations over the identical workload and pool. Both arms
run with the *reactive* AIMD CPU flag neutralized (``cpu_overload_factor``
pushed out of reach), so the only CPU feedback in play is the new
predictive pool term — a clean A/B of the admission change itself:

* **naive** — ``cpu_queue_bound_s = inf`` (the default): admission sizes
  the window on GPU/KV pressure only. Admitted sessions pile tool work
  onto the saturated pool, interference stretches every service time,
  core-queue waits stack onto every round, and sessions blow their SLOs
  together — the goodput collapse.

* **cpu_aware** — a finite ``cpu_queue_bound_s``: admission projects the
  standing tool-CPU commitments of admitted sessions (plus the pool's
  scheduled work-in-system) onto the cores and defers admits that would
  push the projected queueing delay past the bound (tool-light sessions
  behind them still pass).

The derived row reports the goodput recovery plus the structural evidence
(core queue-wait seconds actually accumulated under naive; admits
actually deferred under aware) that the recovery comes from the CPU term
and not noise.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
from repro.core.admission import ControlPlaneConfig
from repro.core.cpu_pool import CpuPoolConfig
from repro.core.goodput import summarize
from repro.core.policies import MARSConfig
from repro.core.telemetry import TelemetryConfig
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100
from repro.workloads.generator import TOOL_HEAVY_MIX, WorkloadSpec, generate

# few cores under many concurrent test/build tools: the contended regime
CPU_CORES = 4
# co-running work on a saturated pool stretches up to ~1.6x: build/test
# processes thrash shared caches and memory bandwidth, not just timeslices
INTERFERENCE = 0.8
# CPU-aware bound: defer an admit once the projected core-queue delay
# (standing commitments + scheduled work over cores) crosses this
CPU_BOUND_S = 40.0


def _workload(n_sessions: int, rate: float, seed: int = 29) -> WorkloadSpec:
    return WorkloadSpec(regime="S-ILR1", arrival_rate=rate,
                        n_sessions=n_sessions, seed=seed,
                        max_context=131_072,
                        tool_mix=TOOL_HEAVY_MIX,
                        tool_time_scale=0.5)


def _run(spec: WorkloadSpec, *, bound_s: float, name: str) -> Dict:
    # a slow-opening admission window (small w_init, unit additive step)
    # keeps a standing arrival queue, so most admits happen while the pool
    # is already hot — the regime where the CPU term can actually act
    mars = MARSConfig(control=ControlPlaneConfig(
        w_init=2.0, cpu_queue_bound_s=bound_s))
    eng = Engine(EngineConfig(total_kv_blocks=12_000, block_size=32,
                              token_budget=8192, max_decode_batch=64,
                              decode_granularity=8, cpu_slots=CPU_CORES,
                              telem=TelemetryConfig(
                                  cpu_slots=CPU_CORES,
                                  cpu_overload_factor=1e9),
                              cpu_pool=CpuPoolConfig(
                                  cores=CPU_CORES,
                                  interference=INTERFERENCE)),
                 "mars", SimBackend(QWEN3, H100), mars_cfg=mars)
    sessions = generate(spec, QWEN3, H100)
    finished, horizon = run_sim(eng, sessions, max_time=4e5)
    eng.check_invariants()
    stats = summarize(finished, horizon)
    pool = eng.cpu_pool.stats()
    return {
        "figure": "cpu_contention",
        "name": name,
        "n_finished": len(finished),
        "goodput3_req_s": round(stats["goodput"][3.0], 5),
        "mean_s": round(stats["latency"].mean, 1),
        "p90_s": round(stats["latency"].p90, 1),
        "cpu_cores": pool["cores"],
        "cpu_queue_wait_s": round(pool["queue_wait_total_s"], 1),
        "cpu_busy_s": round(sum(pool["busy_s"].values()), 1),
        "cpu_max_backlog": pool["max_backlog"],
        "cpu_deferred": eng.policy.control.cpu_deferred,
    }


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    """``dry`` (CI smoke): a minimal tool-heavy workload through both
    admission modes — exercises pool queueing, interference stretching and
    the admission CPU term without timing-grade sizes."""
    n = 16 if dry else (24 if quick else 48)
    rate = 1.0
    spec = _workload(n, rate=rate)
    rows: List[Dict] = []
    for name, bound in (("naive", float("inf")), ("cpu_aware", CPU_BOUND_S)):
        rows.append(_run(spec, bound_s=bound, name=name))
    naive, aware = rows[0], rows[1]
    rows.append({
        "figure": "cpu_contention",
        "name": "cpu_aware_recovery",
        "naive_goodput": naive["goodput3_req_s"],
        "aware_goodput": aware["goodput3_req_s"],
        # collapse can drive the naive arm to exactly zero goodput, so the
        # ratio floors its denominator at 1e-4 req/s (~one SLO-met session
        # per 2.8 h) instead of exploding
        "goodput_ratio": round(aware["goodput3_req_s"] /
                               max(1e-4, naive["goodput3_req_s"]), 3),
        "queue_wait_ratio": round(naive["cpu_queue_wait_s"] /
                                  max(1e-9, aware["cpu_queue_wait_s"]), 3),
        # structural evidence: the pool really queued under the naive run,
        # and the aware run really exercised the deferral path
        "naive_queue_wait_s": naive["cpu_queue_wait_s"],
        "deferred": aware["cpu_deferred"],
    })
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: minimal tool-heavy workload, "
                             "naive vs CPU-aware admission")
