"""Cross-replica prefix reuse benchmark: family-aware placement.

Drives a multi-replica sim — ``ClusterRouter`` + R MARS engines on one
lockstep clock — with shared-prefix session families (many agents on the
same repository context, Qwen3-Coder-30B / H100), twice:

* **digest_blind** — heartbeats carry no radix digest: placement is
  load + per-session affinity only, so families scatter and every replica
  pays its own cold prefill of the same repository context;
* **digest_on**   — heartbeats carry each replica's radix-root digest and
  ``_score`` adds the longest-indexed-prefix-match term: one replica
  accumulates each family, later members attach to already-built blocks.

Reported per run: cluster prefill tokens actually computed, prefix hit
tokens, family placement spread (replicas per family), cluster prefix hit
rate, completion counts and mean latency. The headline row computes the
cluster prefill-token savings and asserts (non-``--dry``) the acceptance
bar: with digests on, every family lands on <= 2 replicas and cluster
prefill tokens drop >= 25% at equal admission throughput.

``--dry`` (CI smoke): tiny cluster, both configurations, no assertions.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3, CONTEXT_LIMIT
from repro.distributed.router import ClusterRouter, RouterConfig
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig
from repro.models.perf_model import H100
from repro.workloads.generator import WorkloadSpec, generate


def _workload(n_sessions: int, n_families: int, rate: float,
              seed: int = 13) -> WorkloadSpec:
    # dense family structure on small-regime prompts, repository-context
    # dominated (few rounds, round 0 carries most of the volume, 90% of it
    # family-shared): placement decides whether that context is built once
    # per cluster or once per replica
    return WorkloadSpec(regime="S-ILR1", arrival_rate=rate,
                        n_sessions=n_sessions, seed=seed,
                        max_context=CONTEXT_LIMIT, n_families=n_families,
                        first_round_frac=0.85, shared_frac=0.9, dup_frac=0.1,
                        rounds_lo=2, rounds_hi=5)


def _engine(blocks: int) -> Engine:
    return Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                               token_budget=8192, max_decode_batch=64,
                               decode_granularity=8, cpu_slots=16),
                  "mars", SimBackend(QWEN3, H100))


def _run_cluster(name: str, spec: WorkloadSpec, *, n_replicas: int,
                 blocks: int, digests_on: bool, max_time: float = 2e5,
                 max_steps: int = 500_000) -> Dict:
    router = ClusterRouter(RouterConfig())
    engines: Dict[str, Engine] = {}
    for i in range(n_replicas):
        rid = f"r{i}"
        engines[rid] = _engine(blocks)
        router.register(rid, engines[rid], now=0.0)
        router.heartbeat(rid, kv_utilization=0.0, tool_backlog=0,
                         active_sessions=0, step_latency=1e-3, now=0.0)
    arrivals = sorted(generate(spec, QWEN3, H100),
                      key=lambda s: s.arrival_time)
    fam_homes: Dict[int, set] = {}
    now, i = 0.0, 0
    for _step in range(max_steps):
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            s = arrivals[i]
            rid = router.place(s, now=now)
            fam = s.meta.get("family")
            if fam is not None and rid is not None:
                fam_homes.setdefault(fam, set()).add(rid)
            i += 1
        progressed, max_el = False, 0.0
        for rid, eng in engines.items():
            el, prog = eng.tick(now)
            progressed |= prog or el > 0
            max_el = max(max_el, el)
            # homogeneous cluster: report a steady step latency (per-tick
            # elapsed varies 100x with batch composition, and the induced
            # straggler-penalty noise would randomize placement for *both*
            # configurations — straggler handling is not what this bench
            # measures)
            router.heartbeat(
                rid, kv_utilization=eng.telem.kv_utilization,
                tool_backlog=eng.tools.backlog,
                active_sessions=len(eng.active),
                step_latency=1e-3,
                radix_digest=eng.radix_digest() if digests_on else None,
                now=now)
        if i >= len(arrivals) and all(e.done() for e in engines.values()):
            break
        if now > max_time:
            break
        if progressed:
            now += max(max_el, 0.05)
            continue
        cands = [arrivals[i].arrival_time] if i < len(arrivals) else []
        for eng in engines.values():
            t = eng.tools.next_event_time()
            if t is not None:
                cands.append(t)
            t = eng.next_timer_event(now)
            if t is not None:
                cands.append(t)
            if eng.waiting:
                cands.append(now + 0.5)   # let the AIMD window recover
        if not cands:
            break
        now = max(now + 1e-9, min(cands))
    for eng in engines.values():
        eng.check_invariants()
    finished = [s for e in engines.values() for s in e.finished]
    spreads = [len(v) for v in fam_homes.values()] or [0]
    queries = sum(e.radix.queries for e in engines.values() if e.radix)
    hits = sum(e.radix.hits for e in engines.values() if e.radix)
    cluster = router.cluster_prefix_stats()
    return {
        "figure": "cross_replica",
        "name": name,
        "n_replicas": n_replicas,
        "n_finished": len(finished),
        "mean_s": round(float(np.mean([s.e2e_latency for s in finished])), 1)
            if finished else None,
        "prefill_tokens_computed": sum(e.prefill_tokens_computed
                                       for e in engines.values()),
        "prefix_hit_tokens": sum(e.prefix_hit_tokens
                                 for e in engines.values()),
        "mean_family_spread": round(float(np.mean(spreads)), 2),
        "max_family_spread": int(max(spreads)),
        "cluster_prefix_hit_rate": round(hits / max(1, queries), 3),
        # the router-side aggregate only sees heartbeat digests, so it is 0
        # for the digest-blind run — that asymmetry is the exported signal
        "router_prefix_hit_rate": round(
            cluster["cluster_prefix_hit_rate"], 3),
        "horizon_s": round(now, 1),
    }


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    if dry:
        n, fams, reps, blocks, rate = 10, 2, 3, 8_000, 0.6
    elif quick:
        n, fams, reps, blocks, rate = 36, 4, 6, 16_000, 0.5
    else:
        n, fams, reps, blocks, rate = 72, 6, 8, 16_000, 0.8
    spec = _workload(n, fams, rate)
    rows: List[Dict] = []
    blind = _run_cluster("digest_blind", spec, n_replicas=reps,
                         blocks=blocks, digests_on=False)
    on = _run_cluster("digest_on", spec, n_replicas=reps,
                      blocks=blocks, digests_on=True)
    rows += [blind, on]
    saved = 1.0 - on["prefill_tokens_computed"] / \
        max(1, blind["prefill_tokens_computed"])
    head = {
        "figure": "cross_replica",
        "name": "reuse",
        "prefill_tokens_saved_frac": round(saved, 3),
        "blind_mean_spread": blind["mean_family_spread"],
        "on_mean_spread": on["mean_family_spread"],
        "on_max_spread": on["max_family_spread"],
        "prefix_hit_rate": on["cluster_prefix_hit_rate"],
        "equal_throughput": on["n_finished"] == blind["n_finished"],
    }
    rows.append(head)
    if not dry:
        assert on["n_finished"] == blind["n_finished"], \
            f"admission throughput drifted: {on['n_finished']} vs " \
            f"{blind['n_finished']} finished"
        assert on["max_family_spread"] <= 2, \
            f"family spread {on['max_family_spread']} replicas — " \
            f"digest placement not accumulating families"
        assert saved >= 0.25, \
            f"cluster prefill savings {saved:.1%} < 25% — cross-replica " \
            f"reuse not materializing"
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: tiny cluster, both configurations")
