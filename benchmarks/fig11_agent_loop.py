"""Fig. 11/12: OpenHands-style full-loop deployment (H200): framework
overheads (chat-template + RPC + sandbox stages) shift latency outside the
serving backend; tool durations get more diverse/irregular. Also reports the
task completion rate (Fig. 12): scheduling must not change task outcomes."""
from benchmarks.common import POLICIES, fmt_row, run_point, speedup_vs_best_baseline
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H200
from repro.workloads import generator


def run(quick: bool = True):
    rows = []
    n = 20 if quick else 40
    # framework realism: higher tool-time variance + per-round fixed stages
    old_scale = dict(generator.TOOL_KINDS)
    try:
        # framework stack adds latency and variance to every tool phase
        generator.TOOL_KINDS = {
            k: (p, ms * 1.3, ss * 1.3, ml * 1.3, sl * 1.3)
            for k, (p, ms, ss, ml, sl) in old_scale.items()}
        for regime in ["ILR-1", "ILR-2", "ILR-3", "ILR-4"]:
            point = []
            for policy in POLICIES:
                s = run_point(CONFIG, H200, policy, regime, 0.2, n,
                              max_context=CONTEXT_LIMIT, cpu_slots=6)
                r = fmt_row(s)
                r["figure"] = "fig11"
                # all sessions that finish complete their task (rate = n/n);
                # timeouts would show up as unfinished sessions
                r["completion_rate"] = round(r["n"] / n, 3)
                point.append(r)
            sp = speedup_vs_best_baseline(point)
            for r in point:
                r["mars_speedup_mean"] = sp.get("speedup")
            rows.extend(point)
    finally:
        generator.TOOL_KINDS = old_scale
    return rows
