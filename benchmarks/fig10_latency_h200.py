"""Fig. 10: H200 testbed — gains persist on stronger hardware."""
from benchmarks.common import POLICIES, fmt_row, run_point, speedup_vs_best_baseline
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H200


def run(quick: bool = True):
    rows = []
    rates = [0.2] if quick else [0.1, 0.2, 0.33, 0.5, 0.8, 1.0, 1.2]
    n = 24 if quick else 48
    for regime in ["ILR-1", "ILR-2", "ILR-3", "ILR-4"]:
        for rate in rates:
            point = []
            for policy in POLICIES:
                s = run_point(CONFIG, H200, policy, regime, rate, n,
                              max_context=CONTEXT_LIMIT)
                r = fmt_row(s)
                r["figure"] = "fig10"
                point.append(r)
            sp = speedup_vs_best_baseline(point)
            for r in point:
                r["mars_speedup_mean"] = sp.get("speedup")
            rows.extend(point)
    return rows
