"""Tiered KV-state benchmark: prefix sharing + three-way retention.

Two controlled comparisons on a shared-prefix ILR-2 sim workload
(session families on one repository context, Qwen3-Coder-30B / H100):

* **sharing** — radix prefix index ON vs OFF, same workload/policy:
  prefill tokens actually computed, prefix hit tokens, mean latency.
* **retention** — binary pin/drop vs three-way pin/offload/drop at equal
  device-KV capacity: mean / p90 end-to-end latency, offload hit rate.

``Engine.check_invariants`` (refcount accounting included) runs after every
configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3, CONTEXT_LIMIT
from repro.core.goodput import summarize
from repro.core.policies import MARSConfig
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100
from repro.workloads.generator import WorkloadSpec, generate


def _workload(n_sessions: int, rate: float, seed: int = 7,
              first_frac: float = 0.7) -> WorkloadSpec:
    # dense family structure (many agents on one repository): 8-member
    # families, 80% of the round-0 context is the shared repo state
    return WorkloadSpec(regime="ILR-2", arrival_rate=rate,
                        n_sessions=n_sessions, seed=seed,
                        max_context=CONTEXT_LIMIT,
                        n_families=max(2, n_sessions // 8),
                        first_round_frac=first_frac,
                        shared_frac=0.8, dup_frac=0.15)


def _run(spec: WorkloadSpec, *, blocks: int, sharing: bool,
         three_way: bool) -> Dict:
    cosched_overrides = {} if three_way else {"enable_offload": False}
    mars_cfg = MARSConfig()
    mars_cfg.cosched = dataclasses.replace(mars_cfg.cosched,
                                           **cosched_overrides)
    eng = Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                              token_budget=8192, max_decode_batch=64,
                              decode_granularity=8, cpu_slots=32,
                              enable_prefix_sharing=sharing,
                              host_tier_blocks=(-1 if three_way else 0)),
                 "mars", SimBackend(QWEN3, H100), mars_cfg=mars_cfg)
    sessions = generate(spec, QWEN3, H100)
    finished, horizon = run_sim(eng, sessions, max_time=2e5)
    eng.check_invariants()
    stats = summarize(finished, horizon)
    host = eng.host
    return {
        "figure": "kvcache",
        "n_finished": len(finished),
        "mean_s": round(stats["latency"].mean, 1),
        "p90_s": round(stats["latency"].p90, 1),
        "ttft_p95_s": round(stats["ttft"].p95, 2),
        "prefill_tokens_computed": eng.prefill_tokens_computed,
        "prefix_hit_tokens": eng.prefix_hit_tokens,
        "cow_copies": eng.blocks.cow_count,
        "offload_stores": host.stores if host else 0,
        "offload_hit_rate": round(host.hit_rate, 3) if host else 0.0,
    }


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    """``dry`` (CI smoke): a minimal workload through every configuration —
    exercises sharing + three-way retention without timing-grade sizes."""
    n = 8 if dry else (24 if quick else 48)
    rows: List[Dict] = []

    # (a) prefix sharing on/off: ample pool, so the delta isolates sharing
    spec = _workload(n, rate=0.5)
    for sharing in (False, True):
        r = _run(spec, blocks=24_000, sharing=sharing, three_way=True)
        r.update(name=f"sharing_{'on' if sharing else 'off'}")
        rows.append(r)
    off, on = rows[-2], rows[-1]
    saved = 1.0 - on["prefill_tokens_computed"] / \
        max(1, off["prefill_tokens_computed"])
    rows.append({"figure": "kvcache", "name": "prefill_reduction",
                 "prefill_tokens_saved_frac": round(saved, 3)})

    # (b) binary vs three-way retention at equal device-KV capacity:
    # constrained pool + bursty arrivals, where pins get revoked under
    # pressure and the offload tier can save the recompute
    spec_b = _workload(n, rate=1.0, seed=11, first_frac=0.55)
    for three_way in (False, True):
        r = _run(spec_b, blocks=10_000, sharing=True, three_way=three_way)
        r.update(name=f"retention_{'three_way' if three_way else 'binary'}")
        rows.append(r)
    binary, tri = rows[-2], rows[-1]
    rows.append({"figure": "kvcache", "name": "retention_speedup",
                 "binary_mean_s": binary["mean_s"],
                 "three_way_mean_s": tri["mean_s"],
                 "speedup": round(binary["mean_s"] /
                                  max(1e-9, tri["mean_s"]), 3)})
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: minimal workload, all configurations")
