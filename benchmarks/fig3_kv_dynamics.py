"""Fig. 3: KV eviction dynamics over normalized progress (A) and per-round
TTFT percentiles (B). MARS reclaims aggressively during the arrival spike,
then suppresses eviction to protect resident state -> warm resumes."""
import numpy as np

from benchmarks.common import run_point
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100


def run(quick: bool = True):
    rows = []
    n = 24 if quick else 48
    for policy in ["fcfs", "continuum-dy", "infercept", "mars"]:
        s = run_point(CONFIG, H100, policy, "ILR-2", 0.25, n,
                      max_context=CONTEXT_LIMIT)
        eng = s["engine"]
        evs = [e for e in eng.bus.log if e.kind in ("evict", "preempt")]
        horizon = max((e.t for e in eng.bus.log), default=1.0)
        # eviction-rate histogram over 10 progress bins (panel A)
        bins = np.zeros(10)
        for e in evs:
            bins[min(9, int(10 * e.t / horizon))] += e.data.get("blocks", 1)
        ttfts = []
        for sess in eng.finished:
            ttfts.extend(sess.ttfts)
        ttfts = np.asarray(ttfts) if ttfts else np.zeros(1)
        rows.append({
            "figure": "fig3", "policy": policy,
            "evict_blocks_by_decile": [int(b) for b in bins],
            "ttft_mean_s": round(float(ttfts.mean()), 2),
            "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 2),
            "ttft_p99_s": round(float(np.percentile(ttfts, 99)), 2),
        })
    return rows
