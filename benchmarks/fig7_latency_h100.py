"""Fig. 7: mean/P90/P95 E2E latency, Qwen3-Coder-30B x H100, ILR-1..4."""
from benchmarks.common import POLICIES, fmt_row, run_point, speedup_vs_best_baseline
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100

RATES_QUICK = [0.1, 0.33]
RATES_FULL = [0.05, 0.1, 0.2, 0.33, 0.5]


def run(quick: bool = True):
    rows = []
    rates = RATES_QUICK if quick else RATES_FULL
    n = 24 if quick else 48
    for regime in ["ILR-1", "ILR-2", "ILR-3", "ILR-4"]:
        for rate in rates:
            point = []
            for policy in POLICIES:
                s = run_point(CONFIG, H100, policy, regime, rate, n,
                              max_context=CONTEXT_LIMIT)
                r = fmt_row(s)
                r["figure"] = "fig7"
                point.append(r)
            sp = speedup_vs_best_baseline(point)
            for r in point:
                r["mars_speedup_mean"] = sp.get("speedup")
            rows.extend(point)
    return rows
