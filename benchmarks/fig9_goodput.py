"""Fig. 9: Dynamic SLO-aware goodput, alpha in {1,2,3}, ILR-1..4."""
from benchmarks.common import POLICIES, run_point
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100


def run(quick: bool = True):
    rows = []
    n = 24 if quick else 48
    for regime in ["ILR-1", "ILR-2", "ILR-3", "ILR-4"]:
        for policy in POLICIES:
            s = run_point(CONFIG, H100, policy, regime, 0.1, n,
                          max_context=CONTEXT_LIMIT)
            rows.append({
                "figure": "fig9", "policy": policy, "regime": regime,
                **{f"goodput_a{int(a)}": round(g, 5)
                   for a, g in s["goodput"].items()}})
    return rows
