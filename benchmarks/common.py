"""Shared benchmark harness: one paper figure per module.

Each figure module exposes ``run(quick: bool) -> list[dict]`` returning CSV
rows; ``benchmarks.run`` drives them all and prints
``name,us_per_call,derived`` summaries plus per-figure tables.

All serving benchmarks run the *real* MARS/baseline scheduler code on the
discrete-event backend (H100/H200 perf model, Qwen3-Coder-30B / GPT-OSS-120B
configs) — see DESIGN.md §2: the simulator is the paper's testbed analogue.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core.goodput import summarize
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models import perf_model as pm
from repro.workloads.generator import WorkloadSpec, generate

POLICIES = ["fcfs", "autellix", "infercept", "continuum", "continuum-dy", "mars"]


def engine_for(cfg, hw, policy: str, *, cpu_slots: int = 32,
               mars_cfg=None) -> Engine:
    kv_budget = hw.hbm_bytes - 2.1 * cfg.param_count()
    blocks = max(1024, int(kv_budget / pm.kv_cache_bytes(cfg, 1) / 32))
    backend = SimBackend(cfg, hw)
    return Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                               token_budget=8192, max_decode_batch=64,
                               decode_granularity=8, cpu_slots=cpu_slots),
                  policy, backend, mars_cfg=mars_cfg)


def run_point(cfg, hw, policy: str, regime: str, rate: float,
              n_sessions: int, *, seed: int = 0, max_context=None,
              cpu_slots: int = 32, mars_cfg=None, alphas=(1.0, 2.0, 3.0)):
    spec = WorkloadSpec(regime=regime, arrival_rate=rate,
                        n_sessions=n_sessions, seed=seed,
                        max_context=max_context)
    sessions = generate(spec, cfg, hw)
    eng = engine_for(cfg, hw, policy, cpu_slots=cpu_slots, mars_cfg=mars_cfg)
    t0 = time.time()
    finished, horizon = run_sim(eng, sessions, max_time=2e5)
    stats = summarize(finished, horizon, alphas)
    stats["wall_s"] = time.time() - t0
    stats["policy"] = policy
    stats["regime"] = regime
    stats["rate"] = rate
    stats["engine"] = eng
    return stats


def fmt_row(stats: Dict) -> Dict:
    lat = stats["latency"]
    return {
        "policy": stats["policy"], "regime": stats["regime"],
        "rate": stats["rate"], "n": stats["n_finished"],
        "mean_s": round(lat.mean, 1), "p90_s": round(lat.p90, 1),
        "p95_s": round(lat.p95, 1),
        "ttft_p95_s": round(stats["ttft"].p95, 2),
        "goodput3_req_s": round(stats["goodput"][3.0], 5),
        "tok_s": round(stats["token_throughput"], 1),
    }


def bench_main(run_fn, dry_help: str = "CI smoke", add_args=None) -> None:
    """Shared CLI epilogue for the standalone benchmarks: ``--dry``/
    ``--full`` mode selection, JSON-lines rows on stdout, and the
    machine-readable ``--json OUT`` file the bench-regression gate
    (scripts/check_bench.py) consumes — one place to evolve the wire
    shape, five call sites.

    ``add_args(parser)`` lets a bench register extra flags; it must return
    the list of dest names, which are forwarded to ``run_fn`` as keyword
    arguments (e.g. the obs bench's ``--trace OUT.json``)."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true", help=dry_help)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="also write rows as machine-readable JSON")
    extra_names = add_args(ap) if add_args is not None else []
    args = ap.parse_args()
    extra = {k: getattr(args, k) for k in extra_names}
    rows = run_fn(quick=not args.full, dry=args.dry, **extra)
    for row in rows:
        print(json.dumps(row))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
            f.write("\n")


def speedup_vs_best_baseline(rows: List[Dict], metric: str = "mean_s") -> Dict:
    base = [r for r in rows if r["policy"] != "mars"]
    mars = [r for r in rows if r["policy"] == "mars"]
    if not base or not mars:
        return {}
    best = min(base, key=lambda r: r[metric])
    return {"mars": mars[0][metric], "best_baseline": best[metric],
            "best_baseline_policy": best["policy"],
            "speedup": round(best[metric] / max(mars[0][metric], 1e-9), 2)}
