"""Incident-plane proof benchmark: detection latency + precision/recall.

Every detector in ``repro.obs.detect`` is proven against a *known* fault:
``repro.engine.faults.FaultPlan`` injects one deterministic failure per
scenario (stuck tool, frozen admission, degraded PCIe, frozen decode
lane, co-tenant CPU flood, event-ring overflow) into a seeded sim run
with the full online observability stack installed (DetectorSuite +
SloTracker + FlightRecorder), and the bench measures:

* **recall** — every injected fault class raises its expected incident
  kind (gated at 1.0: a silent fault is a broken detector);
* **false incidents** — two clean control runs (the plain config and the
  KV-pressured config the swap scenarios use) must raise *zero*
  incidents (gated at 0: a noisy detector is worse than none);
* **detection latency** — modeled seconds from fault activation to the
  first expected incident (gated loose; the point is a bound, not a
  race);
* **precision** — fraction of incidents across fault runs whose kind is
  expected *or* a documented secondary effect of that fault (a CPU flood
  genuinely stalls admission — that is a true positive, not noise).

Everything runs on the modeled clock, so rows are bit-stable across
machines and dry/quick/full — the sizes below are used for all modes.
``--bundle-dir DIR`` keeps the flight-recorder bundles (CI smokes
``scripts/trace_report.py`` over one).

SLO accounting rides along: the clean rows carry goodput under the
``standard`` class so a collapsed-but-incident-free run still shows up.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs.qwen3_coder_30b import CONFIG
from repro.core.events import EventBus
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.engine.faults import Fault, FaultPlan
from repro.models.perf_model import H100
from repro.obs import DetectorSuite, FlightRecorder, SloTracker
from repro.workloads.generator import WorkloadSpec, generate

SEED = 7
# short-mode tool times keep the stuck-tool bound (4x nominal) well inside
# the run's active window, so detection happens while ticks still flow
TOOL_SCALE = 0.25
# plain scenarios spread arrivals over ~400 modeled seconds: faults need
# live traffic *after* they bite (ticks only flow while sessions run), and
# frozen admission only stalls something if sessions still arrive behind it
PLAIN_RATE, PLAIN_N = 0.06, 24
PRESSURED_RATE, PRESSURED_N = 0.33, 16

# scenario -> (fault kwargs, expected incident kind, allowed secondary
# kinds: genuine downstream effects of the fault, counted as true
# positives for precision)
SCENARIOS: Dict[str, dict] = {
    "stuck_tool": {
        "fault": dict(kind="stuck_tool", at_s=100.0),
        "expect": "tool_stall",
        "allowed": {"decode_livelock"},
    },
    "frozen_admission": {
        "fault": dict(kind="frozen_admission", at_s=150.0),
        "expect": "admission_stall",
        "allowed": set(),
    },
    "slowed_swap": {
        "fault": dict(kind="slowed_swap", at_s=200.0, factor=200.0),
        "expect": "swap_storm",
        "allowed": {"decode_livelock", "tool_stall"},
        "pressured": True,
    },
    "freeze_decode": {
        "fault": dict(kind="freeze_decode", at_s=150.0),
        "expect": "decode_livelock",
        "allowed": set(),
    },
    "cpu_flood": {
        "fault": dict(kind="cpu_flood", at_s=120.0, cpu_work_s=300.0,
                      n_leases=64),
        "expect": "cpu_queue_collapse",
        # the flood really does freeze admission (CPU-aware deferral) and
        # stretch tool turnarounds past their promises
        "allowed": {"admission_stall", "tool_stall"},
    },
    "event_loss": {
        "fault": None,                  # the fault *is* the tiny ring
        "expect": "event_loss",
        "allowed": set(),
        "max_log": 2000,
        "dense": True,                  # ring overflows in seconds; no need
                                        # for the long-arrival workload
    },
}


def _spec(pressured: bool, dense: bool = False) -> WorkloadSpec:
    if dense:
        return WorkloadSpec(regime="S-ILR1", arrival_rate=PRESSURED_RATE,
                            n_sessions=PRESSURED_N, seed=SEED,
                            max_context=40_000, tool_time_scale=TOOL_SCALE,
                            slo_class="standard")
    if pressured:
        # long-idle tool mix + tight KV: MARS parks KV in the host tier at
        # every yield and swaps it back on resume — steady io traffic for
        # the storm detector to watch
        return WorkloadSpec(regime="S-ILR1", arrival_rate=PRESSURED_RATE,
                            n_sessions=PRESSURED_N, seed=SEED,
                            max_context=40_000,
                            tool_mix={"terminal": 0.3, "file_editor": 0.2,
                                      "test_runner": 0.5},
                            tool_time_scale=TOOL_SCALE,
                            slo_class="standard")
    return WorkloadSpec(regime="S-ILR1", arrival_rate=PLAIN_RATE,
                        n_sessions=PLAIN_N, seed=SEED,
                        max_context=40_000, tool_time_scale=TOOL_SCALE,
                        slo_class="standard")


def _engine(pressured: bool, max_log: Optional[int]) -> Engine:
    if pressured:
        cfg = EngineConfig(total_kv_blocks=2048, block_size=32,
                           token_budget=8192, max_decode_batch=64,
                           decode_granularity=8, cpu_slots=32,
                           host_tier_blocks=8192)
    else:
        cfg = EngineConfig(total_kv_blocks=16_384, block_size=32,
                           token_budget=8192, max_decode_batch=64,
                           decode_granularity=8, cpu_slots=32)
    return Engine(cfg, "mars", SimBackend(CONFIG, H100),
                  bus=EventBus(max_log=max_log))


def _run_scenario(name: str, *, fault: Optional[dict], pressured: bool,
                  max_log: Optional[int], bundle_dir: Optional[str],
                  dense: bool = False) -> dict:
    eng = _engine(pressured, max_log)
    suite = DetectorSuite.install(eng)
    slo = SloTracker.install(eng)
    rec = None
    if bundle_dir is not None:
        import os
        d = os.path.join(bundle_dir, name)
        os.makedirs(d, exist_ok=True)
        rec = FlightRecorder.install(eng, d, max_events=50_000)
    plan = None
    if fault is not None:
        plan = FaultPlan([Fault(**fault)]).install(eng)
    sessions = generate(_spec(pressured, dense), CONFIG, H100)
    finished, horizon = run_sim(eng, sessions, max_time=6000.0)
    return {"suite": suite, "slo": slo, "rec": rec, "plan": plan,
            "finished": len(finished), "horizon": horizon,
            "events": len(eng.bus.log), "dropped": eng.bus.dropped}


def run(quick: bool = True, dry: bool = False,
        bundle_dir: Optional[str] = None) -> List[Dict]:
    rows: List[Dict] = []

    # -- clean controls: zero incidents on both configs -------------------
    false_incidents = 0
    clean_detail: Dict[str, int] = {}
    goodput = {}
    for label, pressured in (("plain", False), ("pressured", True)):
        r = _run_scenario(f"clean_{label}", fault=None, pressured=pressured,
                          max_log=None, bundle_dir=None)
        false_incidents += len(r["suite"].incidents)
        for inc in r["suite"].incidents:
            clean_detail[inc["kind"]] = clean_detail.get(inc["kind"], 0) + 1
        rep = r["slo"].report()["classes"].get("standard", {})
        goodput[label] = round(rep.get("goodput_frac", 0.0), 4)
    rows.append({"figure": "slo", "name": "clean",
                 "false_incidents": false_incidents,
                 "false_by_kind": clean_detail,
                 "goodput_frac_plain": goodput.get("plain", 0.0),
                 "goodput_frac_pressured": goodput.get("pressured", 0.0)})

    # -- fault scenarios --------------------------------------------------
    detected = 0
    latencies: List[float] = []
    tp = fp = 0
    for name, sc in SCENARIOS.items():
        fault = sc["fault"]
        r = _run_scenario(name, fault=fault,
                          pressured=sc.get("pressured", False),
                          max_log=sc.get("max_log"),
                          bundle_dir=bundle_dir,
                          dense=sc.get("dense", False))
        suite = r["suite"]
        expect = sc["expect"]
        allowed = {expect} | sc["allowed"]
        hits = [i for i in suite.incidents if i["kind"] == expect]
        ok = bool(hits)
        detected += ok
        at_s = fault["at_s"] if fault is not None else None
        latency = (hits[0]["t"] - at_s) if (ok and at_s is not None) \
            else None
        if latency is not None:
            latencies.append(latency)
        n_tp = sum(1 for i in suite.incidents if i["kind"] in allowed)
        n_fp = len(suite.incidents) - n_tp
        tp += n_tp
        fp += n_fp
        rows.append({
            "figure": "slo", "name": f"fault_{name}",
            "expect": expect, "detected": int(ok),
            "latency_s": round(latency, 2) if latency is not None else None,
            "incidents": len(suite.incidents),
            "by_kind": {k: suite.count(k)
                        for k in {i["kind"] for i in suite.incidents}},
            "unexpected": n_fp,
            "bundles": len(r["rec"].bundles) if r["rec"] else 0,
            "fault_hits": plan_hits(r["plan"]),
            "finished": r["finished"],
            "horizon_s": round(r["horizon"], 1),
            "dropped_events": r["dropped"],
        })

    recall = detected / len(SCENARIOS)
    precision = tp / max(1, tp + fp)
    rows.append({
        "figure": "slo", "name": "detection",
        "faults": len(SCENARIOS), "detected": detected,
        "recall": round(recall, 4), "precision": round(precision, 4),
        "max_latency_s": round(max(latencies), 2) if latencies else None,
        "mean_latency_s": round(sum(latencies) / len(latencies), 2)
        if latencies else None,
    })
    assert false_incidents == 0, \
        f"clean runs raised incidents: {clean_detail}"
    assert recall == 1.0, \
        f"undetected faults: {[r['name'] for r in rows if r.get('detected') == 0]}"
    return rows


def plan_hits(plan: Optional[FaultPlan]) -> int:
    if plan is None:
        return 0
    return sum(f["hits"] for f in plan.summary())


if __name__ == "__main__":
    try:
        from common import bench_main
    except ModuleNotFoundError:
        from benchmarks.common import bench_main

    def _add_args(ap):
        ap.add_argument("--bundle-dir", dest="bundle_dir", metavar="DIR",
                        default=None,
                        help="keep flight-recorder incident bundles here")
        return ["bundle_dir"]

    bench_main(run, dry_help="deterministic sim faults (same sizes in "
               "all modes)", add_args=_add_args)
