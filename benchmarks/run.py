# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV summary lines plus per-figure tables.
from __future__ import annotations

import argparse
import importlib
import json
import os
import time

FIGURES = [
    "fig2_goodput_collapse",
    "fig3_kv_dynamics",
    "fig7_latency_h100",
    "fig8_latency_gptoss",
    "fig9_goodput",
    "fig10_latency_h200",
    "fig11_agent_loop",
    "fig13_ablation",
    "kernel_bench",
    "kvcache_bench",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow); default is quick mode")
    ap.add_argument("--only", default=None, help="comma-separated figure list")
    ap.add_argument("--out", default="bench_results.json")
    args = ap.parse_args(argv)
    quick = not args.full
    figures = args.only.split(",") if args.only else FIGURES

    all_rows = []
    print("name,us_per_call,derived")
    for fig in figures:
        mod = importlib.import_module(f"benchmarks.{fig}")
        t0 = time.time()
        rows = mod.run(quick=quick)
        dt = time.time() - t0
        all_rows.extend(rows)
        derived = ""
        mars_rows = [r for r in rows if r.get("policy") == "mars"
                     and r.get("mars_speedup_mean")]
        if mars_rows:
            sp = [r["mars_speedup_mean"] for r in mars_rows]
            derived = f"mars_speedup_mean={min(sp)}x..{max(sp)}x"
        elif rows and "us_per_call" in rows[0]:
            derived = ";".join(f"{r['name']}={r['us_per_call']}us"
                               for r in rows)
        print(f"{fig},{dt*1e6/max(1,len(rows)):.0f},{derived}")
        for r in rows:
            clean = {k: v for k, v in r.items() if k != "engine"}
            print("  " + json.dumps(clean))
    with open(args.out, "w") as f:
        json.dump([{k: v for k, v in r.items() if k != "engine"}
                   for r in all_rows], f, indent=1)
    print(f"[benchmarks] wrote {args.out} ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
