"""Fig. 2: token throughput vs Dynamic SLO-Aware Goodput (ILR-1 vs ILR-4).

Baselines sustain token throughput while goodput collapses under heavier
input-length regimes; MARS keeps request completions within SLO."""
from benchmarks.common import POLICIES, fmt_row, run_point
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100


def run(quick: bool = True):
    rows = []
    n = 24 if quick else 48
    for regime, rate in [("ILR-1", 0.2), ("ILR-4", 0.2)]:
        for policy in POLICIES:
            s = run_point(CONFIG, H100, policy, regime, rate, n,
                          max_context=CONTEXT_LIMIT)
            r = fmt_row(s)
            r["figure"] = "fig2"
            rows.append(r)
    return rows
