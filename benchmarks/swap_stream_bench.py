"""Async swap stream benchmark: decode ticks must not stretch by swap time.

Drives the live paged JAX engine (reduced model, CPU-friendly) with two
populations sharing one page pool:

* **decoders** — K sessions in steady greedy decode (the latency-sensitive
  work whose ticks must not stretch);
* **swappers** — M tool-calling sessions forced to ``KVAction.OFFLOAD`` at
  every tool yield, so each round pushes a D2H page drain and, on resume,
  an H2D restore through the engine.

Three runs, same arrival pattern:

* ``no_swap``     — decoders only: the per-tick latency baseline;
* ``serialized``  — swappers on, ``async_swap=False``: every page copy
  executes inside ``run_batch``, so swap-carrying decode ticks stretch by
  the transfer time (the pre-stream behaviour);
* ``async``       — swappers on, background swap stream (default): the
  copies drain on the worker, swap-ins are prefetched, and the engine
  defers unresolved restores instead of stalling the batch.

Reported per run: the median decode-tick latency (ticks batching all K
decoders and no prefill chunk), the same median over *swap-carrying* ticks
(ticks that also executed swap-outs/swap-ins — the ticks the serialized
path inflates), and the swap stream's transfer/staging stats. The headline
row asserts the async path's swap-carrying decode ticks stay within 1.15x
of the no-swap baseline (not asserted under ``--dry``; on a CPU-only JAX
the host "crossings" are cheap, so the serialized column understates what
a PCIe-attached accelerator would show — the assert is the regression
guard, the comparison is the point).

All three runs pin ``EngineConfig(scheduler="round")``: the median-over-
comparable-ticks methodology needs round-granular decode quanta, and the
swap stream's overlap behaviour is orthogonal to iteration-level batching
(which ``continuous_batching_bench`` measures on its own terms).

``--dry`` (CI smoke): tiny populations, one round — exercises all three
configurations end to end without timing-grade sizes.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

from repro.core.policies import KVAction
from repro.core.session import Round, make_session
from repro.engine.engine import Engine, EngineConfig


def _sessions(K: int, M: int, *, dec_tokens: int, swap_prefill: int,
              rounds: int, tool_s: float, sid0: int):
    out = []
    for j in range(K):
        out.append(make_session(0.0, [Round(128, dec_tokens, None, 0.0)],
                                ideal_time=1.0, sid=sid0 + j))
    for j in range(M):
        rs = [Round(swap_prefill, 4, "t", tool_s)]
        for r in range(1, rounds):
            rs.append(Round(64, 4, "t" if r < rounds - 1 else None,
                            tool_s if r < rounds - 1 else 0.0))
        out.append(make_session(0.1, rs, ideal_time=1.0,
                                sid=sid0 + 1000 + j))
    return out


def _run(name: str, *, K: int, M: int, pages: int, slots: int,
         async_swap: bool, dec_tokens: int, swap_prefill: int, rounds: int,
         tool_s: float, sid0: int, timeout_s: float = 120.0) -> Dict:
    from repro.configs.registry import get_config
    from repro.engine.jax_runner import JaxBackend
    cfg = get_config("llama3.2-1b").reduced()
    backend = JaxBackend(cfg, layout="paged", max_slots=slots, max_len=1024,
                         total_pages=pages, async_swap=async_swap)
    # Pinned to the round scheduler: the figure isolates the swap stream,
    # and its methodology (medians over comparable g-token decode ticks)
    # needs round-granular tick shapes. Under the mixed default the tick
    # population is 1-token iterations whose timing distribution is not
    # comparable across the three runs on a wall-clock CPU runner. This
    # also keeps the scheduler="round" compat path exercised in CI.
    eng = Engine(EngineConfig(total_kv_blocks=pages - 16, block_size=32,
                              token_budget=4096, max_decode_batch=slots,
                              decode_granularity=8, cpu_slots=4,
                              scheduler="round"),
                 "fcfs", backend)
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    # per-tick record: (elapsed, n_decodes, n_prefills, n_swap_entries)
    records: List[tuple] = []
    inner = backend.run_batch

    def run_batch(work, now):
        t = inner(work, now)
        records.append((t, len(work.decodes), len(work.prefills),
                        len(work.swapouts) + len(work.swapins)))
        return t

    backend.run_batch = run_batch
    arrivals = sorted(_sessions(K, M, dec_tokens=dec_tokens,
                                swap_prefill=swap_prefill, rounds=rounds,
                                tool_s=tool_s, sid0=sid0),
                      key=lambda s: s.arrival_time)
    t0 = time.monotonic()
    i = 0
    while time.monotonic() - t0 < timeout_s:
        now = time.monotonic() - t0
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            eng.submit(arrivals[i])
            i += 1
        elapsed, progressed = eng.tick(now)
        if eng.done() and i >= len(arrivals):
            break
        if not progressed and elapsed == 0.0:
            time.sleep(0.001)
    eng.check_invariants()
    # decode ticks: the full decoder population and no prefill chunk (same
    # compiled shapes across runs); swap-carrying = those that also moved
    # swap entries — the ticks the serialized path stretches
    dec_ticks = [t for t, nd, npf, _sw in records if nd == K and npf == 0]
    swap_ticks = [t for t, nd, npf, sw in records
                  if nd == K and npf == 0 and sw > 0]
    stream = getattr(backend._impl, "stream", None)
    row = {
        "figure": "swap_stream",
        "name": name,
        "decode_tick_ms": round(1e3 * statistics.median(dec_ticks), 3)
            if dec_ticks else None,
        "swap_tick_ms": round(1e3 * statistics.median(swap_ticks), 3)
            if swap_ticks else None,
        "n_decode_ticks": len(dec_ticks),
        "n_swap_ticks": len(swap_ticks),
        "host_stores": eng.host.stores if eng.host else 0,
        "host_hits": eng.host.hits if eng.host else 0,
        "wall_s": round(time.monotonic() - t0, 2),
    }
    if stream is not None:
        row["d2h"] = stream.d2h_completed
        row["h2d"] = stream.h2d_completed
        row["staging_reuses"] = stream.staging.reuses
        row["staging_max_in_flight"] = stream.staging.max_in_flight
    backend.close()
    return row


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    if dry:
        K, M, dec, pre, rounds, tool_s = 2, 1, 64, 256, 2, 0.05
    elif quick:
        K, M, dec, pre, rounds, tool_s = 4, 2, 768, 2048, 5, 0.15
    else:
        K, M, dec, pre, rounds, tool_s = 6, 3, 1536, 4096, 8, 0.2
    rows: List[Dict] = []
    # same pool size and lane count in all three runs — pool scale and
    # decode-lane bucketing must not pollute the baseline comparison
    pages = (K * (128 + dec) + M * (pre + 64 * rounds)) // 32 + 32
    kw = dict(K=K, pages=pages, slots=K + M, dec_tokens=dec,
              swap_prefill=pre, rounds=rounds, tool_s=tool_s)
    base = _run("no_swap", M=0, async_swap=True, sid0=870_000, **kw)
    ser = _run("serialized", M=M, async_swap=False, sid0=871_000, **kw)
    asy = _run("async", M=M, async_swap=True, sid0=872_000, **kw)
    rows += [base, ser, asy]
    baseline = base["decode_tick_ms"]
    head = {"figure": "swap_stream", "name": "overlap"}
    if baseline:
        for row in (ser, asy):
            m = row["swap_tick_ms"] or row["decode_tick_ms"]
            head[f"{row['name']}_over_baseline"] = round(m / baseline, 3) \
                if m else None
    rows.append(head)
    if not dry and baseline and asy["swap_tick_ms"]:
        ratio = asy["swap_tick_ms"] / baseline
        assert ratio <= 1.15, \
            f"async swap ticks {ratio:.2f}x the no-swap baseline — " \
            f"swap traffic is back on the critical path"
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: tiny populations, all three configs")
