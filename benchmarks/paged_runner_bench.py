"""Paged live runner benchmark: physical prefix sharing on device.

Drives the *live* JAX engine (reduced model, CPU-friendly) with a K-session
family sharing one repository context, in both cache layouts:

* **slot-dense** — every member owns a dense per-slot KV region: device
  residency ~ K * ceil(total/page) pages, prefix recomputed per member;
* **paged** — BlockPool block tables drive the Pallas ``paged_attention``
  placement: shared prefix blocks are physically shared, so residency
  ~ ceil(shared/page) + K * ceil(tail/page).

Reported per layout: peak device-page residency (pool ``physical``), prefill
tokens actually computed, prefix hit tokens, and the sustained decode tick
floor (``decode_tick_ms``: min over steady family-wide decode ticks —
compile-paying first visits of a shape bucket would dominate a mean). The
headline row asserts the MARS warm-state claim is *physical*, not
accounting: paged residency < 0.6x slot-dense for the same family.

A second headline row, ``prefill_hbm_bytes_per_chunk``, reports the
analytic HBM bytes each prefill chunk touches under the gather-free
(block-table steered) kernel vs the legacy gather path, from the
runner's dispatch counters; ``inplace_over_gather`` is gated at <= 0.5
in ``baselines.json``.

``--dry`` (CI smoke): tiny family, single rep — exercises both layouts
without the timing-grade sizes.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.session import Round, make_session
from repro.engine.engine import Engine, EngineConfig


def _family(sids, shared_chunks: int, tail_chunks: int, decode: int):
    """One canonical builder at t=0, then the K-1 other members together
    once the repository context is built and indexed (the steady state the
    paper's warm-resumption argument is about: agents joining a repo whose
    context already exists). The same arrival pattern drives both layouts."""
    fam = [(("fam", i), 32) for i in range(shared_chunks)]
    first = 32 * (shared_chunks + tail_chunks)
    out = []
    for j, sid in enumerate(sids):
        arr = 0.0 if j == 0 else 2.0
        s = make_session(arr, [Round(first, decode, None, 0.0)],
                         ideal_time=1.0, sid=sid)
        s.meta["prefix_hashes"] = fam + [
            (("u", sid, i), 32) for i in range(tail_chunks)]
        out.append(s)
    return out


def _run_layout(layout: str, *, K: int, shared_chunks: int, tail_chunks: int,
                decode: int, sid0: int) -> Dict:
    from repro.configs.registry import get_config
    from repro.engine.jax_runner import JaxBackend
    cfg = get_config("llama3.2-1b").reduced()
    backend = JaxBackend(cfg, layout=layout, max_slots=K, max_len=512)
    blocks = K * 511 // 32
    eng = Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                              token_budget=1024, max_decode_batch=K,
                              decode_granularity=4, cpu_slots=2),
                 "fcfs", backend)
    arrivals = sorted(_family(range(sid0, sid0 + K), shared_chunks,
                              tail_chunks, decode),
                      key=lambda s: s.arrival_time)
    t0 = time.monotonic()
    i = 0
    peak_pages = 0
    peak_shared = 0
    decode_ticks: List[float] = []
    ticks = 0
    while ticks < 50_000:
        ticks += 1
        now = time.monotonic() - t0
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            eng.submit(arrivals[i])
            i += 1
        elapsed, progressed = eng.tick(now)
        n_dec = sum(1 for s in eng.active if s.phase.value == "decoding")
        pr = eng.blocks.probe()
        peak_pages = max(peak_pages, pr.physical)
        # logical refs minus physical blocks = references satisfied by an
        # already-resident block: nonzero iff sharing is physical. Unlike
        # the peak-residency ratio this is wall-clock independent — it
        # needs members to *attach*, not to overlap just so.
        peak_shared = max(peak_shared, pr.leased - pr.physical)
        if elapsed > 0 and n_dec >= K - 1:   # steady family-wide decode
            decode_ticks.append(elapsed)
        if eng.done() and i >= len(arrivals):
            break
        if not progressed and elapsed == 0.0:
            time.sleep(0.001)
    eng.check_invariants()
    st = backend.dispatch_stats
    return {
        "figure": "paged_runner",
        "name": f"{layout}",
        "peak_device_pages": peak_pages,
        "peak_shared_refs": peak_shared,
        "prefill_tokens_computed": eng.prefill_tokens_computed,
        "prefix_hit_tokens": eng.prefix_hit_tokens,
        # analytic HBM bytes-touched counters kept by the paged layout's
        # prefill (zero under dense, which has no block-table indirection)
        "prefill_calls": int(st.get("prefill_calls", 0)),
        "prefill_gather_bytes": float(st.get("prefill_gather_bytes", 0.0)),
        "prefill_inplace_bytes": float(st.get("prefill_inplace_bytes", 0.0)),
        # sustained floor: ticks that pay a jit compile (first visit of a
        # (B, max_pages) bucket) would dominate any mean on a short CPU run
        "decode_tick_ms": round(1e3 * min(decode_ticks), 2)
            if decode_ticks else None,
        "wall_s": round(time.monotonic() - t0, 2),
    }


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    if dry:
        K, shared, tail, decode = 3, 2, 1, 4
    elif quick:
        K, shared, tail, decode = 6, 8, 1, 16
    else:                      # --full: deeper context, wider family
        K, shared, tail, decode = 8, 24, 2, 48
    rows: List[Dict] = []
    dense = _run_layout("dense", K=K, shared_chunks=shared, tail_chunks=tail,
                        decode=decode, sid0=880_000)
    paged = _run_layout("paged", K=K, shared_chunks=shared, tail_chunks=tail,
                        decode=decode, sid0=890_000)
    rows += [dense, paged]
    ratio = paged["peak_device_pages"] / max(1, dense["peak_device_pages"])
    rows.append({
        "figure": "paged_runner", "name": "residency_ratio",
        "paged_over_dense": round(ratio, 3),
        "physical_sharing": ratio < 0.6,
        # structural sharing proof: peak count of block references backed
        # by an already-resident physical block. This is what baselines.json
        # gates in the CI smoke — the peak-residency *ratio* depends on how
        # the two layouts' prefills overlap the wall-clocked arrivals, which
        # made the dry gate environment-sensitive; the timing-grade ratio
        # claim stays asserted on non-dry (nightly) runs below.
        "shared_block_refs": paged["peak_shared_refs"],
        "prefill_tokens_saved": dense["prefill_tokens_computed"]
                                - paged["prefill_tokens_computed"],
    })
    # gather-free prefill HBM traffic: per-chunk bytes the legacy gather
    # path would touch (gather read + dense copy + attention read) vs what
    # the block-table-steered kernel touches (in-place attention read +
    # chunk scatter). Analytic model from the runner's dispatch counters;
    # the gate in baselines.json holds the ratio at <= 0.5x.
    chunks = max(1, paged["prefill_calls"])
    g_per = paged["prefill_gather_bytes"] / chunks
    ip_per = paged["prefill_inplace_bytes"] / chunks
    rows.append({
        "figure": "paged_runner", "name": "prefill_hbm_bytes_per_chunk",
        "prefill_chunks": paged["prefill_calls"],
        "gather_bytes_per_chunk": round(g_per),
        "inplace_bytes_per_chunk": round(ip_per),
        "inplace_over_gather": round(ip_per / max(1.0, g_per), 3),
    })
    if not dry:
        assert ratio < 0.6, \
            f"paged residency {ratio:.2f}x dense — sharing not physical?"
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: tiny family, both layouts")
