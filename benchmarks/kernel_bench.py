"""Kernel micro-bench: us/call for the Pallas kernels (interpret mode on
CPU; on-TPU numbers are the target) vs the jnp oracles.

``--dry`` (CI smoke): tiny shapes, single rep — exercises every kernel
entry point without the timing loops."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _timed(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def _smoke(fn, *args, reps=1):
    jax.block_until_ready(fn(*args))
    return 0.0


def run(quick: bool = True, dry: bool = False):
    rows = []
    _time = _smoke if dry else _timed
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, S, D = 1, 8, 2, 64 if dry else 256, 64
    q = jax.random.normal(key, (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(key, (B, Hkv, S, D), jnp.float32)
    rows.append({"figure": "kernels", "name": "flash_attention_interp",
                 "us_per_call": round(_time(
                     lambda: ops.attention(q, k, k, use_kernel=True)), 1)})
    rows.append({"figure": "kernels", "name": "attention_oracle",
                 "us_per_call": round(_time(
                     lambda: ops.attention(q, k, k, use_kernel=False)), 1)})
    qd = jax.random.normal(key, (4, Hq, D), jnp.float32)
    kp = jax.random.normal(key, (32, 32, Hkv, D), jnp.float32)
    tbl = jnp.zeros((4, 4), jnp.int32)
    lens = jnp.full((4,), 100, jnp.int32)
    rows.append({"figure": "kernels", "name": "paged_attention_interp",
                 "us_per_call": round(_time(
                     lambda: ops.decode_attention(qd, kp, kp, tbl, lens,
                                                  use_kernel=True)), 1)})
    kn = jax.random.normal(key, (4, Hkv, D), jnp.float32)
    wp = jnp.arange(4, dtype=jnp.int32) + 8
    wo = jnp.full((4,), 3, jnp.int32)
    lens_f = jnp.full((4,), 100, jnp.int32)
    rows.append({"figure": "kernels", "name": "paged_attention_fused_interp",
                 "us_per_call": round(_time(
                     lambda: ops.decode_attention(
                         qd, kp, kp, tbl, lens_f, k_new=kn, v_new=kn,
                         write_pages=wp, write_offsets=wo,
                         use_kernel=True)), 1)})
    # gather-free chunked prefill: a chunk of 64 queries over an 8-page
    # scratch-padded table (the paged flash kernel's hot shape)
    qc = jax.random.normal(key, (1, Hq, 64, D), jnp.float32)
    ptbl = jnp.arange(8, dtype=jnp.int32)[None]
    kvl = jnp.full((1,), 7 * 32, jnp.int32)
    qoff = jnp.full((1,), 7 * 32 - 64, jnp.int32)
    rows.append({"figure": "kernels", "name": "paged_flash_attention_interp",
                 "us_per_call": round(_time(
                     lambda: ops.prefill_attention(qc, kp, kp, ptbl, kvl,
                                                   qoff, use_kernel=True)), 1)})
    r_ = jax.random.normal(key, (1, 64, 2, 32), jnp.float32) * 0.3
    w = jnp.full((1, 64, 2, 32), 0.9, jnp.float32)
    u = jnp.zeros((2, 32), jnp.float32)
    s0 = jnp.zeros((1, 2, 32, 32), jnp.float32)
    rows.append({"figure": "kernels", "name": "wkv6_interp",
                 "us_per_call": round(_time(
                     lambda: ops.wkv(r_, r_, r_, w, u, s0, use_kernel=True)), 1)})
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: tiny shapes, no timing loops")
