"""Observability overhead benchmark + critical-path breakdown figure.

Runs the standard agentic mix (ILR-2, Qwen3-Coder-30B x H100, MARS policy)
twice per repetition — observability off, then on — with freshly
generated sessions each run (the engine mutates them). "On" is the full
plane: ``Tracer.install`` (span assembly, tick/audit emission, metrics
histograms) *plus* the online half (``DetectorSuite`` + ``SloTracker``),
so the <=3% budget covers incident detection and SLO accounting too.
Three measurements:

* ``overhead_ratio`` — min-aggregated wall-clock ratio over interleaved
  repetitions (GC quiesced around each run). End-to-end but noisy on
  shared CI cores, so the CI gate bound is catastrophic-only; the tight
  claim rides on the next number.
* ``tracer_cpu_frac`` — the plane's *marginal* CPU cost, measured
  directly: replay the recorded event stream through a fresh tracer,
  detector suite and SLO tracker and divide by the engine's wall time.
  This is the observability plane's own work (span assembly + histograms
  + detector state machines), free of scheduler noise — the <=3% claim
  is asserted on it in non-dry runs.
* ``bucket_sum_err_frac`` — worst relative error of
  ``sum(critical_path(sid).buckets) == e2e`` over finished sessions. The
  exclusive-timeline invariant; deterministic, gated tight (<=1%).

The ``critical_path`` row is the paper-style breakdown figure: per-plane
fractions of total end-to-end latency (GPU / CPU-tool / PCIe+NVMe I/O /
control-plane wait) over the mix. ``--trace OUT.json`` additionally
writes the traced run's Perfetto export (nightly uploads it as an
artifact; ``scripts/trace_report.py`` consumes it).
"""
from __future__ import annotations

import gc
import time
from typing import Dict, List, Optional

from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.engine.engine import run_sim
from repro.models.perf_model import H100
from repro.obs import (DetectorSuite, MetricsRegistry, SloTracker, Tracer,
                       bind_engine_probes, export_perfetto)
from repro.workloads.generator import WorkloadSpec, generate

RATE = 0.33
REGIME = "ILR-2"


def _run_once(traced: bool, *, n_sessions: int, seed: int):
    try:                                   # package import (tests, run.py)
        from benchmarks.common import engine_for
    except ModuleNotFoundError:            # standalone: python benchmarks/x.py
        from common import engine_for
    spec = WorkloadSpec(regime=REGIME, arrival_rate=RATE,
                        n_sessions=n_sessions, seed=seed,
                        max_context=CONTEXT_LIMIT)
    sessions = generate(spec, CONFIG, H100)
    eng = engine_for(CONFIG, H100, "mars")
    tr = suite = slo = None
    if traced:
        tr = Tracer.install(eng, metrics=MetricsRegistry())
        bind_engine_probes(tr.metrics, eng)
        suite = DetectorSuite.install(eng, metrics=tr.metrics)
        slo = SloTracker.install(eng, metrics=tr.metrics)
    gc.collect()
    gc.disable()
    t0 = time.perf_counter()
    run_sim(eng, sessions, max_time=2e5)
    dt = time.perf_counter() - t0
    gc.enable()
    return dt, eng, tr, suite, slo


def run(quick: bool = True, dry: bool = False,
        trace: Optional[str] = None) -> List[Dict]:
    if dry:
        n_sessions, reps = 12, 2
    elif quick:
        n_sessions, reps = 24, 4
    else:
        n_sessions, reps = 48, 6
    offs: List[float] = []
    ons: List[float] = []
    eng = tr = suite = slo = None
    for rep in range(reps):
        # interleaved off/on pairs: slow-machine drift hits both modes;
        # min aggregation then discards the noise spikes
        woff, _, _, _, _ = _run_once(False, n_sessions=n_sessions, seed=0)
        won, eng, tr, suite, slo = _run_once(True, n_sessions=n_sessions,
                                             seed=0)
        offs.append(woff)
        ons.append(won)
    wall_off, wall_on = min(offs), min(ons)
    overhead_ratio = wall_on / wall_off

    # marginal plane cost: replay the recorded stream through a fresh
    # tracer + detector suite + SLO tracker — pure observability work,
    # no scheduler noise
    events = list(eng.bus.log)
    gc.collect()
    t0 = time.perf_counter()
    replayed = Tracer.replay(events)
    DetectorSuite.replay(events)
    SloTracker.replay(events)
    tracer_s = time.perf_counter() - t0
    tracer_cpu_frac = tracer_s / wall_on

    # exclusive-timeline invariant: buckets partition e2e
    worst_err = 0.0
    for sid in tr.finished_sids():
        cp = tr.critical_path(sid)
        err = abs(sum(cp["buckets"].values()) - cp["e2e"]) \
            / max(cp["e2e"], 1e-12)
        worst_err = max(worst_err, err)
    agg = tr.aggregate()

    pf = export_perfetto(tr, trace)
    rows: List[Dict] = [
        {"figure": "obs", "name": "overhead",
         "wall_off_s": round(wall_off, 3), "wall_on_s": round(wall_on, 3),
         "overhead_ratio": round(overhead_ratio, 4),
         "tracer_cpu_frac": round(tracer_cpu_frac, 5),
         "events": len(events), "ticks": len(tr.ticks),
         "sessions": tr.finished_count, "reps": reps,
         # online-plane vitals (reported, not asserted — slo_bench gates
         # detector precision/recall on purpose-built fault scenarios)
         "incidents": suite.count(),
         "goodput_frac": round(slo.report()["classes"]
                               .get("standard", {})
                               .get("goodput_frac", 0.0), 4)},
        {"figure": "obs", "name": "critical_path",
         "sessions": agg["sessions"],
         "e2e_total_s": round(agg["e2e_total"], 2),
         **{f"{p}_frac": round(f, 4)
            for p, f in agg["bucket_frac"].items()},
         "bucket_sum_err_frac": round(worst_err, 9)},
        {"figure": "obs", "name": "export",
         "trace_events": len(pf["traceEvents"]),
         "replay_sessions": replayed.finished_count,
         "dropped_session_tracks":
             pf["otherData"]["dropped_session_tracks"],
         "trace_path": trace},
    ]
    assert worst_err <= 0.01, \
        f"critical-path buckets drift from e2e by {worst_err:.2%}"
    assert replayed.finished_count == tr.finished_count, \
        "JSONL replay disagrees with the live tracer"
    if not dry:
        assert tracer_cpu_frac <= 0.03, \
            f"tracer marginal cost {tracer_cpu_frac:.1%} of engine wall " \
            f"time — observability is no longer <=3%"
        assert overhead_ratio <= 1.15, \
            f"traced runs {overhead_ratio:.2f}x untraced — emission is " \
            f"back on the hot path (re-pricing in the audit?)"
    return rows


if __name__ == "__main__":
    from common import bench_main

    def _add_args(ap):
        ap.add_argument("--trace", metavar="OUT.json", default=None,
                        help="write the traced run's Perfetto export here")
        return ["trace"]

    bench_main(run, dry_help="CI smoke: tiny mix, two repetitions",
               add_args=_add_args)
