"""Iteration-level continuous batching vs the round scheduler: decode
inter-token latency under a prefill-heavy arrival burst.

Setup (deterministic sim, Qwen3-Coder-30B / H100): a set of *streamer*
sessions is mid-decode when a burst of large cold prefills arrives. The
round scheduler (``EngineConfig(scheduler="round")``) dispatches
``decode_granularity``-token decode quanta next to whatever prefill tokens
fit the tick budget, so a streamer's tokens arrive in bursts separated by
full prefill-wave ticks. The mixed scheduler (the default) advances every
decode lane one token per iteration and caps the prefill share of each
iteration via the co-scheduler's budget split — the arrival burst stretches
an iteration by at most the capped prefill chunk.

Metric: p95 of the inter-token delivery gap (ITL) over the streamers'
decode tokens, from ``DECODE_STEP`` events — a burst of g tokens delivered
at one instant contributes one real gap and g-1 zero gaps, which is exactly
what a token-streaming client observes. The gate is the mixed/round p95
ratio (strictly < 1), plus a structural check that mixed iterations really
co-dispatched prefill chunks with decode lanes.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.session import Round, Session, make_session
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100


def _sessions(n_streamers: int, n_burst: int, burst_ctx: int,
              decode_tokens: int) -> Tuple[List[Session], List[int]]:
    """Streamers (small warm context, long decode) arriving first; a cold
    prefill burst landing while they are mid-decode."""
    out: List[Session] = []
    streamer_sids = []
    for j in range(n_streamers):
        s = make_session(0.0, [Round(2_048, decode_tokens, None, 0.0)],
                         ideal_time=1.0, sid=100 + j)
        streamer_sids.append(s.sid)
        out.append(s)
    # the burst arrives once the streamers are decoding (their prefill is
    # 2k tokens — a fraction of one tick's budget)
    for j in range(n_burst):
        out.append(make_session(4.0 + 0.01 * j,
                                [Round(burst_ctx, 16, None, 0.0)],
                                ideal_time=1.0, sid=200 + j))
    return out, streamer_sids


def _run(scheduler: str, n_streamers: int, n_burst: int, burst_ctx: int,
         decode_tokens: int) -> Dict:
    bus = EventBus()
    deliveries: Dict[int, List[Tuple[float, int]]] = {}
    prefill_ticks: set = set()
    decode_ticks: set = set()

    def on_decode(e):
        deliveries.setdefault(e.sid, []).append((e.t, e.data["tokens"]))
        decode_ticks.add(e.data["start"])

    def on_prefill(e):
        prefill_ticks.add(e.data["start"])

    bus.subscribe(ev.DECODE_STEP, on_decode)
    bus.subscribe(ev.PREFILL_CHUNK, on_prefill)
    eng = Engine(EngineConfig(total_kv_blocks=16_384, block_size=32,
                              token_budget=8192, max_decode_batch=64,
                              decode_granularity=8, cpu_slots=8,
                              host_tier_blocks=0, scheduler=scheduler),
                 "mars", SimBackend(QWEN3, H100), bus=bus)
    sessions, streamer_sids = _sessions(n_streamers, n_burst, burst_ctx,
                                        decode_tokens)
    finished, _ = run_sim(eng, sessions, max_time=2e5)
    eng.check_invariants()
    assert len(finished) == len(sessions), "bench run must finish everyone"
    gaps: List[float] = []
    for sid in streamer_sids:
        evs = sorted(deliveries.get(sid, []))
        for (t0, _g0), (t1, g1) in zip(evs, evs[1:]):
            gaps.append(t1 - t0)          # the visible stall between bursts
            gaps.extend([0.0] * (g1 - 1))  # burst co-delivered tokens
    gaps.sort()
    p95 = gaps[int(0.95 * (len(gaps) - 1))] if gaps else 0.0
    mean = sum(gaps) / len(gaps) if gaps else 0.0
    return {
        "scheduler": scheduler,
        "itl_p95_ms": round(1e3 * p95, 3),
        "itl_mean_ms": round(1e3 * mean, 3),
        "n_gaps": len(gaps),
        # iterations that co-dispatched prefill chunks WITH decode lanes
        "co_dispatch_ticks": len(prefill_ticks & decode_ticks),
    }


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    """``dry`` (CI smoke): small streamer/burst counts, same structure —
    the sim is deterministic, so the ratio gate stays tight even here."""
    if dry:
        n_streamers, n_burst, burst_ctx, dec = 4, 6, 24_000, 64
    elif quick:
        n_streamers, n_burst, burst_ctx, dec = 8, 12, 48_000, 128
    else:
        n_streamers, n_burst, burst_ctx, dec = 16, 24, 96_000, 256
    rows: List[Dict] = []
    by_sched = {}
    for sched in ("round", "mixed"):
        r = _run(sched, n_streamers, n_burst, burst_ctx, dec)
        r.update(figure="continuous_batching", name=f"itl_{sched}")
        by_sched[sched] = r
        rows.append(r)
    mixed, rnd = by_sched["mixed"], by_sched["round"]
    rows.append({
        "figure": "continuous_batching", "name": "itl_burst",
        "mixed_p95_ms": mixed["itl_p95_ms"],
        "round_p95_ms": rnd["itl_p95_ms"],
        "mixed_over_round": round(mixed["itl_p95_ms"] /
                                  max(1e-9, rnd["itl_p95_ms"]), 3),
        "co_dispatch_ticks": mixed["co_dispatch_ticks"],
    })
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: small burst, same structure")
