"""Fig. 13: component ablations — external control plane, priority-aware
coordinator, opportunistic co-scheduler."""
from benchmarks.common import fmt_row, run_point
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100

VARIANTS = ["mars", "mars-no-ctrl", "mars-no-coord", "mars-no-cosched"]


def run(quick: bool = True):
    rows = []
    n = 24 if quick else 48
    for regime in ["ILR-1", "ILR-3"] if quick else ["ILR-1", "ILR-2", "ILR-3", "ILR-4"]:
        for variant in VARIANTS:
            s = run_point(CONFIG, H100, variant, regime, 0.25, n,
                          max_context=CONTEXT_LIMIT)
            r = fmt_row(s)
            r["figure"] = "fig13"
            r["policy"] = variant
            rows.append(r)
    return rows
