"""Fig. 8: GPT-OSS-120B x H100, S-ILR1..3 (131K context cap)."""
from benchmarks.common import POLICIES, fmt_row, run_point, speedup_vs_best_baseline
from repro.configs.gpt_oss_120b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100


def run(quick: bool = True):
    rows = []
    n = 20 if quick else 40
    pols = POLICIES
    for regime in ["S-ILR1", "S-ILR2", "S-ILR3"]:
        point = []
        for policy in pols:
            s = run_point(CONFIG, H100, policy, regime, 0.25, n,
                          max_context=CONTEXT_LIMIT)
            r = fmt_row(s)
            r["figure"] = "fig8"
            point.append(r)
        sp = speedup_vs_best_baseline(point)
        for r in point:
            r["mars_speedup_mean"] = sp.get("speedup")
        rows.extend(point)
    return rows
