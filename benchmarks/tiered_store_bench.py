"""Tiered-store benchmark: four-way retention (HBM / host DRAM / NVMe /
recompute) vs three-way (host-only) at equal HBM+DRAM budget.

The workload is the long-idle agentic mix the cold tier exists for: session
families whose tool rounds draw from CI runs and human-in-the-loop waits
(``LONG_TOOL_KINDS``) alongside the usual terminal/test tools — heavy-tailed
multi-minute idle windows during which parked KV would otherwise pin down
the whole host tier. Both configurations get the *same* device pool and the
same host-DRAM capacity; the four-way run adds only the NVMe tier, so any
latency win is attributable to the staged hierarchy (direct-to-disk
offloads of long-idle sessions + net-benefit demotion of cold host
entries), not extra warm memory.

``Engine.check_invariants`` (tier occupancy included) runs after every
configuration.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.internlm2_20b import CONFIG as INTERNLM2
from repro.core.goodput import summarize
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100
from repro.workloads.generator import WorkloadSpec, generate

# CI pipelines and review waits dominate the idle time; the short
# interactive kinds keep the engine's batch mix realistic
LONG_IDLE_MIX = {
    "terminal": 0.2, "file_editor": 0.1, "test_runner": 0.2,
    "ci_runner": 0.3, "human_review": 0.2,
}

# dense 20B on H100: prefix recompute is genuinely expensive (~20-30 s at
# agentic contexts), which is the regime where retention — and therefore
# the tier hierarchy — decides end-to-end latency. The 0.25 tool-time
# scale keeps the idle windows past the co-scheduler's long-idle
# threshold while the sessions' e2e stays recompute-sensitive.
TOOL_SCALE = 0.25


def _workload(n_sessions: int, rate: float, seed: int = 13) -> WorkloadSpec:
    return WorkloadSpec(regime="ILR-2", arrival_rate=rate,
                        n_sessions=n_sessions, seed=seed,
                        max_context=200_000,
                        n_families=max(2, n_sessions // 6),
                        first_round_frac=0.6, shared_frac=0.7,
                        dup_frac=0.1, tool_mix=LONG_IDLE_MIX,
                        tool_time_scale=TOOL_SCALE)


def _run(spec: WorkloadSpec, *, blocks: int, host_blocks: int,
         disk_blocks: int) -> Dict:
    eng = Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                              token_budget=8192, max_decode_batch=64,
                              decode_granularity=8, cpu_slots=64,
                              host_tier_blocks=host_blocks,
                              disk_tier_blocks=disk_blocks),
                 "mars", SimBackend(INTERNLM2, H100))
    sessions = generate(spec, INTERNLM2, H100)
    finished, horizon = run_sim(eng, sessions, max_time=4e5)
    eng.check_invariants()
    stats = summarize(finished, horizon)
    tier = eng.tiers.stats()
    host, disk = tier["host"], tier["disk"]
    return {
        "figure": "tiered_store",
        "n_finished": len(finished),
        "mean_s": round(stats["latency"].mean, 1),
        "p90_s": round(stats["latency"].p90, 1),
        "ttft_p95_s": round(stats["ttft"].p95, 2),
        "prefill_tokens_computed": eng.prefill_tokens_computed,
        "host_stores": host["stores"],
        "host_hit_rate": host["hit_rate"],
        "disk_stores": disk["stores"] if disk else 0,
        "disk_hit_rate": disk["hit_rate"] if disk else 0.0,
        "demotions": tier["demotions"],
        "staged_restores": tier["staged_restores"],
        "direct_to_disk": tier["direct_to_disk"],
    }


def run(quick: bool = True, dry: bool = False) -> List[Dict]:
    """``dry`` (CI smoke): a minimal long-idle workload through both
    retention configurations — exercises direct-to-disk offload, demotion,
    staged promotion and the occupancy invariants without timing-grade
    sizes."""
    n = 12 if dry else (24 if quick else 48)
    rate = 1.0
    # equal HBM+DRAM: a constrained host tier that long-idle sessions
    # saturate; the four-way run adds only NVMe capacity on top
    blocks = 9_000
    host_blocks = 5_000
    disk_blocks = 96_000
    spec = _workload(n, rate=rate)
    rows: List[Dict] = []
    for disk in (0, disk_blocks):
        r = _run(spec, blocks=blocks, host_blocks=host_blocks,
                 disk_blocks=disk)
        r.update(name="four_way" if disk else "three_way")
        rows.append(r)
    three, four = rows[-2], rows[-1]
    rows.append({
        "figure": "tiered_store", "name": "disk_speedup",
        "three_way_mean_s": three["mean_s"],
        "four_way_mean_s": four["mean_s"],
        "speedup": round(three["mean_s"] / max(1e-9, four["mean_s"]), 3),
        # structural evidence the staged machinery actually ran (the
        # latency delta alone could come from anywhere)
        "disk_stores": four["disk_stores"],
        "staged_restores": four["staged_restores"],
    })
    return rows


if __name__ == "__main__":
    from common import bench_main
    bench_main(run, dry_help="CI smoke: minimal long-idle workload, "
                             "both retention configurations")
