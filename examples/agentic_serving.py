"""OpenHands-style agent loop on the live MARS engine: each session is an
agent task whose tool callables REALLY run (sandboxed workspace: file edits,
command execution, a task tracker) while the engine schedules LLM rounds.

    PYTHONPATH=src python examples/agentic_serving.py [--disk-tier]

``--disk-tier`` enables the NVMe cold tier with a real-file spool: every
tool yield parks its KV through the staged host->disk path (forced, so the
tiny demo contexts exercise it) and restores promote back through host
DRAM. Either way the per-tier occupancy / hit-rate breakdown prints at
exit.

``--trace OUT.json`` attaches the critical-path tracer (repro.obs) and
writes a Perfetto trace at exit (open at ui.perfetto.dev), plus prints the
per-session latency-breakdown table.

``--cpu-cores N`` sizes the shared host-CPU pool (default 2) that the
real tool threads and the swap/spool staging paths all lease from; the
pool's occupancy / queue-wait breakdown prints at exit.
"""
import argparse
import os
import shutil
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.registry import get_config
from repro.core.events import EventBus
from repro.core.session import Round, make_session
from repro.engine.engine import Engine, EngineConfig, run_live
from repro.engine.jax_runner import JaxBackend
from repro.engine.tools import RealToolExecutor


class Workspace:
    """Per-session sandbox (private runtime dir + guarded tools)."""

    def __init__(self, sid: int, root: str):
        self.dir = os.path.join(root, f"session_{sid}")
        os.makedirs(self.dir, exist_ok=True)
        self.tracker = []

    def _guard(self, path: str) -> str:
        full = os.path.realpath(os.path.join(self.dir, path))
        assert full.startswith(os.path.realpath(self.dir)), "fs escape"
        return full

    def file_editor(self, path: str, content: str):
        with open(self._guard(path), "w") as f:
            f.write(content)

    def terminal(self, cmd: list):
        return subprocess.run(cmd, cwd=self.dir, capture_output=True,
                              timeout=10, text=True).stdout

    def task_tracker(self, note: str):
        self.tracker.append(note)


def _print_tier_breakdown(engine):
    stats = engine.telem.kv_tier_stats()
    for tier in ("host", "disk"):
        t = stats.get(tier)
        if t is None:
            print(f"  {tier} tier: (off)")
            continue
        print(f"  {tier} tier: {t['used_blocks']}/{t['capacity_blocks']} "
              f"blocks ({t['occupancy']:.0%}), stores={t['stores']} "
              f"hit_rate={t['hit_rate']:.2f}")
    print(f"  demotions={stats['demotions']} "
          f"staged_restores={stats['staged_restores']} "
          f"direct_to_disk={stats['direct_to_disk']}")


def _print_cpu_pool(engine):
    stats = engine.cpu_pool.stats()
    leases = ", ".join(f"{k}={n}" for k, n in
                       sorted(stats["n_leases"].items())) or "none"
    busy = sum(stats["busy_s"].values())
    print(f"  {stats['cores']} cores, leases: {leases}")
    print(f"  busy={busy:.2f}s queue_wait={stats['queue_wait_total_s']:.2f}s "
          f"max_backlog={stats['max_backlog']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--disk-tier", action="store_true",
                    help="enable the NVMe cold tier (real-file spool) and "
                         "force the staged offload path at tool yields")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="write a Perfetto trace and print the per-session "
                         "critical-path breakdown at exit")
    ap.add_argument("--cpu-cores", type=int, default=2, metavar="N",
                    help="shared host-CPU pool size: tool threads and "
                         "swap/spool staging all lease from it (default 2)")
    args = ap.parse_args()

    cfg = get_config("qwen2.5-3b").reduced()
    spool = tempfile.mkdtemp(prefix="mars_spool_") if args.disk_tier else None
    backend = JaxBackend(cfg, max_slots=4, max_len=512, disk_spool=spool)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=args.cpu_cores, bus=bus)
    engine = Engine(
        EngineConfig(total_kv_blocks=4 * 511 // 32, token_budget=256,
                     max_decode_batch=4, decode_granularity=4,
                     cpu_slots=args.cpu_cores,
                     disk_tier_blocks=(1024 if args.disk_tier else 0)),
        "mars", backend, bus=bus, tool_exec=tools)
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer.install(engine)
    if args.disk_tier:
        # demo contexts are far below disk_min_tokens: force the staged
        # path so the run really exercises spill -> promote -> restore
        from repro.core.session import KVAction
        engine.policy.on_tool_yield = \
            lambda s, now: (KVAction.OFFLOAD_DISK, 0.0)

    root = tempfile.mkdtemp(prefix="mars_agents_")
    rng = np.random.default_rng(1)
    sessions = []
    try:
        for i in range(3):
            ws = Workspace(i, root)
            rounds = [
                Round(int(rng.integers(80, 160)), 12, "file_editor", 0.0),
                Round(40, 10, "terminal", 0.0),
                Round(32, 10, "task_tracker", 0.0),
                Round(24, 8, None, 0.0),
            ]
            s = make_session(0.1 * i, rounds, ideal_time=1.0)
            # real tool callables per round (the agent's actions)
            s.meta["tool_fns"] = {
                0: lambda ws=ws, i=i: ws.file_editor(
                    "solution.py", f"def answer():\n    return {i}\n"),
                1: lambda ws=ws: ws.terminal(
                    [sys.executable, "-c", "print('tests pass')"]),
                2: lambda ws=ws: ws.task_tracker("done: wrote solution"),
            }
            s.meta["workspace"] = ws
            sessions.append(s)

        t0 = time.time()
        finished, _ = run_live(engine, sessions, timeout=180)
        print(f"agent loop: {len(finished)}/3 tasks completed in "
              f"{time.time()-t0:.1f}s")
        for s in finished:
            ws = s.meta["workspace"]
            sol = os.path.join(ws.dir, "solution.py")
            print(f"  task {s.sid}: e2e {s.e2e_latency:.2f}s, "
                  f"solution_written={os.path.exists(sol)}, "
                  f"tracker={ws.tracker}")
        print("KV tier breakdown:")
        _print_tier_breakdown(engine)
        print("CPU pool:")
        _print_cpu_pool(engine)
        if tracer is not None:
            from repro.obs import breakdown_table, export_perfetto
            export_perfetto(tracer, args.trace)
            rows = [tracer.critical_path(sid)
                    for sid in tracer.finished_sids()]
            print("per-session critical-path breakdown:")
            print(breakdown_table([r for r in rows if r]))
            print(f"Perfetto trace written to {args.trace} "
                  f"(open at ui.perfetto.dev)")
    finally:
        tools.shutdown()
        backend.close()
        shutil.rmtree(root, ignore_errors=True)
        if spool is not None:
            shutil.rmtree(spool, ignore_errors=True)


if __name__ == "__main__":
    main()
