"""Quickstart: serve a small model with batched agentic requests through the
MARS engine on this host (real jit'd prefill/decode + real tool threads).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.registry import get_config
from repro.core.events import EventBus
from repro.core.session import Round, make_session
from repro.engine.engine import Engine, EngineConfig, run_live
from repro.engine.jax_runner import JaxBackend
from repro.engine.tools import RealToolExecutor


def main():
    cfg = get_config("llama3.2-1b").reduced()
    print(f"model: {cfg.name} ({cfg.param_count():,} params, reduced)")
    backend = JaxBackend(cfg, max_slots=4, max_len=512)
    print(f"calibrated oracle: prefill {backend.prefill_rate():.0f} tok/s, "
          f"decode step {backend._decode_s_per_step*1e3:.1f} ms")

    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=2, bus=bus)
    engine = Engine(
        EngineConfig(total_kv_blocks=4 * 511 // 32, block_size=32,
                     token_budget=256, max_decode_batch=4,
                     decode_granularity=4, cpu_slots=2),
        "mars", backend, bus=bus, tool_exec=tools)

    rng = np.random.default_rng(0)
    sessions = []
    for i in range(4):
        rounds = [
            Round(int(rng.integers(80, 200)), 16, "terminal", 0.3),
            Round(48, 12, "file_editor", 0.15),
            Round(32, 8, None, 0.0),
        ]
        sessions.append(make_session(0.1 * i, rounds, ideal_time=1.0))

    t0 = time.time()
    finished, _ = run_live(engine, sessions, timeout=120)
    tools.shutdown()
    print(f"\nserved {len(finished)} multi-round sessions in "
          f"{time.time()-t0:.1f}s:")
    for s in finished:
        print(f"  session {s.sid}: {len(s.rounds)} rounds, "
              f"{len(s.meta['generated'])} tokens generated, "
              f"e2e {s.e2e_latency:.2f}s, per-round TTFT "
              f"{[f'{t:.3f}s' for t in s.ttfts]}")
    warm = engine.bus.counts.get("unpin", 0)
    print(f"\nunified-info-stream event counts: { {k: v for k, v in sorted(engine.bus.counts.items())} }")
    print(f"warm resumptions (KV retained across tools): {warm}")


if __name__ == "__main__":
    main()
