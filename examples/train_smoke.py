"""Train a ~100M-param llama-family model for a few hundred steps with
checkpoint/restart, on this host.

    PYTHONPATH=src python examples/train_smoke.py [--steps 200]
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.configs.registry import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M-param reduced llama3 (vocab dominates at this scale)
    cfg = get_config("llama3.2-1b").reduced(
        d_model=args.d_model, n_layers=args.layers, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=64_000)
    print(f"training {cfg.name}: {cfg.param_count():,} params")

    ckpt_dir = tempfile.mkdtemp(prefix="mars_train_")
    t0 = time.time()
    # monkey-patch the arch lookup so train() uses our custom reduction
    import repro.launch.train as T
    orig = T.get_config
    T.get_config = lambda a: type("X", (), {"reduced": lambda self=None: cfg})()
    try:
        losses, _ = train("custom", reduced=True, steps=args.steps,
                          seq_len=256, batch=8, ckpt_dir=ckpt_dir,
                          ckpt_every=50, log_every=20)
    finally:
        T.get_config = orig
    dt = time.time() - t0
    toks = args.steps * 8 * 256
    print(f"\n{args.steps} steps ({toks:,} tokens) in {dt:.0f}s "
          f"({toks/dt:.0f} tok/s); loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
