"""Fleet-scale serving: the cluster router (paper §7 scale-out path) driving
many simulated engine replicas with failures, stragglers, and elastic join.
Demonstrates the 1000+ node control-plane story on this host.

    PYTHONPATH=src python examples/cluster_serving.py [--replicas 64]

``--trace OUT.json`` attaches one critical-path tracer per replica and
writes a multi-process Perfetto trace at exit (one process track per
replica; open at ui.perfetto.dev), plus prints the fleet-wide per-session
latency-breakdown table.
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.core.goodput import LatencyStats
from repro.distributed.router import ClusterRouter, RouterConfig
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig
from repro.models.perf_model import H100, kv_cache_bytes
from repro.workloads.generator import WorkloadSpec, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--sessions", type=int, default=96)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--kill", type=int, default=2,
                    help="replicas to fail mid-run")
    ap.add_argument("--families", type=int, default=12,
                    help="shared-prefix session families (0 = independent)")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="write a per-replica Perfetto trace and print the "
                         "fleet critical-path breakdown at exit")
    args = ap.parse_args()

    backend = SimBackend(CONFIG, H100)
    blocks = int((H100.hbm_bytes - 2.1 * CONFIG.param_count())
                 / kv_cache_bytes(CONFIG, 1) / 32)
    router = ClusterRouter(RouterConfig(heartbeat_timeout=15.0))
    engines = {}
    tracers = {}
    from repro.obs import DetectorSuite
    detectors = {}
    for i in range(args.replicas):
        rid = f"replica-{i}"
        engines[rid] = Engine(EngineConfig(total_kv_blocks=blocks,
                                           cpu_slots=16), "mars", backend)
        router.register(rid, engines[rid], now=0.0)
        # per-replica incident detectors feed the fleet health rollup
        detectors[rid] = DetectorSuite.install(engines[rid])
        if args.trace:
            from repro.obs import Tracer
            tracers[rid] = Tracer.install(engines[rid])

    spec = WorkloadSpec(regime="ILR-1", arrival_rate=args.rate,
                        n_sessions=args.sessions, seed=0,
                        max_context=CONTEXT_LIMIT,
                        n_families=args.families)
    arrivals = sorted(generate(spec, CONFIG, H100),
                      key=lambda s: s.arrival_time)
    rng = np.random.default_rng(0)
    dead = set(rng.choice(args.replicas, args.kill, replace=False))

    now, i, killed = 0.0, 0, False
    for step in range(300_000):
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            router.place(arrivals[i], now=now)
            i += 1
        if not killed and now > 60.0:           # mid-run failure injection
            killed = True
            print(f"[t={now:.0f}s] killing {sorted(dead)}")
        progressed = False
        max_el = 0.0
        for idx, (rid, eng) in enumerate(engines.items()):
            if killed and idx in dead:
                continue                         # failed: no ticks, no beats
            el, prog = eng.tick(now)
            progressed |= prog or el > 0
            max_el = max(max_el, el)
            router.heartbeat(rid, kv_utilization=eng.telem.kv_utilization,
                             tool_backlog=eng.tools.backlog,
                             active_sessions=len(eng.active),
                             step_latency=max(el, 1e-3),
                             radix_digest=eng.radix_digest(), now=now)
        router.check_failures(now=now)
        router.update_stragglers(now=now)
        router.dispatch_requeued(now=now)
        alive = [e for idx, (rid, e) in enumerate(engines.items())
                 if not (killed and idx in dead)]
        if i >= len(arrivals) and all(e.done() for e in alive) \
                and not router.requeued:
            break
        now += max(max_el, 0.25) if progressed else 2.0

    finished = [s for idx, (rid, e) in enumerate(engines.items())
                if not (killed and idx in dead) for s in e.finished]
    lat = LatencyStats.of([s.e2e_latency for s in finished])
    fail_evs = [e for e in router.events if e["ev"] == "failed"]
    prefix = router.cluster_prefix_stats()
    print(f"\nfleet: {args.replicas} replicas ({args.kill} failed mid-run), "
          f"{len(finished)}/{args.sessions} sessions completed")
    print(f"latency mean {lat.mean:.1f}s p95 {lat.p95:.1f}s; "
          f"router events: {len(fail_evs)} failures detected, "
          f"{sum(1 for e in router.events if e['ev']=='straggler_drain')} drains")
    print(f"cluster prefix reuse: hit rate "
          f"{prefix['cluster_prefix_hit_rate']:.2f} over "
          f"{prefix['cluster_prefix_queries']} sessions, "
          f"{prefix['cluster_indexed_blocks']} indexed blocks across "
          f"{len(prefix['replicas'])} advertising replicas")

    # fleet health rollup: router vitals (liveness, draining, requeue
    # depth) joined with each replica's incident counters
    from repro.obs import HealthReport
    print()
    print(HealthReport.collect(router, detectors=detectors).render())
    if args.trace:
        from repro.obs import breakdown_table, export_perfetto
        export_perfetto(tracers, args.trace)
        rows = [tr.critical_path(sid)
                for rid, tr in sorted(tracers.items())
                for sid in tr.finished_sids()]
        rows = [r for r in rows if r]
        rows.sort(key=lambda r: -r["e2e"])
        print("\nfleet critical-path breakdown (slowest sessions first):")
        print(breakdown_table(rows))
        print(f"Perfetto trace written to {args.trace} "
              f"({len(tracers)} replica process tracks; "
              f"open at ui.perfetto.dev)")


if __name__ == "__main__":
    main()
