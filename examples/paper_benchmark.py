"""Reproduce a paper operating point: Qwen3-Coder-30B x H100, ILR-2, all six
scheduling policies on the discrete-event backend (paper testbed analogue).

    PYTHONPATH=src python examples/paper_benchmark.py [--rate 0.25]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.common import POLICIES, fmt_row, run_point, \
    speedup_vs_best_baseline
from repro.configs.qwen3_coder_30b import CONFIG, CONTEXT_LIMIT
from repro.models.perf_model import H100


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=0.25)
    ap.add_argument("--regime", default="ILR-2")
    ap.add_argument("--sessions", type=int, default=24)
    args = ap.parse_args()

    rows = []
    print(f"{args.regime} @ {args.rate} req/s, {args.sessions} sessions, "
          f"Qwen3-Coder-30B on H100:\n")
    print(f"{'policy':14s} {'mean':>8s} {'p90':>8s} {'p95':>8s} "
          f"{'ttft_p95':>9s} {'goodput':>9s}")
    for policy in POLICIES:
        s = run_point(CONFIG, H100, policy, args.regime, args.rate,
                      args.sessions, max_context=CONTEXT_LIMIT)
        r = fmt_row(s)
        rows.append(r)
        print(f"{policy:14s} {r['mean_s']:8.1f} {r['p90_s']:8.1f} "
              f"{r['p95_s']:8.1f} {r['ttft_p95_s']:9.2f} "
              f"{r['goodput3_req_s']:9.5f}")
    sp = speedup_vs_best_baseline(rows)
    print(f"\nMARS vs best baseline ({sp['best_baseline_policy']}): "
          f"{sp['speedup']}x mean-latency")


if __name__ == "__main__":
    main()
