"""Paged live runner tests: BlockPool block-table export invariants, greedy
decode parity between the paged and slot-dense layouts on a shared-prefix
session family (physical sharing must not change tokens), per-block host
offload round trips, and pool consistency across swap-out/in."""
import numpy as np
import pytest

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.policies import KVAction
from repro.core.session import Round, make_session
from repro.engine.engine import Engine, EngineConfig, run_live, run_sim
from repro.kvcache import BlockPool, DeviceBindingMap

jax = pytest.importorskip("jax")


# ---------------------------------------------------------------------------
# block-table export
# ---------------------------------------------------------------------------

def test_block_table_matches_lease_order():
    p = BlockPool(16, 32)
    p.alloc(1, 3)
    p.alloc(2, 2)
    p.alloc(1, 2)                     # interleaved growth keeps lease order
    t = p.block_table(1)
    assert t.dtype == np.int32
    assert list(t) == p.lease(1)
    binding = DeviceBindingMap(16)
    tb = p.block_table(1, binding, width=8)
    assert list(tb[:5]) == p.lease(1)
    assert all(x == binding.scratch_page for x in tb[5:])


def test_block_table_shared_prefix_identical_across_siblings():
    p = BlockPool(16, 32)
    p.alloc(1, 4)
    shared = p.lease(1)[:3]
    p.acquire(2, shared)
    p.alloc(2, 1)                     # private tail
    ta, tb = p.block_table(1), p.block_table(2)
    assert list(ta[:3]) == list(tb[:3])        # same physical pages
    assert ta[3] != tb[3]                      # distinct private tails
    p.check_consistency()


def test_reacquire_requires_matching_generation():
    p = BlockPool(8, 32)
    p.alloc(1, 2)
    bid = p.lease(1)[0]
    gen = p.gen(bid)
    p.acquire(2, [bid])
    p.release_all(1)
    assert p.reacquire(3, bid, gen)            # still referenced by sid 2
    p.release_all(2)
    p.release_all(3)
    # re-taken by a fresh alloc: generation bumps, certificate is void
    p.alloc(4, 8)
    assert not p.reacquire(5, bid, gen)
    p.check_consistency()


def test_copy_on_write_is_logged_for_physical_backends():
    p = BlockPool(8, 32)
    p.alloc(1, 1)
    tail = p.lease(1)[-1]
    p.index_blocks([tail])
    assert p.copy_on_write(1)
    ((sid, src, dst),) = p.drain_cow_log()
    assert (sid, src) == (1, tail) and dst == p.lease(1)[-1]
    assert p.drain_cow_log() == []             # drained


# ---------------------------------------------------------------------------
# live parity: paged vs slot-dense
# ---------------------------------------------------------------------------

def _reduced_cfg():
    from repro.configs.registry import get_config
    return get_config("llama3.2-1b").reduced()


def _family_sessions(sids, *, shared_chunks=3, tail_chunks=1, rounds=1,
                     tool_s=0.05):
    """Shared-prefix family: identical leading chunk keys, unique tails.
    Chunk-key-derived context ids make the shared prefix byte-identical
    across members, so physically shared pages are semantically shared."""
    fam = [(("fam", i), 32) for i in range(shared_chunks)]
    first = 32 * (shared_chunks + tail_chunks)
    out = []
    for j, sid in enumerate(sids):
        rs = [Round(first, 8, "t" if rounds > 1 else None,
                    tool_s if rounds > 1 else 0.0)]
        for r in range(1, rounds):
            rs.append(Round(32, 6, "t" if r < rounds - 1 else None,
                            tool_s if r < rounds - 1 else 0.0))
        s = make_session(0.05 * j, rs, ideal_time=1.0, sid=sid)
        s.meta["prefix_hashes"] = fam + [
            (("u", sid, i), 32) for i in range(tail_chunks)]
        out.append(s)
    return out


def _run_family(layout, sids, *, policy="fcfs", yield_action=None, rounds=1):
    from repro.engine.jax_runner import JaxBackend
    from repro.engine.tools import RealToolExecutor
    backend = JaxBackend(_reduced_cfg(), layout=layout, max_slots=4,
                         max_len=256)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=2, bus=bus) if rounds > 1 else None
    eng = Engine(EngineConfig(total_kv_blocks=30, block_size=32,
                              token_budget=256, max_decode_batch=4,
                              decode_granularity=4, cpu_slots=2),
                 policy, backend, bus=bus,
                 **({"tool_exec": tools} if tools else {}))
    if yield_action is not None:
        eng.policy.on_tool_yield = lambda s, now: (yield_action, 0.0)
    finished, _ = run_live(eng, _family_sessions(sids, rounds=rounds),
                           timeout=120)
    if tools is not None:
        tools.shutdown()
    eng.check_invariants()
    return {s.sid: list(s.meta["generated"]) for s in finished}, eng


@pytest.mark.live
def test_paged_dense_greedy_decode_parity_on_shared_family():
    """The paged backend (prefix sharing ON, shared blocks physically
    shared) must emit exactly the tokens the slot-dense path (every member
    recomputes its whole context) produces."""
    sids = [91001, 91002, 91003]
    dense, _ = _run_family("dense", sids)
    paged, eng = _run_family("paged", sids)
    assert set(dense) == set(paged) == set(sids)
    assert dense == paged
    # sharing actually happened: members 2 and 3 attached the 96-token
    # prefix instead of recomputing it
    assert eng.prefix_hit_tokens >= 2 * 96
    # siblings' leases shared physical pages while resident (tracked by the
    # radix stats), and the pool stayed consistent after teardown
    eng.blocks.check_consistency()


@pytest.mark.live
def test_paged_offload_roundtrip_moves_only_private_blocks():
    """Forced OFFLOAD at every tool yield: per-block offload copies only
    the non-shared suffix over PCIe, restores exactly, and greedy tokens
    still match the slot-dense whole-slot path."""
    sids = [92001, 92002]
    dense, _ = _run_family("dense", sids, yield_action=KVAction.OFFLOAD,
                           rounds=2)
    paged, eng = _run_family("paged", sids, yield_action=KVAction.OFFLOAD,
                             rounds=2)
    assert dense == paged and set(paged) == set(sids)
    outs = [e for e in eng.bus.log if e.kind == ev.SWAP_OUT
            and e.data.get("tier") == "host"]
    assert outs, "offload path not exercised"
    # the second member's swap-out copied fewer blocks than it held: its
    # shared prefix stayed on device
    assert any(e.data["copied"] < e.data["blocks"] for e in outs)
    assert eng.host.used_blocks == 0
    eng.blocks.check_consistency()


def _dup_sessions(sids, *, shared_chunks=3, tail_tokens=16):
    """Canonical builder + exact duplicates (task retries) with a NON-block-
    aligned tail: the canonical's first decode writes into its freshly
    indexed partial tail block (copy-on-write -> device page copy), and a
    duplicate's full-context match must still compute the last chunk to
    seed decoding."""
    first = 32 * shared_chunks + tail_tokens
    h = [(("dfam", i), 32) for i in range(shared_chunks)] + \
        [(("dfam", "t"), tail_tokens)]
    out = []
    for j, sid in enumerate(sids):
        s = make_session(0.2 * j, [Round(first, 8, None, 0.0)],
                         ideal_time=1.0, sid=sid)
        s.meta["prefix_hashes"] = list(h)
        out.append(s)
    return out


@pytest.mark.live
def test_paged_duplicate_and_cow_tail_parity():
    from repro.engine.jax_runner import JaxBackend

    def run(layout, sids):
        backend = JaxBackend(_reduced_cfg(), layout=layout, max_slots=4,
                             max_len=256)
        eng = Engine(EngineConfig(total_kv_blocks=30, block_size=32,
                                  token_budget=256, max_decode_batch=4,
                                  decode_granularity=4, cpu_slots=2),
                     "fcfs", backend)
        finished, _ = run_live(eng, _dup_sessions(sids), timeout=120)
        eng.check_invariants()
        return {s.sid: list(s.meta["generated"]) for s in finished}, eng

    sids = [94001, 94002]
    dense, _ = run("dense", sids)
    paged, eng = run("paged", sids)
    assert dense == paged and set(paged) == set(sids)
    # the duplicate attached the shared chunks but recomputed the tail
    # chunk (real decoders need the last token's logits)
    assert eng.prefix_hit_tokens == 3 * 32
    # the canonical's decode into its indexed partial tail took a private
    # page copy — and tokens still matched, so the copy carried the bytes
    assert eng.blocks.cow_count >= 1
    eng.blocks.check_consistency()


# ---------------------------------------------------------------------------
# transformer-level: gather-free prefill is bit-identical to the gather path
# ---------------------------------------------------------------------------

def test_lm_prefill_paged_bitwise_matches_gather():
    """``lm_prefill_paged`` (block-table indirection, in-place page reads)
    must produce bit-identical logits and cache pages to the legacy
    ``lm_prefill_paged_gather`` (dense gather/scatter) on the CPU math
    path — across chunked prefill, physically shared prefix pages between
    two sequences, scratch-padded tables, and a ragged (padded) final
    chunk. Scratch-page content is the one allowed divergence."""
    import jax.numpy as jnp

    from repro.models import transformer as tf
    cfg = _reduced_cfg()
    params = tf.init_lm(cfg, jax.random.PRNGKey(0), jnp.float32)
    page, P, Np = 32, 8, 4
    scratch = P - 1
    cache_g = tf.PagedKVCache.zeros(cfg, P, page, jnp.float32)
    cache_n = tf.PagedKVCache.zeros(cfg, P, page, jnp.float32)
    rng = np.random.default_rng(17)

    def chunk(cache_g, cache_n, start, n_real, table, lane=32):
        toks = np.zeros(lane, np.int32)
        toks[:n_real] = rng.integers(1, 97, n_real)
        pos = np.arange(start, start + lane, dtype=np.int32)
        pos[n_real:] = Np * page - 1          # padded lanes -> scratch slot
        wp = np.where(np.arange(lane) < n_real,
                      np.asarray(table)[(start + np.arange(lane)) // page],
                      scratch).astype(np.int32)
        wo = np.where(np.arange(lane) < n_real,
                      (start + np.arange(lane)) % page,
                      np.arange(lane) % page).astype(np.int32)
        args = (jnp.asarray(toks)[None], jnp.asarray(pos)[None],
                jnp.asarray(table, jnp.int32), jnp.asarray(wp),
                jnp.asarray(wo))
        lg, cache_g = tf.lm_prefill_paged_gather(cfg, params, cache_g, *args)
        ln, cache_n = tf.lm_prefill_paged(
            cfg, params, cache_n, *args,
            jnp.asarray(start + n_real, jnp.int32))
        np.testing.assert_array_equal(np.asarray(lg[:, :n_real]),
                                      np.asarray(ln[:, :n_real]))
        live = [p for p in range(P) if p != scratch]
        np.testing.assert_array_equal(np.asarray(cache_g.k[:, live]),
                                      np.asarray(cache_n.k[:, live]))
        np.testing.assert_array_equal(np.asarray(cache_g.v[:, live]),
                                      np.asarray(cache_n.v[:, live]))
        return cache_g, cache_n

    # sequence A: three full chunks over pages [0, 1, 2]
    table_a = [0, 1, 2, scratch]
    for start in (0, 32, 64):
        cache_g, cache_n = chunk(cache_g, cache_n, start, 32, table_a)
    # sequence B: attaches A's pages [0, 1] as a physically shared prefix
    # and prefills only its private tail chunk into page 4
    table_b = [0, 1, 4, scratch]
    cache_g, cache_n = chunk(cache_g, cache_n, 64, 32, table_b)
    # sequence C: ragged final chunk — 16 real tokens in a 32-lane chunk,
    # padded lanes parked on the scratch page
    table_c = [5, 6, scratch, scratch]
    cache_g, cache_n = chunk(cache_g, cache_n, 0, 32, table_c)
    cache_g, cache_n = chunk(cache_g, cache_n, 32, 16, table_c)


# ---------------------------------------------------------------------------
# sim-level: per-block offload accounting
# ---------------------------------------------------------------------------

def test_sim_offload_host_tier_holds_only_private_blocks():
    from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
    from repro.engine.backend import SimBackend
    from repro.models.perf_model import H100
    eng = Engine(EngineConfig(total_kv_blocks=9000, block_size=32,
                              token_budget=8192, cpu_slots=8),
                 "fcfs", SimBackend(QWEN3, H100))
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    fam = [(("fam", i), 32) for i in range(48_000 // 32)]
    ss = []
    for j, sid in enumerate([93001, 93002]):
        s = make_session(200.0 * j, [Round(48_000 + 2_000, 32, "t", 30.0),
                                     Round(1_000, 16, None, 0.0)],
                         ideal_time=10.0, sid=sid)
        s.meta["prefix_hashes"] = fam + [
            (("u", sid, i), 32) for i in range(2_000 // 32)]
        ss.append(s)
    finished, _ = run_sim(eng, ss, max_time=1e5)
    assert len(finished) == 2
    outs = [e for e in eng.bus.log if e.kind == ev.SWAP_OUT
            and e.data.get("tier") == "host"]
    # the second member offloaded while the first's round-0 insert kept the
    # shared 1500 blocks alive in the index: only its private suffix crossed
    shared_blocks = 48_000 // 32
    assert any(e.data["copied"] <= e.data["blocks"] - shared_blocks
               for e in outs)
    assert eng.host.used_blocks == 0
    eng.check_invariants()
