"""Iteration-level continuous batching tests: greedy-token parity between
the mixed scheduler (token-level membership, fused prefill+decode dispatch)
and the legacy round scheduler on the live paged runner; MLFQ
quantum-by-token accounting; and the co-scheduler's prefill/decode budget
split on mixed iterations."""
import pytest

from repro.core import events as ev
from repro.core.events import EventBus
from repro.core.mlfq import MLFQConfig, PriorityCoordinator
from repro.core.policies import KVAction, Policy
from repro.core.session import Round, make_session
from repro.engine.engine import Engine, EngineConfig, run_live, run_sim


# ---------------------------------------------------------------------------
# live parity: mixed vs round on the paged runner
# ---------------------------------------------------------------------------

def _reduced_cfg():
    from repro.configs.registry import get_config
    return get_config("llama3.2-1b").reduced()


def _family_sessions(sids, *, shared_chunks=3, tail_chunks=1, rounds=1,
                     tool_s=0.05):
    """Shared-prefix family with staggered arrivals: the first member is
    mid-decode while later members are still prefilling, so the mixed
    scheduler co-dispatches its decode lane next to their chunks."""
    fam = [(("fam", i), 32) for i in range(shared_chunks)]
    first = 32 * (shared_chunks + tail_chunks)
    out = []
    for j, sid in enumerate(sids):
        rs = [Round(first, 8, "t" if rounds > 1 else None,
                    tool_s if rounds > 1 else 0.0)]
        for r in range(1, rounds):
            rs.append(Round(32, 6, "t" if r < rounds - 1 else None,
                            tool_s if r < rounds - 1 else 0.0))
        s = make_session(0.05 * j, rs, ideal_time=1.0, sid=sid)
        s.meta["prefix_hashes"] = fam + [
            (("u", sid, i), 32) for i in range(tail_chunks)]
        out.append(s)
    return out


def _run_family(scheduler, sids, *, policy="fcfs", yield_action=None,
                rounds=1, max_decode_batch=4):
    from repro.engine.jax_runner import JaxBackend
    from repro.engine.tools import RealToolExecutor
    backend = JaxBackend(_reduced_cfg(), layout="paged", max_slots=4,
                         max_len=256)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=2, bus=bus) if rounds > 1 else None
    eng = Engine(EngineConfig(total_kv_blocks=30, block_size=32,
                              token_budget=256,
                              max_decode_batch=max_decode_batch,
                              decode_granularity=4, cpu_slots=2,
                              scheduler=scheduler),
                 policy, backend, bus=bus,
                 **({"tool_exec": tools} if tools else {}))
    if yield_action is not None:
        eng.policy.on_tool_yield = lambda s, now: (yield_action, 0.0)
    finished, _ = run_live(eng, _family_sessions(sids, rounds=rounds),
                           timeout=120)
    if tools is not None:
        tools.shutdown()
    eng.check_invariants()
    return {s.sid: list(s.meta["generated"]) for s in finished}, eng


@pytest.mark.live
def test_mixed_round_greedy_parity_with_midprefill_joins():
    """Mixed batching (decode lanes riding along prefill chunks in one
    fused dispatch) must be bit-identical to the round scheduler on a
    shared-prefix family whose arrival stagger puts the first member in
    decode while siblings still prefill."""
    sids = [95001, 95002, 95003]
    rnd, _ = _run_family("round", sids)
    mix, eng = _run_family("mixed", sids)
    assert set(rnd) == set(mix) == set(sids)
    assert rnd == mix
    # the fused mixed dispatch actually ran (not the per-session fallback)
    st = eng.backend.dispatch_stats
    assert st["mixed_calls"] > 0
    eng.blocks.check_consistency()


@pytest.mark.live
def test_mixed_round_parity_under_lane_churn():
    """max_decode_batch below the family size forces sessions to join and
    leave the decode lane set between iterations — token-granular
    membership churn must not change any greedy token."""
    sids = [96001, 96002, 96003]
    rnd, _ = _run_family("round", sids, max_decode_batch=2)
    mix, _ = _run_family("mixed", sids, max_decode_batch=2)
    assert rnd == mix and set(mix) == set(sids)


@pytest.mark.live
def test_mixed_round_parity_with_tool_yield_offload():
    """Tool yields (forced OFFLOAD) interleave swap traffic with mixed
    iterations: per-block offload/restore under token-level batching must
    keep greedy tokens identical to the round scheduler."""
    sids = [97001, 97002]
    rnd, _ = _run_family("round", sids, yield_action=KVAction.OFFLOAD,
                         rounds=2)
    mix, eng = _run_family("mixed", sids, yield_action=KVAction.OFFLOAD,
                           rounds=2)
    assert rnd == mix and set(mix) == set(sids)
    outs = [e for e in eng.bus.log if e.kind == ev.SWAP_OUT
            and e.data.get("tier") == "host"]
    assert outs, "offload path not exercised"
    assert eng.host.used_blocks == 0
    eng.blocks.check_consistency()


# ---------------------------------------------------------------------------
# MLFQ: quantum-by-token accounting
# ---------------------------------------------------------------------------

def test_mlfq_charge_demotes_at_exact_quantum_crossing():
    """charge() demotes at the precise iteration the cumulative service
    crosses a quantum boundary; round-granular lumps overshoot by up to
    g-1 tokens before the level changes."""
    q = 64
    coord = PriorityCoordinator(MLFQConfig(level_quantum_tokens=q,
                                           max_demotion=2))
    s = make_session(0.0, [Round(8, 512, None, 0.0)], ideal_time=1.0,
                     sid=98001)
    # level = floor(log2(1 + service/q)) crosses 0 -> 1 exactly at
    # service == q: token-by-token charging sees the boundary iteration
    first_demote = None
    for i in range(1, 4 * q + 1):
        lvl = coord.charge(s, 1)
        if lvl >= 1 and first_demote is None:
            first_demote = i
        if lvl >= 2:
            break
    assert first_demote == q
    assert s.service_tokens == 3 * q  # 1 -> 2 exactly at 3q (log2(4))
    # round-granular accounting (g-token lumps) lands past the boundary
    g = 24
    s2 = make_session(0.0, [Round(8, 512, None, 0.0)], ideal_time=1.0,
                      sid=98002)
    served = 0
    while coord.charge(s2, g) < 1:
        served += g
    served += g
    assert served > q  # overshoot: the demotion landed g*ceil(q/g) >= q+...
    assert served == g * -(-(q + 1) // g)


def test_mlfq_charge_matches_level():
    """The level charge() returns is the same demotion component level()
    applies — one accounting rule, two call sites."""
    coord = PriorityCoordinator(MLFQConfig(level_quantum_tokens=100,
                                           max_demotion=2))
    s = make_session(0.0, [Round(8, 64, None, 0.0)], ideal_time=1.0,
                     sid=98003)
    s.admitted_at = s.last_service = 0.0
    for tokens in (50, 49, 1, 200, 10_000):
        lvl = coord.charge(s, tokens)
        assert lvl == coord._demotion(s.service_tokens)
        assert lvl <= 2  # bounded


# ---------------------------------------------------------------------------
# budget split: prefill share capped while decode lanes are live
# ---------------------------------------------------------------------------

def test_policy_prefill_budget_hooks():
    from repro.core.coscheduler import (CoSchedulerConfig,
                                        OpportunisticCoScheduler)
    from repro.core.telemetry import Telemetry, TelemetryConfig
    base = Policy.__new__(Policy)
    assert base.prefill_budget(1000, 300) == 700
    assert base.prefill_budget(1000, 1200) == 0
    cs = OpportunisticCoScheduler(CoSchedulerConfig(prefill_budget_frac=0.5),
                                  Telemetry(TelemetryConfig(), EventBus()),
                                  lambda n: 0.0)
    assert cs.split_budget(1000, 0) == 500       # capped by the frac
    assert cs.split_budget(1000, 700) == 300     # capped by what's left
    assert cs.split_budget(1000, 1200) == 0


def test_mixed_sim_caps_prefill_share_and_co_dispatches():
    """Under a prefill burst with live decode lanes, the mars policy's
    split keeps every mixed iteration's prefill share at or under
    prefill_budget_frac of the budget, decode lanes advance one token per
    iteration, and prefill chunks really co-dispatch with decodes."""
    from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
    from repro.engine.backend import SimBackend
    from repro.models.perf_model import H100
    bus = EventBus()
    ticks = []
    bus.subscribe(ev.TICK, lambda e: ticks.append(e.data))
    budget = 8192
    eng = Engine(EngineConfig(total_kv_blocks=16_384, block_size=32,
                              token_budget=budget, max_decode_batch=32,
                              cpu_slots=8, host_tier_blocks=0),
                 "mars", SimBackend(QWEN3, H100), bus=bus)
    eng.trace_ticks = True  # TICK emission is gated off by default
    ss = [make_session(0.0, [Round(2_048, 64, None, 0.0)], ideal_time=1.0,
                       sid=99000 + j) for j in range(4)]
    ss += [make_session(4.0 + 0.01 * j, [Round(24_000, 8, None, 0.0)],
                        ideal_time=1.0, sid=99100 + j) for j in range(4)]
    finished, _ = run_sim(eng, ss, max_time=1e5)
    assert len(finished) == len(ss)
    mixed = [t for t in ticks if t.get("mixed")]
    assert mixed, "mixed scheduler did not tag its ticks"
    both = [t for t in mixed
            if t["decode_tokens"] > 0 and t["prefill_tokens"] > 0]
    assert both, "no co-dispatched iteration under the burst"
    for t in both:
        assert t["prefill_tokens"] <= budget * 0.5
    # decode lanes contribute exactly one token each: decode_tokens never
    # exceeds the lane cap, and sessions deliver one token per iteration
    assert all(t["decode_tokens"] <= 32 for t in mixed)


def test_scheduler_flag_validation():
    with pytest.raises(ValueError):
        EngineConfig(total_kv_blocks=64, block_size=32, scheduler="bogus")
