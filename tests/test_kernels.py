"""Per-kernel allclose sweeps: Pallas (interpret=True) vs the pure-jnp
oracles, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.wkv6 import wkv6

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 8, 256, 256, 64),
    (1, 8, 2, 64, 192, 128),
    (2, 2, 1, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Hq, Hkv, Sq, Skv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=True, q_offset=Skv - Sq,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=Skv - Sq)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,softcap,causal", [
    (64, None, True), (None, 30.0, True), (32, 50.0, True), (None, None, False),
])
def test_flash_attention_masking_variants(window, softcap, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,D,page,P,maxp", [
    (4, 8, 2, 64, 32, 64, 8),
    (2, 4, 4, 128, 16, 32, 4),
    (1, 16, 8, 64, 32, 16, 6),
    (3, 6, 2, 64, 8, 40, 10),
])
def test_paged_attention_shapes(B, Hq, Hkv, D, page, P, maxp):
    rng = np.random.default_rng(B * 7 + P)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    table = jnp.asarray(rng.integers(0, P, (B, maxp)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * page + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_attention_bf16():
    rng = np.random.default_rng(0)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 64), jnp.bfloat16)
    kp = jax.random.normal(ks[1], (16, 16, 2, 64), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (16, 16, 2, 64), jnp.bfloat16)
    table = jnp.asarray(rng.integers(0, 16, (2, 4)), jnp.int32)
    lengths = jnp.asarray([30, 64], jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 64, 2, 32, 32), (1, 128, 4, 64, 32), (2, 96, 1, 64, 16),
    (1, 32, 2, 32, 8),
])
def test_wkv6_shapes(B, T, H, K, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K), jnp.float32) * 0.5
    w = jnp.exp(-jnp.exp(
        jax.random.normal(ks[3], (B, T, H, K), jnp.float32) * 0.5 - 1.0))
    u = jax.random.normal(ks[4], (H, K), jnp.float32) * 0.3
    s0 = jax.random.normal(ks[0], (B, H, K, K), jnp.float32) * 0.1
    o, sT = wkv6(r, k, v, w, u, s0, chunk=chunk)
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref), atol=5e-4)


def test_wkv6_strong_decay_stability():
    """Strong decay (w near clip floor) must not overflow the chunked form."""
    B, T, H, K = 1, 64, 2, 32
    r = jnp.ones((B, T, H, K)) * 0.3
    k = jnp.ones((B, T, H, K)) * 0.3
    v = jnp.ones((B, T, H, K))
    w = jnp.full((B, T, H, K), 0.25)       # near the decay clip floor
    u = jnp.zeros((H, K))
    s0 = jnp.zeros((B, H, K, K))
    o, sT = wkv6(r, k, v, w, u, s0, chunk=32)
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_model_chunked_wkv_matches_ref():
    from repro.models.rwkv6 import wkv6_chunked
    ks = jax.random.split(KEY, 5)
    B, T, H, K = 2, 96, 2, 32
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.4
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.4
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.4
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.3 - 1.5))
    u = jax.random.normal(ks[4], (H, K)) * 0.2
    s0 = jnp.zeros((B, H, K, K))
    o, sT = wkv6_chunked(r, k, v, w, u, s0, chunk=16)
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref), atol=5e-4)


def test_ops_dispatch():
    from repro.kernels import ops
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    a = ops.attention(q, k, k, use_kernel=True)
    b = ops.attention(q, k, k, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
