"""Per-kernel allclose sweeps: Pallas (interpret=True) vs the pure-jnp
oracles, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.wkv6 import wkv6

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 8, 256, 256, 64),
    (1, 8, 2, 64, 192, 128),
    (2, 2, 1, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Hq, Hkv, Sq, Skv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=True, q_offset=Skv - Sq,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=Skv - Sq)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,softcap,causal", [
    (64, None, True), (None, 30.0, True), (32, 50.0, True), (None, None, False),
])
def test_flash_attention_masking_variants(window, softcap, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,D,page,P,maxp", [
    (4, 8, 2, 64, 32, 64, 8),
    (2, 4, 4, 128, 16, 32, 4),
    (1, 16, 8, 64, 32, 16, 6),
    (3, 6, 2, 64, 8, 40, 10),
])
def test_paged_attention_shapes(B, Hq, Hkv, D, page, P, maxp):
    rng = np.random.default_rng(B * 7 + P)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    table = jnp.asarray(rng.integers(0, P, (B, maxp)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * page + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_attention_bf16():
    rng = np.random.default_rng(0)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 64), jnp.bfloat16)
    kp = jax.random.normal(ks[1], (16, 16, 2, 64), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (16, 16, 2, 64), jnp.bfloat16)
    table = jnp.asarray(rng.integers(0, 16, (2, 4)), jnp.int32)
    lengths = jnp.asarray([30, 64], jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 64, 2, 32, 32), (1, 128, 4, 64, 32), (2, 96, 1, 64, 16),
    (1, 32, 2, 32, 8),
])
def test_wkv6_shapes(B, T, H, K, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K), jnp.float32) * 0.5
    w = jnp.exp(-jnp.exp(
        jax.random.normal(ks[3], (B, T, H, K), jnp.float32) * 0.5 - 1.0))
    u = jax.random.normal(ks[4], (H, K), jnp.float32) * 0.3
    s0 = jax.random.normal(ks[0], (B, H, K, K), jnp.float32) * 0.1
    o, sT = wkv6(r, k, v, w, u, s0, chunk=chunk)
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref), atol=5e-4)


def test_wkv6_strong_decay_stability():
    """Strong decay (w near clip floor) must not overflow the chunked form."""
    B, T, H, K = 1, 64, 2, 32
    r = jnp.ones((B, T, H, K)) * 0.3
    k = jnp.ones((B, T, H, K)) * 0.3
    v = jnp.ones((B, T, H, K))
    w = jnp.full((B, T, H, K), 0.25)       # near the decay clip floor
    u = jnp.zeros((H, K))
    s0 = jnp.zeros((B, H, K, K))
    o, sT = wkv6(r, k, v, w, u, s0, chunk=32)
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_model_chunked_wkv_matches_ref():
    from repro.models.rwkv6 import wkv6_chunked
    ks = jax.random.split(KEY, 5)
    B, T, H, K = 2, 96, 2, 32
    r = jax.random.normal(ks[0], (B, T, H, K)) * 0.4
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.4
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.4
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.3 - 1.5))
    u = jax.random.normal(ks[4], (H, K)) * 0.2
    s0 = jnp.zeros((B, H, K, K))
    o, sT = wkv6_chunked(r, k, v, w, u, s0, chunk=16)
    o_ref, s_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(s_ref), atol=5e-4)


def test_ops_dispatch():
    from repro.kernels import ops
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    a = ops.attention(q, k, k, use_kernel=True)
    b = ops.attention(q, k, k, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# --- gather-free paged prefill -------------------------------------------

def _paged_prefill_case(seed, B, Hq, Hkv, D, page, P, Np, Sq):
    """Random chunked-prefill instance: tables with duplicate (shared)
    pages, kv_len short of the table capacity (scratch tail positions point
    at live pool pages whose content must not leak), nonzero q_offset."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    # draw from a small id range so duplicates (physically shared pages)
    # show up within and across rows
    table = jnp.asarray(rng.integers(0, min(P, 4), (B, Np)), jnp.int32)
    kv_len = jnp.asarray(
        rng.integers(Sq, (Np - 1) * page + 1, (B,)), jnp.int32)
    q_offset = (kv_len - Sq).astype(jnp.int32)
    return q, kp, vp, table, kv_len, q_offset


@pytest.mark.parametrize("B,Hq,Hkv,D,page,P,Np,Sq,block_q", [
    (2, 4, 2, 64, 32, 8, 4, 64, 32),
    (1, 8, 8, 64, 16, 6, 6, 64, 64),
    (2, 2, 1, 128, 32, 8, 4, 32, 32),
    (1, 4, 2, 64, 32, 8, 8, 96, 32),   # 8-page context, multi-block chunk
])
def test_paged_flash_attention_vs_ref(B, Hq, Hkv, D, page, P, Np, Sq,
                                      block_q):
    from repro.kernels.paged_flash_attention import paged_flash_attention
    q, kp, vp, table, kv_len, q_offset = _paged_prefill_case(
        B * 31 + Np, B, Hq, Hkv, D, page, P, Np, Sq)
    out = paged_flash_attention(q, kp, vp, table, kv_len, q_offset,
                                block_q=block_q)
    want = ref.paged_flash_attention_ref(q, kp, vp, table, kv_len, q_offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_flash_attention_vs_dense_flash():
    """Contiguous tables over distinct pages == dense flash attention."""
    from repro.kernels.paged_flash_attention import paged_flash_attention
    B, Hq, Hkv, D, page, Np, Sq = 2, 4, 2, 64, 32, 4, 64
    S = Np * page
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    # pool layout: page p of row b lives at pool id b*Np + p
    kp = k.transpose(0, 2, 1, 3).reshape(B * Np, page, Hkv, D)
    vp = v.transpose(0, 2, 1, 3).reshape(B * Np, page, Hkv, D)
    table = jnp.arange(B * Np, dtype=jnp.int32).reshape(B, Np)
    kv_len = jnp.full((B,), S, jnp.int32)
    q_offset = jnp.full((B,), S - Sq, jnp.int32)
    out = paged_flash_attention(q, kp, vp, table, kv_len, q_offset,
                                block_q=32)
    want = flash_attention(q, k, v, causal=True, q_offset=S - Sq,
                           block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_flash_attention_shared_prefix_rows():
    """Two rows whose tables point at the same physical prefix pages must
    see identical prefix keys (CoW families dup table entries, not pages)."""
    from repro.kernels.paged_flash_attention import paged_flash_attention
    B, Hq, Hkv, D, page, P, Np, Sq = 2, 4, 2, 64, 32, 8, 4, 32
    ks = jax.random.split(KEY, 3)
    q0 = jax.random.normal(ks[0], (1, Hq, Sq, D), jnp.float32)
    q = jnp.concatenate([q0, q0], axis=0)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    # shared prefix pages 0..2, divergent tails 3 vs 4 — but kv_len stops
    # inside the shared prefix, so both rows attend to identical context
    table = jnp.asarray([[0, 1, 2, 3], [0, 1, 2, 4]], jnp.int32)
    kv_len = jnp.full((B,), 3 * page, jnp.int32)
    q_offset = kv_len - Sq
    out = paged_flash_attention(q, kp, vp, table, kv_len, q_offset,
                                block_q=32)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))


@pytest.mark.parametrize("Sq,block_q", [(100, 64), (65, 64), (1, 64)])
def test_flash_attention_ragged_q(Sq, block_q):
    """Final q block may be ragged: Sq need not divide block_q."""
    ks = jax.random.split(KEY, 3)
    Skv = 128
    q = jax.random.normal(ks[0], (1, 4, Sq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, Skv, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, Skv, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=Skv - Sq,
                          block_q=block_q, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=Skv - Sq)
    assert out.shape == (1, 4, Sq, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("Sq,block_q", [(33, 32), (7, 32)])
def test_paged_flash_attention_ragged_q(Sq, block_q):
    from repro.kernels.paged_flash_attention import paged_flash_attention
    q, kp, vp, table, kv_len, q_offset = _paged_prefill_case(
        11, 2, 4, 2, 64, 32, 8, 4, Sq)
    out = paged_flash_attention(q, kp, vp, table, kv_len, q_offset,
                                block_q=block_q)
    want = ref.paged_flash_attention_ref(q, kp, vp, table, kv_len, q_offset)
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# --- fused decode KV write -----------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,D,page,P,maxp", [
    (4, 8, 2, 64, 32, 64, 8),
    (2, 4, 4, 128, 16, 32, 4),
    (3, 6, 2, 64, 8, 40, 10),
])
def test_paged_attention_fused_write(B, Hq, Hkv, D, page, P, maxp):
    """Fused kernel == scatter-then-attend, and only the write slots moved."""
    from repro.kernels.paged_attention import paged_attention_fused
    rng = np.random.default_rng(B * 13 + P)
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    k_new = jax.random.normal(ks[3], (B, Hkv, D), jnp.float32)
    v_new = jax.random.normal(ks[4], (B, Hkv, D), jnp.float32)
    # distinct pages per row so the slot contract is unambiguous
    pages = rng.choice(P, (B, maxp), replace=False).astype(np.int32)
    table = jnp.asarray(pages, jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * page + 1, (B,)), jnp.int32)
    wp = table[jnp.arange(B), (lengths - 1) // page]
    wo = ((lengths - 1) % page).astype(jnp.int32)
    out, kp2, vp2 = paged_attention_fused(q, kp, vp, table, lengths,
                                          k_new, v_new, wp, wo)
    kp_want = kp.at[wp, wo].set(k_new)
    vp_want = vp.at[wp, wo].set(v_new)
    want = ref.paged_attention_ref(q, kp_want, vp_want, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # pool state: write slots carry the new token, everything else intact
    np.testing.assert_array_equal(np.asarray(kp2), np.asarray(kp_want))
    np.testing.assert_array_equal(np.asarray(vp2), np.asarray(vp_want))


def test_ops_fused_decode_dispatch():
    """ops.decode_attention with fused write: kernel vs ref path agree on
    output and on the returned pool state."""
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    ks = jax.random.split(KEY, 5)
    B, Hq, Hkv, D, page, P, maxp = 2, 4, 2, 64, 16, 16, 4
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), jnp.float32)
    k_new = jax.random.normal(ks[3], (B, Hkv, D), jnp.float32)
    v_new = jax.random.normal(ks[4], (B, Hkv, D), jnp.float32)
    table = jnp.asarray(rng.choice(P, (B, maxp), replace=False), jnp.int32)
    lengths = jnp.asarray([30, 50], jnp.int32)
    wp = table[jnp.arange(B), (lengths - 1) // page]
    wo = ((lengths - 1) % page).astype(jnp.int32)
    oa, ka, va = ops.decode_attention(q, kp, vp, table, lengths,
                                      k_new=k_new, v_new=v_new,
                                      write_pages=wp, write_offsets=wo,
                                      use_kernel=True)
    ob, kb, vb = ops.decode_attention(q, kp, vp, table, lengths,
                                      k_new=k_new, v_new=v_new,
                                      write_pages=wp, write_offsets=wo,
                                      use_kernel=False)
    np.testing.assert_allclose(np.asarray(oa), np.asarray(ob), atol=2e-5)
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


def test_ops_prefill_dispatch():
    from repro.kernels import ops
    q, kp, vp, table, kv_len, q_offset = _paged_prefill_case(
        5, 2, 4, 2, 64, 32, 8, 4, 64)
    a = ops.prefill_attention(q, kp, vp, table, kv_len, q_offset,
                              use_kernel=True)
    b = ops.prefill_attention(q, kp, vp, table, kv_len, q_offset,
                              use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
