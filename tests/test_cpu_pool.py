"""Shared host-CPU core pool (core/cpu_pool) and its consumers: eager
deterministic scheduling with interference stretch, cancel backfill,
transfer-priority placement, the ToolExecutor protocol, the Services
policy-binding API, and the control plane's CPU-oversubscription
admission term."""
import pytest

from repro.core import events as ev
from repro.core.admission import ControlPlaneConfig, ExternalControlPlane
from repro.core.cpu_pool import CpuPool, CpuPoolConfig
from repro.core.events import EventBus
from repro.core.policies import MARSPolicy, Policy, Services
from repro.core.session import Round, make_session
from repro.core.telemetry import Telemetry, TelemetryConfig
from repro.engine.tools import RealToolExecutor, SimToolExecutor, ToolExecutor


class _Oracle:
    def recompute_time(self, n_tokens):
        return n_tokens / 1000.0

    def swap_time(self, n_tokens):
        return n_tokens / 5000.0

    def prefill_rate(self):
        return 1000.0


def _pool(cores=2, interference=0.5):
    return CpuPool(CpuPoolConfig(cores=cores, interference=interference))


# ---------------------------------------------------------------------------
# Pool scheduling model
# ---------------------------------------------------------------------------

def test_interference_stretch_deterministic():
    """Eager placement fixes (start, end, stretch) at submit, and the same
    submit sequence reproduces the identical schedule: stretch depends only
    on co-busy cores at the placed start."""
    for _ in range(2):                      # same sequence twice -> identical
        p = _pool(cores=2, interference=0.5)
        a = p.submit(0.0, 10.0)
        b = p.submit(0.0, 10.0)
        c = p.submit(0.0, 10.0)
        assert (a.start, a.stretch, a.end) == (0.0, 1.0, 10.0)
        # b starts beside running a: 1 busy other core of 2 -> 1.25x
        assert (b.start, b.stretch, b.end) == (0.0, 1.25, 12.5)
        # c queues behind a (earliest core), placed beside still-running b
        assert (c.start, c.stretch, c.end) == (10.0, 1.25, 22.5)
        assert c.queue_wait == pytest.approx(10.0)


def test_cancel_queued_releases_core_and_backfills():
    p = _pool(cores=1, interference=0.0)
    a = p.submit(0.0, 10.0)
    b = p.submit(0.0, 10.0)
    c = p.submit(0.0, 5.0)
    assert (b.start, b.end) == (10.0, 20.0)
    assert (c.start, c.end) == (20.0, 25.0)
    p.cancel(b, 0.0)
    # c backfills into b's released slot; a's announced schedule never moves
    assert (a.start, a.end) == (0.0, 10.0)
    assert (c.start, c.end) == (10.0, 15.0)
    assert p.next_event_time() == 10.0


def test_cancel_running_frees_core_now():
    p = _pool(cores=1, interference=0.0)
    a = p.submit(0.0, 10.0)
    b = p.submit(0.0, 10.0)
    p.advance(1.0)                          # a reported started
    p.cancel(a, 4.0)
    assert (b.start, b.end) == (4.0, 14.0)


def test_transfer_priority_placed_ahead_of_queued_tools():
    """A class-0 staging copy goes ahead of waiting tools (never preempts a
    running one) and pushes the queued tool back by its service time."""
    p = _pool(cores=1, interference=0.0)
    a = p.submit(0.0, 10.0, kind="tool")
    b = p.submit(0.0, 10.0, kind="tool")
    sw = p.submit(0.0, 2.0, kind="swap", priority=0)
    assert (a.start, a.end) == (0.0, 10.0)      # running: untouched
    assert (sw.start, sw.end) == (10.0, 12.0)   # jumps the queued tool
    assert (b.start, b.end) == (12.0, 22.0)
    assert p.next_event_time("swap") == 12.0


def test_horizon_wait_is_work_in_system_over_cores():
    p = _pool(cores=4, interference=0.0)
    p.submit(0.0, 10.0)
    p.submit(0.0, 10.0)
    assert p.horizon_wait(0.0) == pytest.approx(5.0)        # 20s over 4 cores
    assert p.horizon_wait(0.0, extra_backlog_s=20.0) == pytest.approx(10.0)
    assert p.horizon_wait(10.0) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# Executors on the shared pool
# ---------------------------------------------------------------------------

def _sim_exec(cores=1):
    bus = EventBus()
    pool = CpuPool(CpuPoolConfig(cores=cores, interference=0.0))
    return SimToolExecutor(pool, bus), bus


def _tool_session(t0=0.0):
    return make_session(t0, [Round(320, 10, "terminal", 5.0),
                             Round(32, 10, None, 0.0)])


def test_sim_executor_next_event_time_includes_queueing():
    ex, _ = _sim_exec(cores=1)
    s1, s2 = _tool_session(), _tool_session()
    ex.start(s1, "terminal", 10.0, 0.0)
    ex.start(s2, "terminal", 10.0, 0.0)
    assert ex.next_event_time() == 10.0
    done = ex.poll(10.0)
    assert [s.sid for s in done] == [s1.sid]
    # s2's completion is at 20.0 (10s queue wait + 10s service), not 10.0
    assert ex.next_event_time() == 20.0
    assert ex.poll(20.0) == [s2]


def test_sim_executor_cancel_releases_pool_lease():
    ex, _ = _sim_exec(cores=1)
    s1, s2, s3 = _tool_session(), _tool_session(), _tool_session()
    ex.start(s1, "terminal", 10.0, 0.0)
    ex.start(s2, "terminal", 10.0, 0.0)
    ex.start(s3, "terminal", 5.0, 0.0)
    ex.cancel(s2.sid, 0.0)
    # s3 backfills into the released slot: completes at 15, not 25
    assert ex.next_event_time() == 10.0
    assert ex.poll(10.0) == [s1]
    assert ex.next_event_time() == 15.0
    assert ex.poll(15.0) == [s3]
    # the cancelled session never completes, and nothing lingers
    assert ex.poll(100.0) == []
    assert ex.active == 0 and ex.backlog == 0


def test_tool_start_event_carries_queue_wait():
    ex, bus = _sim_exec(cores=1)
    waits = {}
    bus.subscribe(ev.TOOL_START,
                  lambda e: waits.__setitem__(e.sid, e.data["queue_wait"]))
    s1, s2 = _tool_session(), _tool_session()
    ex.start(s1, "terminal", 10.0, 0.0)
    ex.start(s2, "terminal", 10.0, 0.0)
    ex.poll(20.0)
    assert waits[s1.sid] == pytest.approx(0.0)
    assert waits[s2.sid] == pytest.approx(10.0)


def test_executor_protocol_conformance():
    sim, _ = _sim_exec()
    assert isinstance(sim, ToolExecutor)
    real = RealToolExecutor(2, EventBus())
    try:
        assert isinstance(real, ToolExecutor)
        # both draw capacity from a CpuPool (shared with swap/spool staging)
        assert isinstance(sim.pool, CpuPool)
        assert isinstance(real.pool, CpuPool)
        assert real.next_event_time() is None   # wall-clock path
    finally:
        real.shutdown()


def test_executors_share_one_pool_with_transfers():
    """A transfer lease on the shared pool delays a queued tool — the
    coupled-pressure behavior the executor protocol exists for."""
    pool = CpuPool(CpuPoolConfig(cores=1, interference=0.0))
    ex = SimToolExecutor(pool, EventBus())
    s = _tool_session()
    pool.submit(0.0, 4.0, kind="swap", priority=0)
    ex.start(s, "terminal", 10.0, 0.0)
    assert ex.next_event_time() == 14.0


# ---------------------------------------------------------------------------
# Services binding API (and the bind_services deprecation shim)
# ---------------------------------------------------------------------------

def _telem(cpu_slots=8):
    bus = EventBus()
    return Telemetry(TelemetryConfig(cpu_slots=cpu_slots), bus), bus


def test_policy_bind_services_dataclass():
    t, bus = _telem()
    p = Policy(t, bus, _Oracle())
    pool, tier = object(), object()
    p.bind(Services(host_tier=tier, async_swap=True, cpu_pool=pool))
    assert p.host_tier is tier
    assert p.async_swap is True
    assert p.cpu_pool is pool
    assert p.disk_tier is None


def test_bind_services_shim_warns_and_routes_through_bind():
    """The deprecated kwarg form must route through bind(), so subclass
    extensions (MARS wiring control.cpu_pool / cosched.cpu_wait) still
    run."""
    t, bus = _telem()
    p = MARSPolicy(t, bus, _Oracle())
    pool = _pool(cores=2)
    with pytest.warns(DeprecationWarning):
        p.bind_services(cpu_pool=pool)
    assert p.cpu_pool is pool
    assert p.control.cpu_pool is pool
    assert p.cosched.cpu_wait is not None
    # and the modern path wires identically
    p2 = MARSPolicy(t, bus, _Oracle())
    p2.bind(Services(cpu_pool=pool))
    assert p2.control.cpu_pool is pool
    assert p2.cosched.cpu_wait is not None


# ---------------------------------------------------------------------------
# Admission CPU-oversubscription term
# ---------------------------------------------------------------------------

def _control_plane(bound_s, cores=2):
    t, bus = _telem(cpu_slots=8)
    t.probe_gpu(100_000, 100_000, 0, 0, 0, 0)
    cp = ExternalControlPlane(
        ControlPlaneConfig(w_init=16.0, cpu_queue_bound_s=bound_s), t, bus)
    cp.cpu_pool = CpuPool(CpuPoolConfig(cores=cores))
    return cp, bus


def _admission_sessions():
    """Ascending-footprint order: two tool-bearing sessions then a
    tool-free one (per-kind EMA is empty, so each tool round prices at the
    8s telemetry default)."""
    s1 = make_session(0.0, [Round(3200, 10, "terminal", 5.0),
                            Round(32, 10, None, 0.0)])
    s2 = make_session(0.01, [Round(6400, 10, "terminal", 5.0),
                             Round(32, 10, None, 0.0)])
    s3 = make_session(0.02, [Round(9600, 10, None, 0.0)])
    return s1, s2, s3


def test_admission_defers_on_committed_cpu_but_passes_tool_free():
    cp, _ = _control_plane(bound_s=1.0)
    s1, s2, s3 = _admission_sessions()
    admitted = cp.balance_and_admit([s1, s2, s3], now=0.0)
    # s1 admits on the idle pool (its own estimate never prices itself);
    # its 8s/2-core commitment then pushes s2 past the 1s bound; the
    # tool-free s3 behind it still passes
    assert [s.sid for s in admitted] == [s1.sid, s3.sid]
    assert cp.cpu_deferred == 1


def test_admission_commitment_cleared_on_finish():
    cp, bus = _control_plane(bound_s=1.0)
    s1, s2, _ = _admission_sessions()
    cp.balance_and_admit([s1, s2], now=0.0)
    assert cp.cpu_deferred == 1
    # a deferral is a skip, not a reject: once the admitted session
    # finishes (commitment released) the deferred one gets in
    bus.emit(ev.FINISH, 50.0, s1.sid)
    admitted = cp.balance_and_admit([s2], now=50.0)
    assert [s.sid for s in admitted] == [s2.sid]


def test_admission_prices_scheduled_pool_work():
    cp, _ = _control_plane(bound_s=1.0)
    s1, _, s3 = _admission_sessions()
    # park long tool leases on every core: horizon_wait >> bound
    cp.cpu_pool.submit(0.0, 100.0, kind="tool")
    cp.cpu_pool.submit(0.0, 100.0, kind="tool")
    admitted = cp.balance_and_admit([s1, s3], now=0.0)
    assert [s.sid for s in admitted] == [s3.sid]
    assert cp.cpu_deferred == 1


def test_admission_term_off_by_default():
    cp, _ = _control_plane(bound_s=float("inf"))
    s1, s2, s3 = _admission_sessions()
    cp.cpu_pool.submit(0.0, 1000.0, kind="tool")
    admitted = cp.balance_and_admit([s1, s2, s3], now=0.0)
    assert len(admitted) == 3
    assert cp.cpu_deferred == 0
