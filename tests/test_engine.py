"""Engine integration tests on the simulated backend: every policy completes
workloads, invariants hold every tick, retention/swap paths exercise, and the
MARS ordering properties show up in the metrics."""
import pytest

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3, CONTEXT_LIMIT
from repro.core import events as ev
from repro.core.goodput import summarize
from repro.core.session import Phase, Round, make_session
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100
from repro.workloads.generator import WorkloadSpec, generate

ALL_POLICIES = ["fcfs", "autellix", "infercept", "continuum", "continuum-dy",
                "mars", "mars-no-ctrl", "mars-no-coord", "mars-no-cosched"]


def _engine(policy, blocks=9000, cpu_slots=8):
    return Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                               token_budget=8192, max_decode_batch=64,
                               decode_granularity=8, cpu_slots=cpu_slots),
                  policy, SimBackend(QWEN3, H100))


def _workload(n=12, rate=0.2, regime="ILR-1", seed=3):
    spec = WorkloadSpec(regime=regime, arrival_rate=rate, n_sessions=n,
                        seed=seed, max_context=CONTEXT_LIMIT)
    return generate(spec, QWEN3, H100)


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_policy_completes_workload(policy):
    eng = _engine(policy)
    sessions = _workload()
    finished, horizon = run_sim(eng, sessions, max_time=5e4)
    assert len(finished) == len(sessions), f"{policy} finished {len(finished)}"
    eng.check_invariants()
    for s in finished:
        assert s.finish_time > s.arrival_time
        assert len(s.ttfts) == len(s.rounds)
        assert all(t >= 0 for t in s.ttfts)


def test_invariants_every_tick():
    eng = _engine("mars", blocks=6000)
    sessions = _workload(n=8, rate=0.5)
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i, now = 0, 0.0
    for _ in range(20_000):
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            eng.submit(arrivals[i])
            i += 1
        elapsed, prog = eng.tick(now)
        eng.check_invariants()
        if elapsed:
            now += elapsed
        elif not prog:
            nxt = eng.tools.next_event_time()
            if nxt is None:
                nxt = eng.next_timer_event(now)   # pin TTLs / host DMA
            if nxt is None and i < len(arrivals):
                nxt = arrivals[i].arrival_time
            if nxt is None and eng.waiting:
                nxt = now + 0.5
            if nxt is None:
                break
            now = max(now + 1e-9, nxt)
        if eng.done() and i >= len(arrivals):
            break
    assert eng.done()
    assert len(eng.finished) + len(eng.rejected) == len(sessions)


def test_oversized_session_rejected():
    eng = _engine("mars", blocks=100)    # 3200-token pool
    from repro.core.session import Round, make_session
    s = make_session(0.0, [Round(50_000, 8, None, 0.0)], ideal_time=1.0)
    eng.submit(s)
    assert s in eng.rejected and not eng.waiting


def test_unified_stream_round_trip_events():
    """Every round produces submit -> first_token -> end, with stable sids."""
    eng = _engine("mars")
    sessions = _workload(n=6)
    finished, _ = run_sim(eng, sessions, max_time=5e4)
    for s in finished:
        subs = [e for e in eng.bus.log
                if e.kind == ev.GPU_SUBMIT and e.sid == s.sid]
        firsts = [e for e in eng.bus.log
                  if e.kind == ev.GPU_FIRST_TOKEN and e.sid == s.sid]
        ends = [e for e in eng.bus.log
                if e.kind == ev.GPU_END and e.sid == s.sid]
        assert len(subs) == len(s.rounds)
        assert len(firsts) == len(s.rounds)
        assert len(ends) == len(s.rounds)
        tools = [e for e in eng.bus.log
                 if e.kind == ev.TOOL_START and e.sid == s.sid]
        assert len(tools) == len(s.rounds) - 1


def test_fcfs_orders_by_arrival():
    """Under FCFS with one giant prefill ahead, TTFT of round 0 should be
    ordered by arrival for same-size sessions."""
    eng = _engine("fcfs", blocks=30_000)
    rounds = lambda: [Round(20_000, 64, None, 0.0)]
    ss = [make_session(i * 0.1, rounds(), ideal_time=1.0) for i in range(5)]
    finished, _ = run_sim(eng, ss, max_time=1e4)
    ftimes = {s.sid: s.finish_time for s in finished}
    sids = [s.sid for s in sorted(ss, key=lambda x: x.arrival_time)]
    assert [ftimes[i] for i in sids] == sorted(ftimes.values())


def test_mars_prioritizes_short_continuations():
    """A tiny interactive session arriving behind a repo-scale prefill should
    finish far earlier under MARS than the big one (HoL resolved)."""
    eng = _engine("mars", blocks=12_000)
    big = make_session(0.0, [Round(200_000, 128, None, 0.0)], ideal_time=30.0)
    small = make_session(1.0, [Round(512, 64, None, 0.0)], ideal_time=2.0)
    finished, _ = run_sim(eng, [big, small], max_time=1e4)
    f = {s.sid: s.finish_time for s in finished}
    assert f[small.sid] < f[big.sid]


def test_infercept_swap_roundtrip():
    eng = _engine("infercept")
    sessions = _workload(n=6, seed=11)
    finished, _ = run_sim(eng, sessions, max_time=5e4)
    assert len(finished) == 6
    kinds = eng.bus.counts
    # swap path exercised at least once under these sizes
    assert kinds.get(ev.SWAP_OUT, 0) + kinds.get(ev.PIN, 0) > 0


def test_continuum_ttl_expiry_releases_blocks():
    eng = _engine("continuum")
    s = make_session(0.0, [Round(60_000, 32, "terminal", 500.0),
                           Round(1_000, 32, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=5e4)
    assert len(finished) == 1
    # fixed TTL (30s) < 500s tool => pin must have been revoked
    assert eng.bus.counts.get(ev.PIN, 0) >= 1
    revokes = [e for e in eng.bus.log if e.kind == ev.EVICT
               and e.data.get("reason") == "pin_revoked"]
    assert revokes, "TTL expiry should release the pinned KV"


def test_mars_warm_resume_fast_second_round():
    """With ample memory and a short tool, MARS pins KV and round 2 TTFT is
    dramatically smaller than a cold rebuild would be."""
    eng = _engine("mars", blocks=30_000)
    s = make_session(0.0, [Round(100_000, 32, "file_editor", 2.0),
                           Round(2_000, 32, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=1e4)
    (f,) = finished
    assert eng.bus.counts.get(ev.UNPIN, 0) >= 1          # warm resume
    assert f.ttfts[1] < 0.5 * f.ttfts[0]


def test_preempted_session_recovers():
    eng = _engine("mars", blocks=5500)   # pool ~1.5 typical sessions
    ss = _workload(n=6, rate=2.0, seed=5)
    finished, _ = run_sim(eng, ss, max_time=1e5)
    # oversized sessions get admission-rejected; everything admitted finishes
    assert len(finished) + len(eng.rejected) == 6
    assert len(finished) >= 3
    eng.check_invariants()


def test_goodput_summary_fields():
    eng = _engine("mars")
    finished, horizon = run_sim(eng, _workload(n=5), max_time=5e4)
    s = summarize(finished, horizon)
    assert s["n_finished"] == 5
    assert s["latency"].mean > 0 and s["token_throughput"] > 0
    assert set(s["goodput"]) == {1.0, 2.0, 3.0}
