"""End-to-end behaviour tests for the full MARS system: paper-level claims
reproduced at test scale (latency ordering, TTFT advantage, ablations, KV
dynamics), plus the live-JAX engine and training loop."""
import numpy as np
import pytest

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3, CONTEXT_LIMIT
from repro.core.goodput import summarize
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100
from repro.workloads.generator import WorkloadSpec, describe, generate


def _run(policy, n=16, rate=0.25, regime="ILR-2", seed=4, blocks=9500):
    spec = WorkloadSpec(regime=regime, arrival_rate=rate, n_sessions=n,
                        seed=seed, max_context=CONTEXT_LIMIT)
    sessions = generate(spec, QWEN3, H100)
    eng = Engine(EngineConfig(total_kv_blocks=blocks, cpu_slots=16),
                 policy, SimBackend(QWEN3, H100))
    finished, horizon = run_sim(eng, sessions, max_time=1e5)
    return summarize(finished, horizon), eng


def test_workload_matches_paper_regimes():
    """ILR prompt volumes grow monotonically ~125K->263K (paper Fig. 6)."""
    means = []
    for regime in ("ILR-1", "ILR-2", "ILR-3", "ILR-4"):
        spec = WorkloadSpec(regime=regime, arrival_rate=0.2, n_sessions=64,
                            seed=0, max_context=CONTEXT_LIMIT)
        d = describe(generate(spec, QWEN3, H100))
        means.append(d["mean_prompt_tokens"])
        assert d["mean_ideal_s"] > 100.0         # tool-dominated ideal times
    assert means == sorted(means)
    assert 90_000 < means[0] < 160_000
    assert 180_000 < means[3] < 280_000


def test_mars_beats_request_oblivious_baselines_e2e():
    """Headline claim at test scale: MARS mean latency < FCFS and Autellix,
    and its per-round TTFT tail is several times better."""
    mars, _ = _run("mars")
    fcfs, _ = _run("fcfs")
    autx, _ = _run("autellix")
    assert mars["latency"].mean < fcfs["latency"].mean
    assert mars["latency"].mean < autx["latency"].mean
    assert mars["ttft"].p95 * 2.0 < fcfs["ttft"].p95


def test_mars_beats_tool_aware_baselines_on_goodput():
    mars, _ = _run("mars", regime="ILR-1", rate=0.2)
    cont, _ = _run("continuum-dy", regime="ILR-1", rate=0.2)
    assert mars["goodput"][3.0] >= cont["goodput"][3.0]


def test_ablations_degrade_mars():
    """Paper Fig. 13: removing any component should not improve MARS."""
    full, _ = _run("mars", n=16)
    worst = 0.0
    for variant in ("mars-no-ctrl", "mars-no-coord", "mars-no-cosched"):
        v, _ = _run(variant, n=16)
        worst = max(worst, v["latency"].mean)
        assert v["latency"].mean >= 0.9 * full["latency"].mean
    assert worst > full["latency"].mean          # at least one clearly hurts


def test_kv_dynamics_mars_suppresses_late_evictions():
    """Paper Fig. 3A: MARS reclaims early (arrival spike) and suppresses
    evictions late, vs FCFS churning throughout."""
    _, eng_m = _run("mars", n=16, rate=0.4)
    evs = [e for e in eng_m.bus.log if e.kind in ("evict", "preempt")]
    horizon = max(e.t for e in eng_m.bus.log)
    early = sum(e.data.get("blocks", 1) for e in evs if e.t < 0.5 * horizon)
    late = sum(e.data.get("blocks", 1) for e in evs if e.t >= 0.5 * horizon)
    assert early + late == 0 or late <= early


def test_live_jax_engine_end_to_end():
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.core.events import EventBus
    from repro.core.session import Round, make_session
    from repro.engine.engine import run_live
    from repro.engine.jax_runner import JaxBackend
    from repro.engine.tools import RealToolExecutor
    cfg = get_config("llama3.2-1b").reduced()
    backend = JaxBackend(cfg, max_slots=4, max_len=256)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=2, bus=bus)
    eng = Engine(EngineConfig(total_kv_blocks=4 * 255 // 32, block_size=32,
                              token_budget=128, max_decode_batch=4,
                              decode_granularity=4, cpu_slots=2),
                 "mars", backend, bus=bus, tool_exec=tools)
    ss = [make_session(0.02 * i, [Round(48, 8, "t", 0.05), Round(24, 6, None, 0.0)],
                       ideal_time=1.0) for i in range(3)]
    finished, _ = run_live(eng, ss, timeout=120)
    tools.shutdown()
    assert len(finished) == 3
    for s in finished:
        assert len(s.meta.get("generated", [])) == 14
        assert len(s.ttfts) == 2


def test_training_loss_decreases():
    from repro.launch.train import train
    losses, _ = train("llama3.2-1b", reduced=True, steps=30, seq_len=64,
                      batch=4, verbose=False)
    assert len(losses) == 30
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_checkpoint_restart_is_exact():
    """Fault tolerance: crash + resume reproduces the uninterrupted run."""
    import shutil
    import tempfile
    from repro.launch.train import train
    d = tempfile.mkdtemp()
    try:
        full, _ = train("llama3.2-1b", steps=12, seq_len=32, batch=2,
                        verbose=False)
        part, _ = train("llama3.2-1b", steps=12, stop_after=6, seq_len=32,
                        batch=2, ckpt_dir=d, ckpt_every=6, verbose=False)
        resumed, _ = train("llama3.2-1b", steps=12, seq_len=32, batch=2,
                           ckpt_dir=d, resume=True, ckpt_every=100,
                           verbose=False)
        np.testing.assert_allclose(resumed, full[6:], rtol=1e-5, atol=1e-6)
    finally:
        shutil.rmtree(d, ignore_errors=True)
