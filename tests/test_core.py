"""Unit + property tests for the MARS core: block manager, telemetry/AIMD,
queue packing (Alg. 1), MLFQ, co-scheduler."""
import math

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # hermetic env: seeded-example fallback
    from _hypo import given, settings, st

from repro.core.admission import ControlPlaneConfig, ExternalControlPlane
from repro.core.coscheduler import CoSchedulerConfig, OpportunisticCoScheduler
from repro.core.events import EventBus
from repro.core.mlfq import MLFQConfig, PriorityCoordinator
from repro.core.session import Round, make_session
from repro.core.telemetry import Telemetry, TelemetryConfig
from repro.engine.block_manager import BlockManager


# ---------------------------------------------------------------------------
# block manager
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "pin", "unpin"]),
                          st.integers(1, 64)), max_size=200))
def test_block_manager_never_leaks(ops):
    bm = BlockManager(256, 32)
    held = 0
    pinned = 0
    for op, n in ops:
        if op == "alloc":
            if bm.alloc(n):
                held += n
        elif op == "free" and held - pinned >= n:
            bm.release(n)
            held -= n
        elif op == "pin" and held - pinned >= n:
            bm.pin(n)
            pinned += n
        elif op == "unpin" and pinned >= n:
            bm.unpin(n)
            pinned -= n
        p = bm.probe()
        assert p.free + held == p.total
        assert p.free >= 0 and p.pinned == pinned


@given(st.integers(0, 10_000))
def test_blocks_for_ceil(n):
    bm = BlockManager(8, 32)
    b = bm.blocks_for(n)
    assert b * 32 >= n and (b - 1) * 32 < n or n == 0


# ---------------------------------------------------------------------------
# telemetry / AIMD
# ---------------------------------------------------------------------------

def _telem(cpu_slots=4):
    bus = EventBus()
    return Telemetry(TelemetryConfig(cpu_slots=cpu_slots,
                                     hysteresis_checks=2), bus), bus


def test_tool_ema_and_hysteresis():
    t, bus = _telem(cpu_slots=2)
    bus.emit("tool_start", 0.0, 1, kind="x")
    bus.emit("tool_start", 0.0, 2, kind="x")
    assert t.active_tools == 2
    t.probe_gpu(100, 50, 0, 2, 1, 0)
    # probes alone never flip flags: hysteresis advances on tick()
    assert not t.cpu_overloaded
    # one hot tick isn't enough (hysteresis)
    t.tick()
    assert not t.cpu_overloaded
    t.tick()
    assert t.cpu_overloaded
    bus.emit("tool_end", 5.0, 1, kind="x", duration=5.0)
    bus.emit("tool_end", 6.0, 2, kind="x", duration=7.0)
    assert t.active_tools == 0
    assert 5.0 <= t.tool_estimate("x") <= 7.0
    t.tick()
    t.tick()
    assert not t.cpu_overloaded


def test_churn_drives_kv_overload():
    t, bus = _telem()
    for _ in range(5):
        bus.emit("preempt", 0.0, 1, tokens=100, blocks=50)
        t.probe_gpu(100, 10, 0, 4, 2, 40)
        t.tick()
    assert t.kv_overloaded
    for _ in range(30):
        t.tick()                            # churn decays
    assert not t.kv_overloaded


@settings(max_examples=100, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=60))
def test_aimd_window_bounds(overloads):
    """W_adm always within [w_min, w_max] whatever the overload pattern."""
    t, bus = _telem()
    cfg = ControlPlaneConfig(control_interval=0.0, w_min=1, w_max=32)
    cp = ExternalControlPlane(cfg, t, bus)
    now = 0.0
    for hot in overloads:
        t.cpu_overloaded = hot
        t.kv_overloaded = False
        now += 1.0
        cp.update_window(now, avg_blocks_per_session=100.0)
        assert cfg.w_min <= cp.w_adm <= cfg.w_max


def test_aimd_multiplicative_decrease_additive_increase():
    t, bus = _telem()
    cfg = ControlPlaneConfig(control_interval=0.0, w_init=16.0)
    cp = ExternalControlPlane(cfg, t, bus)
    t.cpu_overloaded = True
    cp.update_window(1.0, 100.0)
    assert cp.w_adm == pytest.approx(16.0 * cfg.multiplicative_beta)
    t.cpu_overloaded = False
    t.churn_ema = 0.0
    w = cp.w_adm
    cp.update_window(2.0, 100.0)
    assert cp.w_adm == pytest.approx(w + cfg.additive_alpha)


# ---------------------------------------------------------------------------
# PackQueue (Alg. 1)
# ---------------------------------------------------------------------------

def _sessions(sizes, t0=0.0):
    out = []
    for i, sz in enumerate(sizes):
        s = make_session(t0 + i * 0.01, [Round(sz, 10, None, 0.0)])
        out.append(s)
    return out


def test_pack_queue_ascending_default():
    t, bus = _telem()
    cp = ExternalControlPlane(ControlPlaneConfig(), t, bus)
    q = _sessions([3200, 320, 32000, 96])
    packed = cp.pack_queue(q)
    est = [cp.estimate_blocks(s) for s in packed]
    assert est == sorted(est)


def test_pack_queue_descending_under_cpu_overload():
    t, bus = _telem()
    t.cpu_overloaded = True
    cp = ExternalControlPlane(ControlPlaneConfig(), t, bus)
    q = _sessions([3200, 320, 32000, 96])
    packed = cp.pack_queue(q)
    est = [cp.estimate_blocks(s) for s in packed]
    assert est == sorted(est, reverse=True)


def test_estimate_blocks_subtracts_indexed_prefix_floor_one_chunk():
    """Radix-aware admission: the block estimate is net of the shared
    prefix already indexed on the replica, and never drops below one
    chunk — even a full-duplicate session holds/recomputes its tail."""
    t, bus = _telem()
    cp = ExternalControlPlane(ControlPlaneConfig(block_size=32), t, bus)
    (s,) = _sessions([3200])             # 100 blocks raw
    assert cp.estimate_blocks(s) == 100
    cp.prefix_lookup = lambda _s: 60
    assert cp.estimate_blocks(s) == 40
    # full (or over-reported) match floors at one chunk, never 0/negative
    cp.prefix_lookup = lambda _s: 100
    assert cp.estimate_blocks(s) == 1
    cp.prefix_lookup = lambda _s: 10_000
    assert cp.estimate_blocks(s) == 1
    # a lookup that reports garbage below zero must not inflate the estimate
    cp.prefix_lookup = lambda _s: -5
    assert cp.estimate_blocks(s) == 100


def test_engine_binds_exact_prefix_lookup_to_admission():
    """The MARS control plane sizes family members by the engine's exact
    RadixIndex.match — once the builder has indexed the shared context, a
    sibling's admission estimate collapses to its private tail."""
    from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
    from repro.engine.backend import SimBackend
    from repro.engine.engine import Engine, EngineConfig
    from repro.models.perf_model import H100
    eng = Engine(EngineConfig(total_kv_blocks=512, block_size=32,
                              token_budget=8192), "mars",
                 SimBackend(QWEN3, H100))
    cp = eng.policy.control
    builder = make_session(0.0, [Round(8 * 32, 8, None, 0.0)])
    builder.meta["prefix_hashes"] = [(("fam", i), 32) for i in range(8)]
    sib = make_session(0.0, [Round(10 * 32, 8, None, 0.0)])
    sib.meta["prefix_hashes"] = [(("fam", i), 32) for i in range(8)] + \
        [(("u", i), 32) for i in range(2)]
    assert cp.estimate_blocks(sib) == 10         # nothing indexed yet
    eng.submit(builder)
    now = 0.0
    for _ in range(6):
        el, _ = eng.tick(now)
        now += max(el, 0.05)
    assert eng.radix.inserted_blocks >= 8
    assert cp.estimate_blocks(sib) == 2          # private tail only


def test_pack_queue_first_fit_when_all_long():
    t, bus = _telem()
    t.free_blocks = 2500
    cfg = ControlPlaneConfig(long_session_blocks=1000)
    cp = ExternalControlPlane(cfg, t, bus)
    q = _sessions([3 * 32 * 1400, 32 * 1200, 32 * 1100])   # all >= 1000 blocks
    packed = cp.pack_queue(q)
    est = [cp.estimate_blocks(s) for s in packed]
    # feasible set (1100 + 1200 fits 2500) first, oversized last
    assert est[-1] == max(est)
    assert sum(est[:-1]) <= 2500


# ---------------------------------------------------------------------------
# MLFQ
# ---------------------------------------------------------------------------

def test_mlfq_base_level_monotone_in_footprint():
    c = PriorityCoordinator(MLFQConfig())
    small, big = _sessions([256, 200_000])
    assert c.base_level(small) < c.base_level(big)


def test_mlfq_service_demotion_bounded():
    cfg = MLFQConfig()
    c = PriorityCoordinator(cfg)
    (s,) = _sessions([256])
    l0 = c.level(s, 0.0)
    s.service_tokens = 10_000_000
    assert c.level(s, 0.0) <= l0 + cfg.max_demotion


def test_mlfq_promotion_bounded_and_monotone():
    cfg = MLFQConfig(promote_after=10.0, max_promotion=2)
    c = PriorityCoordinator(cfg)
    (s,) = _sessions([200_000])
    s.admitted_at = s.last_service = 0.0
    levels = [c.level(s, t) for t in (0.0, 15.0, 25.0, 1000.0)]
    assert levels[1] <= levels[0] and levels[2] <= levels[1]
    assert levels[0] - min(levels) <= cfg.max_promotion


def test_mlfq_eviction_prefers_low_priority_then_big_kv():
    c = PriorityCoordinator(MLFQConfig())
    a, b, d = _sessions([128, 200_000, 200_000])
    a.kv_blocks, b.kv_blocks, d.kv_blocks = 10, 50, 500
    order = c.eviction_order([a, b, d], now=0.0)
    assert order[0] is d and order[1] is b and order[-1] is a


# ---------------------------------------------------------------------------
# co-scheduler
# ---------------------------------------------------------------------------

def _cosched():
    t, bus = _telem()
    t.probe_gpu(1000, 500, 0, 2, 1, 0)
    cs = OpportunisticCoScheduler(CoSchedulerConfig(block_size=32), t,
                                  recompute_time_fn=lambda n: n / 10_000.0,
                                  prefill_rate_fn=lambda: 10_000.0)
    return cs, t


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 100_000), st.integers(0, 4000))
def test_shrink_chunk_properties(want, free):
    cs, _ = _cosched()
    got = cs.shrink_chunk(want, free)
    assert 0 <= got <= max(want, 32)
    if got > 0:
        assert -(-got // 32) <= max(free, 1)
    if want > 0 and free >= -(-want // 32):
        assert got == want                      # fits -> no shrink


def test_retention_pins_under_slack_releases_under_pressure():
    cs, t = _cosched()
    (s,) = _sessions([64_000])
    s.kv_blocks = 2000
    s.resident_len = 64_000
    s.tool_started = 0.0
    s.rounds[0].tool_kind = "x"
    t.tool_ema["x"] = 10.0
    t.probe_gpu(4000, 2000, 0, 2, 1, 0)          # no waiting demand -> pin
    assert cs.should_pin(s, now=1.0)
    # long tool (test_runner scale) under heavy demand with no free blocks:
    # holding 2000 blocks for ~400 s strands more work than the rebuild saves
    t.tool_ema["x"] = 400.0
    t.probe_gpu(4000, 10, 0, 8, 1, 20_000)
    assert not cs.should_pin(s, now=1.0)


def test_retention_reevaluation_revokes_overrunning_tools():
    """Hazard residual: a pin that was fine at t=0 is revoked once the tool
    overruns its estimate under demand."""
    cs, t = _cosched()
    (s,) = _sessions([16_000])
    s.kv_blocks, s.resident_len, s.tool_started = 250, 16_000, 0.0
    s.rounds[0].tool_kind = "x"
    t.tool_ema["x"] = 2.0
    t.probe_gpu(4000, 100, 250, 4, 1, 3000)
    assert cs.should_pin(s, now=0.5)
    assert not cs.should_pin(s, now=400.0)       # way past estimate
