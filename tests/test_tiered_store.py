"""NVMe third tier + TieredStore tests: disk cost model (per-op latency,
bandwidth asymmetry, bounded queue depth), real-file spool round trips, the
hardened ``HostTier.load`` sentinel, staged demotion/promotion with future
gating, four-way retention decisions, the engine's end-to-end disk round
trip, a property/soak test over random store/demote/promote/drop/detach
sequences holding the occupancy invariants, and live (paged runner) token
parity for the staged restore path."""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # hermetic env: seeded-example fallback
    from _hypo import given, settings, st

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
from repro.core import events as ev
from repro.core.coscheduler import CoSchedulerConfig, OpportunisticCoScheduler
from repro.core.session import KVAction, KVState, Phase, Round, make_session
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.kvcache import (DiskFileStore, DiskTier, DiskTierConfig, HostTier,
                           HostTierConfig, TieredStore)
from repro.models.perf_model import H100

BACKEND = SimBackend(QWEN3, H100)


def _host(cap=100):
    return HostTier(HostTierConfig(capacity_blocks=cap, pcie_bw=1e9,
                                   base_latency_s=1e-3),
                    bytes_per_token=1e6, block_size=32)


def _disk(cap=1000, qd=2):
    return DiskTier(DiskTierConfig(capacity_blocks=cap, read_bw=1e9,
                                   write_bw=5e8, op_latency_s=1e-2,
                                   queue_depth=qd),
                    bytes_per_token=1e6, block_size=32)


class _Fut:
    def __init__(self, done=False):
        self._done = done

    def done(self):
        return self._done

    def resolve(self):
        self._done = True


# ---------------------------------------------------------------------------
# disk tier: cost model + occupancy
# ---------------------------------------------------------------------------

def test_disk_cost_model_latency_and_bandwidth_asymmetry():
    d = _disk()
    assert d.read_seconds(0) == 0.0
    # per-op latency + bytes/bw; write bw half the read bw
    assert d.read_seconds(100) == pytest.approx(1e-2 + 0.1)
    assert d.write_seconds(100) == pytest.approx(1e-2 + 0.2)


def test_disk_bounded_queue_depth_backpressures():
    d = _disk(qd=2)
    svc = d.write_seconds(100)           # 0.21 s per op
    # 4 concurrent writes through a depth-2 queue: the 3rd and 4th wait
    secs = [d.store(i, tokens=100, blocks=1, now=0.0) for i in range(4)]
    assert secs[0] == pytest.approx(svc)
    assert secs[1] == pytest.approx(svc)
    assert secs[2] == pytest.approx(2 * svc)
    assert secs[3] == pytest.approx(2 * svc)
    assert d.used_blocks == 4
    assert not d.ready(3, now=1.9 * svc)
    assert d.ready(3, now=2 * svc + 1e-9)


def test_disk_occupancy_load_drop_and_sentinels():
    d = _disk(cap=4)
    assert d.can_store(4) and not d.can_store(5)
    d.store(1, tokens=50, blocks=3, now=0.0)
    assert d.load(99, now=1.0) is None           # unknown: sentinel
    d.mark_in_flight(1)
    assert d.load(1, now=1e9) is None            # in flight: sentinel, kept
    assert d.holds(1) and d.used_blocks == 3
    fut = _Fut()
    d.attach_future(1, fut)
    assert not d.ready(1, 1e9) and d.time_to_ready(1, 0.0) is None
    assert d.next_event_time(0.0) is None        # wall clock, not sim timer
    fut.resolve()
    assert d.ready(1, 0.0)
    assert d.load(1, now=2.0) == 50
    assert d.used_blocks == 0 and d.hits == 1
    d.drop(1)                                    # tolerated no-op
    assert d.drops == 0


def test_disk_file_store_round_trip(tmp_path):
    fs = DiskFileStore(str(tmp_path))
    k = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    v = -k
    fs.write(7, k, v)
    rk, rv = fs.read(7)
    np.testing.assert_array_equal(rk, k)
    np.testing.assert_array_equal(rv, v)
    assert fs.read(8) is None
    fs.delete(7)
    assert fs.read(7) is None
    fs.delete(7)                                 # idempotent


# ---------------------------------------------------------------------------
# host tier: hardened load (regression) + migration hooks
# ---------------------------------------------------------------------------

def test_host_load_unknown_and_inflight_return_sentinel():
    """Regression: ``load`` must match ``drop`` semantics — an unknown or
    in-flight sid returns None instead of KeyError-ing the engine, and an
    in-flight entry is retained for the transfer to land."""
    ht = _host()
    assert ht.load(404, now=0.0) is None         # unknown: no KeyError
    ht.store(5, tokens=100, blocks=4, now=0.0)
    ht.mark_in_flight(5)
    assert ht.load(5, now=1e9) is None           # in flight: sentinel
    assert ht.holds(5) and ht.used_blocks == 4   # ...entry retained
    fut = _Fut(done=True)
    ht.attach_future(5, fut)
    assert ht.load(5, now=0.0) == 100
    assert ht.used_blocks == 0 and ht.hits == 1


def test_host_evacuate_and_admit_staged():
    ht = _host()
    ht.store(1, tokens=100, blocks=4, now=0.0)
    assert ht.evacuate(1) == (100, 4)
    assert ht.used_blocks == 0 and ht.drops == 0 and ht.hits == 0
    assert ht.evacuate(1) is None
    ht.admit_staged(2, 60, 2, now=5.0, transfer_s=1.0)
    assert ht.used_blocks == 2 and ht.stores == 2
    assert not ht.ready(2, 5.5) and ht.ready(2, 6.0)


# ---------------------------------------------------------------------------
# TieredStore: staged moves
# ---------------------------------------------------------------------------

def test_direct_to_disk_staged_write_then_promote_on_request():
    ts = TieredStore(_host(), _disk())
    sec = ts.store(1, tokens=100, blocks=4, now=0.0, target="disk",
                   context_tokens=200)
    # staged: PCIe D2H leg + NVMe write (through the queue)
    want = ts.host.swap_seconds(100) + ts.disk.write_seconds(100)
    assert sec == pytest.approx(want)
    assert ts.tier_of(1) == "disk" and ts.disk.used_blocks == 4
    assert ts.host.used_blocks == 0
    # not restorable directly, and not promoted while the write lands
    assert not ts.ready(1, now=0.0)
    # staged estimate: durable-time remainder + unqueued read
    assert ts.time_to_ready(1, now=0.0) == \
        pytest.approx(sec + ts.disk.read_seconds(100))
    assert ts.time_to_ready(404, now=0.0) is None
    assert ts.request(1, now=sec / 2) is False
    # first request after durability issues the promotion (hop 1)
    r = ts.request(1, now=sec)
    assert r is False and ts.tier_of(1) == "host"
    assert ts.staged_restores == 1 and ts.disk.hits == 1
    assert ts.disk.used_blocks == 0 and ts.host.used_blocks == 4
    t_read = ts.disk.read_seconds(100)
    assert ts.request(1, now=sec + t_read) is True
    assert ts.load(1, now=sec + t_read) == 100
    assert ts.host.used_blocks == 0 and ts.host.hits == 1


def test_demotion_gates_cold_watermark_benefit_and_inflight():
    host = _host(cap=10)
    # recompute barely more expensive than the staged restore: worth disk
    ts = TieredStore(host, _disk(), recompute_time=lambda n: 1e3,
                     demote_after_s=10.0, demote_watermark=0.5)
    ts.store(1, tokens=100, blocks=4, now=0.0)
    ts.store(2, tokens=100, blocks=4, now=5.0)
    ts.mark_in_flight(2)                   # D2H never resolved: not in DRAM
    # occupancy 8/10 > watermark, but nothing cold yet
    assert ts.maintain(now=9.0) == 0
    # sid 1 cold at t=12; sid 2 in flight -> must never demote
    assert ts.maintain(now=12.0) == 1
    assert ts.tier_of(1) == "disk" and ts.tier_of(2) == "host"
    assert ts.demotions == 1
    assert ts.maintain(now=30.0) == 0      # sid 2 still future-gated
    # cheap recompute: demotion not worth it
    ts2 = TieredStore(_host(cap=10), _disk(), recompute_time=lambda n: 1e-6,
                      demote_after_s=1.0, demote_watermark=0.0)
    ts2.store(1, tokens=100, blocks=8, now=0.0)
    assert ts2.maintain(now=100.0) == 0
    # demotable veto (engine: session already back from its tool)
    ts3 = TieredStore(_host(cap=10), _disk(), recompute_time=lambda n: 1e3,
                      demote_after_s=1.0, demote_watermark=0.0)
    ts3.store(1, tokens=100, blocks=8, now=0.0)
    assert ts3.maintain(now=100.0, demotable=lambda sid: False) == 0
    assert ts3.maintain(now=100.0, demotable=lambda sid: True) == 1


def test_promotion_displaces_cold_entries_when_host_full():
    host = _host(cap=8)
    ts = TieredStore(host, _disk(), recompute_time=lambda n: 1e3,
                     demote_after_s=1e9,   # never age-demoted
                     demote_watermark=1.0)
    ts.store(1, tokens=100, blocks=6, now=0.0, target="disk")
    ts.store(2, tokens=100, blocks=6, now=0.0)     # host-resident, ready
    now = 10.0
    assert ts.disk.ready(1, now)
    # promoting sid 1 needs 6 blocks; host has 2 free -> sid 2 demoted
    r = ts.request(1, now=now)
    assert r is False and ts.tier_of(1) == "host" and ts.tier_of(2) == "disk"
    assert ts.demotions == 1 and ts.staged_restores == 1
    assert ts.host.used_blocks == 6 and ts.disk.used_blocks == 6


def test_request_urgent_signals_capacity_deadlock():
    host = _host(cap=8)
    ts = TieredStore(host, _disk(), demote_after_s=1e9, demote_watermark=1.0)
    ts.store(1, tokens=100, blocks=6, now=0.0, target="disk")
    ts.store(2, tokens=100, blocks=6, now=0.0)
    ts.mark_in_flight(2)                   # undemotable: in-flight
    now = 10.0
    assert ts.request(1, now=now) is False          # patient: keep waiting
    assert ts.request(1, now=now, urgent=True) is None  # stall hatch: abandon
    assert ts.request(404, now=now) is None             # unknown sid


# ---------------------------------------------------------------------------
# four-way retention decision
# ---------------------------------------------------------------------------

class _Telem:
    """Pressured snapshot: waiting demand far above free blocks, so HBM
    pinning prices itself out and the off-device tiers compete."""

    def __init__(self, est):
        self.est = est
        self.waiting_prefill_blocks = 300
        self.free_blocks = 0

    def tool_estimate(self, kind):
        return self.est


def _cosched(est_tool_s, recompute_s=30.0):
    cs = OpportunisticCoScheduler(
        CoSchedulerConfig(disk_min_tokens=4_096),
        telem=_Telem(est_tool_s), recompute_time_fn=lambda n: recompute_s)
    cs.swap_seconds = lambda n: 0.5
    cs.disk_read_seconds = lambda n: 1.0
    cs.disk_write_seconds = lambda n: 2.0
    return cs


def _tool_session(tokens=8192, kind="ci_runner"):
    s = make_session(0.0, [Round(tokens, 8, kind, 100.0)], ideal_time=1.0)
    s.resident_len = tokens
    s.kv_blocks = tokens // 32
    return s


def test_retention_four_way_prefers_disk_on_long_idle():
    s = _tool_session()
    # long expected idle: disk wins over host even though both net positive
    cs = _cosched(est_tool_s=1e4)
    assert cs.disk_net(s, 0.0) > 0 and cs.offload_net(s, 0.0) > 0
    assert cs.retention_decision(s, 0.0) == KVAction.OFFLOAD_DISK
    # idle below the long-idle threshold (but long enough that pressure
    # still prices out the pin): host DRAM keeps the warm restore
    cs = _cosched(est_tool_s=30.0, recompute_s=5.0)
    assert cs.retention_decision(s, 0.0) == KVAction.OFFLOAD
    # tiny context: below the NVMe floor, host offload still allowed
    tiny = _tool_session(tokens=2048)
    cs = _cosched(est_tool_s=1e4)
    cs.cfg = CoSchedulerConfig(disk_min_tokens=4_096, offload_min_tokens=1024)
    assert cs.disk_net(tiny, 0.0) == float("-inf")
    assert cs.retention_decision(tiny, 0.0) == KVAction.OFFLOAD
    # recompute cheaper than any restore: FREE
    cs = _cosched(est_tool_s=1e4, recompute_s=0.01)
    assert cs.retention_decision(s, 0.0) == KVAction.FREE


# ---------------------------------------------------------------------------
# engine: end-to-end disk round trip (sim)
# ---------------------------------------------------------------------------

def _engine(policy="fcfs", blocks=9000, **cfg_kw):
    return Engine(EngineConfig(total_kv_blocks=blocks, block_size=32,
                               token_budget=8192, max_decode_batch=64,
                               decode_granularity=8, cpu_slots=8, **cfg_kw),
                  policy, BACKEND)


def test_disk_offload_round_trip_restores_resident_len():
    """Force OFFLOAD_DISK at every tool yield: the session parks on NVMe
    (staged write), promotes back through host DRAM on resume (staged
    restore), and finishes with exact resident_len — SWAP_OUT tier=disk,
    PROMOTE, and SWAP_IN tier=disk events paired."""
    eng = _engine(disk_tier_blocks=50_000)
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD_DISK, 0.0)
    s = make_session(0.0, [Round(50_000, 32, "terminal", 30.0),
                           Round(2_000, 32, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=1e5)
    assert len(finished) == 1
    outs = [e for e in eng.bus.log if e.kind == ev.SWAP_OUT
            and e.data.get("tier") == "disk"]
    ins = [e for e in eng.bus.log if e.kind == ev.SWAP_IN
           and e.data.get("tier") == "disk"]
    proms = [e for e in eng.bus.log if e.kind == ev.PROMOTE]
    assert len(outs) == 1 and len(ins) == 1 and len(proms) == 1
    assert ins[0].data["tokens"] == 50_032      # prefill + round-0 decode
    t = eng.tiers.stats()
    assert t["direct_to_disk"] == 1 and t["staged_restores"] == 1
    assert t["disk"]["hits"] == 1 and t["host"]["hits"] == 1
    assert eng.disk.used_blocks == 0 and eng.host.used_blocks == 0
    eng.check_invariants()


def test_disk_offload_falls_back_when_disk_absent():
    """OFFLOAD_DISK without a configured disk tier degrades to the host
    path (no crash, tier=host events)."""
    eng = _engine()                               # disk_tier_blocks=0
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD_DISK, 0.0)
    s = make_session(0.0, [Round(30_000, 16, "terminal", 10.0),
                           Round(500, 16, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [s], max_time=1e5)
    assert len(finished) == 1
    assert any(e.kind == ev.SWAP_OUT and e.data.get("tier") == "host"
               for e in eng.bus.log)
    assert eng.host.hits == 1
    eng.check_invariants()


def test_engine_demotes_cold_host_entries_and_still_finishes():
    """A long tool wait with a tight, pressured host tier: the engine's
    per-tick maintain() demotes the cold entry to NVMe and the session
    still restores token-exact (DEMOTE + PROMOTE events appear)."""
    eng = _engine(host_tier_blocks=2_000, disk_tier_blocks=50_000,
                  disk_demote_after_s=5.0, disk_demote_watermark=0.1)
    eng.policy.on_tool_yield = lambda s, now: (KVAction.OFFLOAD, 0.0)
    a = make_session(0.0, [Round(40_000, 32, "terminal", 120.0),
                           Round(2_000, 32, None, 0.0)], ideal_time=10.0)
    b = make_session(1.0, [Round(20_000, 32, "terminal", 8.0),
                           Round(1_000, 32, None, 0.0)], ideal_time=10.0)
    finished, _ = run_sim(eng, [a, b], max_time=1e5)
    assert len(finished) == 2
    assert eng.bus.counts.get(ev.DEMOTE, 0) >= 1
    assert eng.bus.counts.get(ev.PROMOTE, 0) >= 1
    t = eng.tiers.stats()
    assert t["demotions"] >= 1 and t["staged_restores"] >= 1
    assert eng.disk.used_blocks == 0 and eng.host.used_blocks == 0
    eng.check_invariants()


@pytest.mark.parametrize("seed", [0, 1])
def test_random_four_way_schedule_holds_invariants(seed):
    """Randomized four-way retention over a family workload: every tick
    holds the engine's extended invariants (tier occupancy included) and
    the run drains clean."""
    from repro.configs.qwen3_coder_30b import CONTEXT_LIMIT
    from repro.workloads.generator import WorkloadSpec, generate
    rng = random.Random(seed)

    def random_yield(s, now):
        r = rng.random()
        if r < 0.25:
            return KVAction.PIN, rng.choice([5.0, float("inf")])
        if r < 0.5:
            return KVAction.OFFLOAD, 0.0
        if r < 0.75:
            return KVAction.OFFLOAD_DISK, 0.0
        return KVAction.FREE, 0.0

    eng = _engine(policy="continuum", blocks=6000, host_tier_blocks=6000,
                  disk_tier_blocks=20_000, disk_demote_after_s=2.0,
                  disk_demote_watermark=0.1)
    eng.policy.on_tool_yield = random_yield
    spec = WorkloadSpec(regime="ILR-1", arrival_rate=1.0, n_sessions=8,
                        seed=seed, max_context=CONTEXT_LIMIT, n_families=2)
    sessions = generate(spec, QWEN3, H100)
    arrivals = sorted(sessions, key=lambda s: s.arrival_time)
    i, now = 0, 0.0
    for _ in range(60_000):
        while i < len(arrivals) and arrivals[i].arrival_time <= now:
            eng.submit(arrivals[i])
            i += 1
        elapsed, prog = eng.tick(now)
        eng.check_invariants()
        if elapsed:
            now += elapsed
        elif not prog:
            nxt = eng.tools.next_event_time()
            t2 = eng.next_timer_event(now)
            cands = [t for t in (nxt, t2) if t is not None]
            if i < len(arrivals):
                cands.append(arrivals[i].arrival_time)
            if eng.waiting:
                cands.append(now + 0.5)
            if not cands:
                break
            now = max(now + 1e-9, min(cands))
        if eng.done() and i >= len(arrivals):
            break
    assert eng.done()
    assert len(eng.finished) + len(eng.rejected) == len(sessions)
    assert eng.blocks.free == eng.blocks.total
    assert eng.host.used_blocks == 0 and eng.disk.used_blocks == 0


# ---------------------------------------------------------------------------
# property/soak: random tier-op sequences never leak or overflow
# ---------------------------------------------------------------------------

op_seq = st.lists(
    st.tuples(st.integers(0, 5),               # sid
              st.sampled_from(["store_host", "store_disk", "inflight",
                               "resolve", "request", "load", "drop",
                               "maintain", "tick"]),
              st.integers(1, 6)),              # blocks
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(op_seq, st.integers(4, 24), st.integers(6, 30))
def test_tiered_store_random_ops_occupancy_invariants(ops, host_cap,
                                                      disk_cap):
    """Random store/demote/promote/drop sequences — including future-gated
    in-flight entries — must keep 0 <= used <= capacity on both tiers and
    account every live entry in exactly one tier (no leaks)."""
    host = HostTier(HostTierConfig(capacity_blocks=host_cap, pcie_bw=1e9),
                    bytes_per_token=1e5, block_size=32)
    disk = DiskTier(DiskTierConfig(capacity_blocks=disk_cap, read_bw=1e9,
                                   write_bw=5e8, queue_depth=2),
                    bytes_per_token=1e5, block_size=32)
    ts = TieredStore(host, disk, recompute_time=lambda n: 1e3,
                     demote_after_s=1.0, demote_watermark=0.1)
    futs = {}
    now = 0.0
    expect = {}                       # sid -> blocks of live entries
    for sid, op, blocks in ops:
        now += 0.7
        if op in ("store_host", "store_disk"):
            target = "disk" if op == "store_disk" else "host"
            tier = disk if target == "disk" else host
            if not ts.holds(sid) and tier.can_store(blocks):
                ts.store(sid, tokens=blocks * 32, blocks=blocks, now=now,
                         target=target, context_tokens=blocks * 32)
                expect[sid] = blocks
        elif op == "inflight":
            if ts.holds(sid):
                ts.mark_in_flight(sid)
                f = _Fut()
                ts.attach_future(sid, f)
                futs[sid] = f
        elif op == "resolve":
            if sid in futs:
                futs.pop(sid).resolve()
        elif op == "request":
            r = ts.request(sid, now, urgent=(blocks % 2 == 0))
            if r is None and sid in expect and not ts.holds(sid):
                expect.pop(sid)       # caller would abandon to recompute
        elif op == "load":
            if ts.ready(sid, now):
                got = ts.load(sid, now)
                if got is not None:
                    expect.pop(sid, None)
                    futs.pop(sid, None)
        elif op == "drop":
            ts.drop(sid)
            expect.pop(sid, None)
            futs.pop(sid, None)
        elif op == "maintain":
            ts.maintain(now)
        elif op == "tick":
            now += 50.0
            ts.maintain(now, demotable=lambda s: s % 2 == 0)
        # --- invariants after every op ---
        assert 0 <= host.used_blocks <= host_cap
        assert 0 <= disk.used_blocks <= disk_cap
        live = sum(expect.values())
        assert host.used_blocks + disk.used_blocks == live, \
            f"leak: host={host.used_blocks} disk={disk.used_blocks} " \
            f"expected={live}"
        for sid in expect:
            assert ts.tier_of(sid) in ("host", "disk")
    # drain: dropping everything returns both tiers to zero
    for sid in list(expect):
        ts.drop(sid)
    assert host.used_blocks == 0 and disk.used_blocks == 0


# ---------------------------------------------------------------------------
# live paged runner: staged restore token parity
# ---------------------------------------------------------------------------

pytest.importorskip("jax")


@pytest.mark.live
def test_paged_disk_tier_token_parity(tmp_path):
    """Forced OFFLOAD_DISK on the live paged runner with a real-file
    spool: KV really spills to NVMe files and fills back (h2n/n2h jobs on
    the stream), restores gen-certify, and greedy tokens are identical to
    the host-only offload path."""
    from repro.core.events import EventBus
    from repro.engine.engine import run_live
    from repro.engine.jax_runner import JaxBackend
    from repro.engine.tools import RealToolExecutor
    from repro.configs.registry import get_config

    def run(action, disk_blocks, spool):
        backend = JaxBackend(get_config("llama3.2-1b").reduced(),
                             layout="paged", max_slots=4, max_len=256,
                             async_swap=True, disk_spool=spool)
        bus = EventBus()
        tools = RealToolExecutor(cpu_slots=2, bus=bus)
        eng = Engine(EngineConfig(total_kv_blocks=30, block_size=32,
                                  token_budget=256, max_decode_batch=4,
                                  decode_granularity=4, cpu_slots=2,
                                  disk_tier_blocks=disk_blocks),
                     "fcfs", backend, bus=bus, tool_exec=tools)
        eng.policy.on_tool_yield = lambda s, now: (action, 0.0)
        fam = [(("dsk", i), 32) for i in range(3)]
        sessions = []
        for j, sid in enumerate((97001, 97002)):
            # identical sids across both runs: decode-appended context ids
            # are content-addressed by (sid, position), so parity requires
            # the same identities
            s = make_session(0.05 * j, [Round(128, 8, "t", 0.05),
                                        Round(32, 6, None, 0.0)],
                             ideal_time=1.0, sid=sid)
            s.meta["prefix_hashes"] = fam + [(("u", sid, 0), 32)]
            sessions.append(s)
        finished, _ = run_live(eng, sessions, timeout=120)
        tools.shutdown()
        eng.check_invariants()
        out = {s.sid: list(s.meta["generated"]) for s in finished}
        stream = backend._impl.stream
        stats = (stream.h2n_completed, stream.n2h_completed,
                 eng.tiers.stats() if eng.tiers else None)
        backend.close()
        return out, stats

    host_out, _ = run(KVAction.OFFLOAD, 0, None)
    disk_out, (h2n, n2h, tier) = run(KVAction.OFFLOAD_DISK, 64,
                                     str(tmp_path))
    assert disk_out == host_out and len(disk_out) == 2
    assert h2n >= 1 and n2h >= 1          # spool writes/reads really ran
    assert tier["direct_to_disk"] >= 1
    assert tier["staged_restores"] >= 1
    assert tier["disk"]["used_blocks"] == 0
    assert tier["host"]["used_blocks"] == 0
