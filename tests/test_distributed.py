"""Distributed substrate tests: sharding rules, EP-vs-local MoE numerics,
distributed train step equivalence, checkpoint restore, cluster router."""
import os
import shutil
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed import checkpoint as ckpt
from repro.distributed import sharding as sh
from repro.distributed.router import ClusterRouter, RouterConfig
from repro.models import model_zoo

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class _FakeMesh:
    """Shape/axis-name stand-in so spec rules can be checked without devices."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_always_divisible(arch):
    """Every emitted PartitionSpec must evenly divide its dim on the
    production mesh shape — for all 10 archs (full-scale shapes)."""
    cfg = get_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    params = jax.eval_shape(lambda k: model_zoo.init(cfg, k, jnp.bfloat16), KEY)
    specs = sh.param_specs(cfg, mesh, params)

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (arch, spec, leaf.shape)

    jax.tree.map(check, params, specs)


def _shards_of(spec, mesh):
    shards = 1
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            shards *= mesh.shape[a]
    return shards


@pytest.mark.parametrize("arch", ["gemma2-27b", "dbrx-132b", "llava-next-34b"])
def test_param_bytes_per_device_fit_v5e(arch):
    """bf16 params (TP/EP) + f32 Adam moments (additionally data-sharded,
    ZeRO-1) per chip must fit well under v5e's 16 GB."""
    cfg = get_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    params = jax.eval_shape(lambda k: model_zoo.init(cfg, k, jnp.bfloat16), KEY)
    specs = sh.param_specs(cfg, mesh, params)
    is_spec = lambda s: isinstance(s, jax.sharding.PartitionSpec)
    total = 0.0
    for leaf, spec in zip(jax.tree.leaves(params),
                          jax.tree.leaves(specs, is_leaf=is_spec)):
        n = np.prod(leaf.shape)
        total += n / _shards_of(spec, mesh) * 2          # bf16 params
        mspec = sh.opt_moment_spec(spec, leaf.shape, mesh)
        total += 2 * n / _shards_of(mspec, mesh) * 4     # f32 mu + nu
    assert total < 12e9, f"{arch}: {total/1e9:.1f} GB/chip"


def test_moe_ep_matches_local():
    """Expert-parallel MoE (shard_map + all_to_all) == single-device MoE."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.configs.registry import get_config
from repro.models.layers import init_moe, moe_ffn, moe_ffn_ep_local, ParallelCtx
from repro.models.transformer import _moe_block

import dataclasses
cfg = get_config("dbrx-132b").reduced()
# disable capacity drops: EP capacities are shard-local, so with drops the
# two paths legitimately differ; without drops they must agree exactly.
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k))
assert cfg.moe.num_experts % 2 == 0
mesh = jax.make_mesh((2, 2), ("data", "model"))
p = init_moe(cfg, jax.random.PRNGKey(1), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model), jnp.float32)
want = moe_ffn(cfg, p, x)
pctx = ParallelCtx(mesh=mesh, dp_axes=("data",), tp_axis="model", ep_axis="data")
lp = {"moe": p}
with mesh:
    got = jax.jit(lambda lp, x: _moe_block(cfg, lp, x, pctx))(lp, x)
err = float(jnp.max(jnp.abs(got - want)))
# capacity-dispatch order can differ at shard boundaries; tolerance loose
assert err < 5e-4, err
# gradient correctness through shard_map + all_to_all
g1 = jax.grad(lambda p_: jnp.sum(moe_ffn(cfg, p_, x) ** 2))(p)
with mesh:
    g2 = jax.jit(jax.grad(
        lambda p_: jnp.sum(_moe_block(cfg, {"moe": p_}, x, pctx) ** 2)))(p)
for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)
print("EP-OK")
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "EP-OK" in out.stdout, out.stdout + out.stderr


def test_distributed_train_step_matches_single_device():
    """jit train_step on a (2,2) mesh == single device, same inputs."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.distributed.steps import build_train_step
from repro.models import model_zoo
from repro.train.optimizer import init_opt

cfg = get_config("llama3.2-1b").reduced()
params = model_zoo.init(cfg, jax.random.PRNGKey(0), jnp.float32)
opt_state = init_opt(params)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 1, cfg.vocab_size)
batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}

mesh = jax.make_mesh((2, 2), ("data", "model"))
fn_m = build_train_step(cfg, mesh, remat=False)
with mesh:
    p_m, o_m, m_m = jax.jit(fn_m)(params, opt_state, batch)

mesh1 = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                          ("data", "model"))
fn_1 = build_train_step(cfg, mesh1, remat=False)
with mesh1:
    p_1, o_1, m_1 = jax.jit(fn_1)(params, opt_state, batch)
np.testing.assert_allclose(float(m_m["loss"]), float(m_1["loss"]),
                           rtol=1e-4, atol=1e-4)
for a, b in zip(jax.tree.leaves(p_m), jax.tree.leaves(p_1)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-4, rtol=5e-3)
print("DIST-OK")
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DIST-OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    cfg = get_config("llama3.2-1b").reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    d = tempfile.mkdtemp()
    try:
        for step in (1, 2, 3, 4, 5):
            ckpt.save(d, params, step=step, keep=2)
        assert ckpt.latest_step(d) == 5
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2
        restored, step = ckpt.restore(d, params)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_checkpoint_async_and_atomicity():
    d = tempfile.mkdtemp()
    try:
        tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
        th = ckpt.save(d, tree, step=7, async_=True)
        th.join()
        got, step = ckpt.restore(d, tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(got["b"]["c"]), np.ones((3, 3)))
        assert not any(x.endswith(".tmp") for x in os.listdir(d))
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_engine_snapshot_restore():
    from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
    from repro.engine.backend import SimBackend
    from repro.engine.engine import Engine, EngineConfig, run_sim
    from repro.models.perf_model import H100
    from repro.workloads.generator import WorkloadSpec, generate
    spec = WorkloadSpec(regime="ILR-1", arrival_rate=1.0, n_sessions=6, seed=2,
                        max_context=250_000)
    sessions = generate(spec, QWEN3, H100)
    eng = Engine(EngineConfig(total_kv_blocks=9000), "mars",
                 SimBackend(QWEN3, H100))
    for s in sessions:
        eng.submit(s)
    now = 0.0
    for _ in range(60):                       # run partway, then "crash"
        el, _ = eng.tick(now)
        now += max(el, 0.05)
    snap = ckpt.snapshot_engine(eng)
    eng2 = Engine(EngineConfig(total_kv_blocks=9000), "mars",
                  SimBackend(QWEN3, H100))
    n = ckpt.restore_engine(eng2, snap)
    assert n == len(snap["waiting"]) + len(snap["active"])
    finished, _ = run_sim(eng2, [], max_time=1e5)
    assert len(finished) == n                 # all recovered sessions complete


# ---------------------------------------------------------------------------
# cluster router
# ---------------------------------------------------------------------------

def _mini_engine():
    from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
    from repro.engine.backend import SimBackend
    from repro.engine.engine import Engine, EngineConfig
    from repro.models.perf_model import H100
    return Engine(EngineConfig(total_kv_blocks=9000), "mars",
                  SimBackend(QWEN3, H100))


def test_router_failover_requeues_sessions():
    from repro.core.session import Round, make_session
    r = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    e1, e2 = _mini_engine(), _mini_engine()
    r.register("a", e1, now=0.0)
    r.register("b", e2, now=0.0)
    ss = [make_session(0.0, [Round(1000, 8, None, 0.0)], ideal_time=1.0)
          for _ in range(6)]
    for s in ss:
        r.heartbeat("a", kv_utilization=0.1, tool_backlog=0,
                     active_sessions=len(e1.waiting), step_latency=0.1, now=0.0)
        r.heartbeat("b", kv_utilization=0.1, tool_backlog=0,
                     active_sessions=len(e2.waiting), step_latency=0.1, now=0.0)
        r.place(s, now=0.0)
    placed_a = len(e1.waiting)
    assert placed_a + len(e2.waiting) == 6
    # replica a dies: heartbeat only from b
    r.heartbeat("b", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=10.0)
    failed = r.check_failures(now=10.0)
    assert failed == ["a"]
    assert len(r.requeued) == placed_a
    n = r.dispatch_requeued(now=10.0)
    assert n == placed_a
    assert len(e2.waiting) + len(e2.active) + len(e2.rejected) == 6


def test_router_straggler_drain_and_affinity():
    from repro.core.session import Round, make_session
    r = ClusterRouter(RouterConfig(straggler_factor=2.0))
    e1, e2, e3 = _mini_engine(), _mini_engine(), _mini_engine()
    for rid, e in (("a", e1), ("b", e2), ("c", e3)):
        r.register(rid, e, now=0.0)
        r.heartbeat(rid, kv_utilization=0.2, tool_backlog=0, active_sessions=0,
                    step_latency=0.1, now=0.0)
    # c becomes 5x slower than the median
    for _ in range(20):
        r.heartbeat("c", kv_utilization=0.2, tool_backlog=0, active_sessions=0,
                    step_latency=0.5, now=1.0)
    drained = r.update_stragglers(now=1.0)
    assert drained == ["c"]
    s = make_session(0.0, [Round(1000, 8, None, 0.0)], ideal_time=1.0)
    rid = r.place(s, now=1.0)
    assert rid in ("a", "b")
    # affinity: same session returns to its home replica
    rid2 = r.place(s, now=2.0)
    assert rid2 == rid


@pytest.mark.parametrize("path", ["drain", "failure"])
def test_router_resets_kv_accounting_on_leave_and_failure(path):
    """Sessions handed back by a dying/draining replica must not carry
    phantom block accounting into their next placement — the new engine's
    refcount invariants would trip on the stale kv_blocks."""
    from repro.core.session import KVState, Round, make_session
    r = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    e1 = _mini_engine()
    r.register("a", e1, now=0.0)
    r.heartbeat("a", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=0.0)
    s = make_session(0.0, [Round(200_000, 16, None, 0.0)], ideal_time=1.0)
    assert r.place(s, now=0.0) == "a"
    # run a few ticks so the session holds blocks mid-prefill
    now = 0.0
    for _ in range(3):
        el, _ = e1.tick(now)
        now += max(el, 0.05)
    assert s.kv_blocks > 0 and s.resident_len > 0
    if path == "drain":
        moved = r.leave("a", now=1.0)
        assert s in moved
    else:
        failed = r.check_failures(now=100.0)
        assert failed == ["a"]
        assert s in r.requeued
        moved = r.requeued
    for m in moved:
        assert m.kv_blocks == 0 and m.resident_len == 0
        assert m.kv_state == KVState.NONE
    # the old engine is detached too: no stale membership or leases, so a
    # heartbeat-recovered replica can keep ticking without tripping
    assert s not in e1.active and s not in e1.waiting
    assert e1.blocks.free == e1.blocks.total
    e1.check_invariants()
    e1.tick(2.0)
    # re-placement on a fresh replica keeps the new invariants intact
    e2 = _mini_engine()
    r.register("b", e2, now=101.0)
    r.heartbeat("b", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=101.0)
    assert r.place(s, now=101.0) == "b"
    e2.tick(0.0)
    e2.check_invariants()


def test_router_leave_drops_host_tier_entries():
    """Draining a replica must clear engine-side host-tier occupancy for the
    sessions handed back — a reused engine would otherwise carry orphaned
    host entries and trip its host-occupancy invariant."""
    from repro.core.session import KVState, Round, make_session
    r = ClusterRouter()
    e1 = _mini_engine()
    r.register("a", e1, now=0.0)
    r.heartbeat("a", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=0.0)
    s = make_session(0.0, [Round(20_000, 16, None, 0.0)], ideal_time=1.0)
    assert r.place(s, now=0.0) == "a"
    now = 0.0
    for _ in range(3):
        el, _ = e1.tick(now)
        now += max(el, 0.05)
    assert s.kv_blocks > 0
    # demote to the host tier, as pin revocation under pressure would
    assert e1._offload_kv(s, now)
    assert e1.host.holds(s.sid) and s.meta.get("host_tier")
    moved = r.leave("a", now=now + 1.0)
    assert s in moved
    assert s.kv_state == KVState.NONE and "host_tier" not in s.meta
    assert not e1.host.holds(s.sid)
    assert e1.host.used_blocks == 0
    e1.check_invariants()


def test_router_failover_cancels_inflight_tools():
    """A session detached mid-tool must not be resumed by the old
    (heartbeat-recovered) replica: its queued/running tool is cancelled,
    so the replica ticking past the tool's end leaves the session — now
    owned by another replica — untouched."""
    from repro.core.session import Phase, Round, make_session
    r = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    e1 = _mini_engine()
    r.register("a", e1, now=0.0)
    r.heartbeat("a", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=0.0)
    s = make_session(0.0, [Round(2_000, 8, "t", 50.0),
                           Round(1_000, 8, None, 0.0)], ideal_time=1.0)
    assert r.place(s, now=0.0) == "a"
    now = 0.0
    while s.phase != Phase.TOOL and now < 100.0:
        el, _ = e1.tick(now)
        now += max(el, 0.05)
    assert s.phase == Phase.TOOL
    round_before = s.cur_round
    assert r.check_failures(now=100.0) == ["a"]
    assert s in r.requeued
    # recovered replica ticks past the tool's completion time
    for t in (101.0, 160.0, 200.0):
        e1.tick(t)
    assert s.cur_round == round_before and s.phase == Phase.TOOL
    assert e1.tools.active == 0
    e1.check_invariants()


def test_router_midtool_victim_completes_on_new_replica():
    """A session evacuated mid-tool has already finished its round's decode
    quantum; re-placement must reset the round progress so the new replica
    re-decodes and re-runs the cancelled tool — without the reset the
    session lands in DECODING with decoded == decode_tokens, a 0-token
    quantum no batch picks up and no timer finishes (livelock)."""
    from repro.core.session import Phase, Round, make_session
    r = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    e1 = _mini_engine()
    r.register("a", e1, now=0.0)
    r.heartbeat("a", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=0.0)
    s = make_session(0.0, [Round(2_000, 16, "t", 50.0),
                           Round(1_000, 8, None, 0.0)], ideal_time=1.0)
    assert r.place(s, now=0.0) == "a"
    now = 0.0
    while s.phase != Phase.TOOL and now < 100.0:
        el, _ = e1.tick(now)
        now += max(el, 0.05)
    assert s.phase == Phase.TOOL
    assert s.decoded == s.rounds[0].decode_tokens   # quantum complete
    assert r.check_failures(now=100.0) == ["a"]
    assert s in r.requeued
    assert s.decoded == 0 and not s.first_token_seen
    e2 = _mini_engine()
    r.register("b", e2, now=100.0)
    r.heartbeat("b", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=100.0)
    assert r.dispatch_requeued(now=100.0) == 1
    assert r.session_home[s.sid] == "b"
    from repro.engine.engine import run_sim
    finished, _ = run_sim(e2, [], max_time=1e4)
    assert s in finished                            # re-decode + re-run tool
    # per-round TTFT stays one entry per round: the stale entry measured on
    # the dead replica was dropped with the round-progress reset
    assert len(s.ttfts) == len(s.rounds)
    e2.check_invariants()


def test_router_elastic_join_leave():
    r = ClusterRouter()
    e1 = _mini_engine()
    r.register("a", e1, now=0.0)
    r.heartbeat("a", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=0.0)
    from repro.core.session import Round, make_session
    s = make_session(0.0, [Round(1000, 8, None, 0.0)], ideal_time=1.0)
    assert r.place(s, now=0.0) == "a"
    moved = r.leave("a", now=1.0)
    assert s in moved
    assert r.place(s, now=1.0) is None       # no replicas left
    e2 = _mini_engine()
    r.register("b", e2, now=2.0)
    r.heartbeat("b", kv_utilization=0.1, tool_backlog=0, active_sessions=0,
                step_latency=0.1, now=2.0)
    assert r.place(s, now=2.0) == "b"


# ---------------------------------------------------------------------------
# cross-replica prefix reuse (radix digests in heartbeats)
# ---------------------------------------------------------------------------

def _family_session(n_shared=8, n_tail=0, fam="fam", tag=None):
    from repro.core.session import Round, make_session
    s = make_session(0.0, [Round(32 * (n_shared + n_tail), 8, None, 0.0)],
                     ideal_time=1.0)
    s.meta["prefix_hashes"] = [((fam, i), 32) for i in range(n_shared)] + \
        [((tag, i), 32) for i in range(n_tail)]
    return s


def _digest_for(fam="fam", blocks=8, depth=8, hits=0, queries=0):
    from repro.kvcache.radix import chunk_key_digest
    return {"v": 1, "indexed_blocks": blocks, "queries": queries,
            "hits": hits, "hit_tokens": 0,
            "anchors": {chunk_key_digest((fam, 0)): {
                "blocks": blocks, "depth": depth,
                "hits": hits, "queries": queries,
                "hit_rate": hits / max(1, queries)}}}


def _beat(r, rid, *, util=0.1, digest=None, now=0.0):
    r.heartbeat(rid, kv_utilization=util, tool_backlog=0, active_sessions=0,
                step_latency=0.1, radix_digest=digest, now=now)


def test_router_prefix_match_pulls_family_spill_guard_overrides():
    """A replica advertising the session's anchor wins placement despite a
    mild load disadvantage; past the spill threshold the pull is off and
    the family overflows by plain pressure score."""
    r = ClusterRouter(RouterConfig())
    for rid in ("a", "b"):
        r.register(rid, None, now=0.0)
    _beat(r, "a", util=0.05)
    _beat(r, "b", util=0.25, digest=_digest_for())   # warmer but has the prefix
    s = _family_session()
    assert r.place(s, now=0.0) == "b"
    # hot home: same digest, utilization past prefix_spill_kv -> overflow
    s2 = _family_session()
    _beat(r, "b", util=r.cfg.prefix_spill_kv + 0.02, digest=_digest_for())
    assert r.place(s2, now=1.0) == "a"


def test_router_empty_digest_scores_neutrally():
    """No digest, an empty-anchor digest, and a non-matching digest must
    all produce the identical score — digest-blind replicas are never
    penalized (or favored) for what they don't advertise."""
    r = ClusterRouter(RouterConfig())
    r.register("a", None, now=0.0)
    _beat(r, "a", util=0.2)
    s = _family_session()
    ra = r.replicas["a"]
    base = r._score(ra, s)
    _beat(r, "a", util=0.2, digest={"v": 0, "anchors": {}})
    assert r._score(ra, s) == base
    _beat(r, "a", util=0.2, digest=_digest_for(fam="other"))
    assert r._score(ra, s) == base
    # and a session with no prefix metadata is unaffected by a rich digest
    from repro.core.session import Round, make_session
    plain = make_session(0.0, [Round(256, 8, None, 0.0)], ideal_time=1.0)
    _beat(r, "a", util=0.2)
    base_plain = r._score(ra, plain)
    _beat(r, "a", util=0.2, digest=_digest_for())
    assert r._score(ra, plain) == base_plain


def test_router_failure_clears_stale_digest():
    """A failed replica's advertised prefix state died with its pool: the
    digest is invalidated with the failure, so requeued sessions are
    re-placed by load, not by a ghost index."""
    r = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    e1, e2 = _mini_engine(), _mini_engine()
    r.register("a", e1, now=0.0)
    r.register("b", e2, now=0.0)
    _beat(r, "a", util=0.1, digest=_digest_for())
    _beat(r, "b", util=0.1)
    s = _family_session()
    assert r.place(s, now=0.0) == "a"
    _beat(r, "b", util=0.1, now=10.0)            # only b stays alive
    assert r.check_failures(now=10.0) == ["a"]
    assert r.replicas["a"].radix_digest is None
    assert s in r.requeued
    assert r.dispatch_requeued(now=10.0) == 1
    assert r.session_home[s.sid] == "b"


def test_router_reregistered_replica_starts_digest_clean():
    r = ClusterRouter(RouterConfig())
    r.register("a", None, now=0.0)
    _beat(r, "a", util=0.1, digest=_digest_for())
    assert r.replicas["a"].radix_digest is not None
    r.leave("a", now=1.0)
    assert "a" not in r.replicas                 # digest gone with the replica
    r.register("a", None, now=2.0)
    assert r.replicas["a"].radix_digest is None
    # an omitted-digest heartbeat keeps it clean (refresh-wholesale)
    _beat(r, "a", util=0.1, now=2.5)
    assert r.replicas["a"].radix_digest is None
    s = _family_session()
    ra = r.replicas["a"]
    assert r._prefix_match_frac(ra, s) == 0.0


def test_router_cluster_prefix_stats_aggregates_alive_digests():
    r = ClusterRouter(RouterConfig(heartbeat_timeout=5.0))
    for rid in ("a", "b", "c"):
        r.register(rid, None, now=0.0)
    _beat(r, "a", digest=_digest_for(fam="f1", hits=3, queries=4))
    _beat(r, "b", digest=_digest_for(fam="f2", hits=1, queries=2))
    _beat(r, "c")                                # digest-blind
    stats = r.cluster_prefix_stats()
    assert set(stats["replicas"]) == {"a", "b"}
    assert stats["cluster_prefix_hits"] == 4
    assert stats["cluster_prefix_queries"] == 6
    assert stats["cluster_prefix_hit_rate"] == pytest.approx(4 / 6)
    # a dead replica's digest leaves the aggregate with the failure
    _beat(r, "b", digest=_digest_for(fam="f2", hits=1, queries=2), now=10.0)
    assert set(r.check_failures(now=10.0)) == {"a", "c"}
    stats = r.cluster_prefix_stats()
    assert set(stats["replicas"]) == {"b"}       # a/c failed
    assert stats["cluster_prefix_hit_rate"] == pytest.approx(1 / 2)


def test_router_digest_placement_co_locates_family_end_to_end():
    """Two live engines behind the router: once the builder's replica
    advertises the family anchor, siblings land there and attach to the
    shared blocks instead of recomputing them."""
    r = ClusterRouter(RouterConfig())
    engines = {"a": _mini_engine(), "b": _mini_engine()}
    for rid, e in engines.items():
        r.register(rid, e, now=0.0)
        _beat(r, rid, util=0.0)
    builder = _family_session(n_shared=16, n_tail=2, tag="t0")
    home = r.place(builder, now=0.0)
    now = 0.0
    for _ in range(8):                           # build + index the prefix
        for rid, e in engines.items():
            el, _ = e.tick(now)
            _beat(r, rid, util=e.telem.kv_utilization,
                  digest=e.radix_digest(), now=now)
            now += max(el, 0.05)
    assert engines[home].radix.inserted_blocks >= 16
    sibs = [_family_session(n_shared=16, n_tail=2, tag=f"t{i+1}")
            for i in range(3)]
    for s in sibs:
        assert r.place(s, now=now) == home
    for _ in range(30):
        el, _ = engines[home].tick(now)
        now += max(el, 0.05)
        if all(s.meta.get("radix_hit") for s in sibs):
            break
    assert all(s.meta.get("radix_hit") for s in sibs)
    assert engines[home].prefix_hit_tokens >= 3 * 16 * 32
    for e in engines.values():
        e.check_invariants()
