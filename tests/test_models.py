"""Per-architecture smoke tests (reduced same-family configs) + semantic
consistency checks (decode == teacher-forced forward, sliding windows,
softcaps, chunked vs direct prefill)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model_zoo
from repro.models.transformer import KVCache, lm_forward, lm_step

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32):
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "image_patches":
        batch["embeds"] = 0.01 * jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "whisper":
        batch["frames"] = 0.01 * jax.random.normal(
            KEY, (B, 24, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one grad step on a reduced config; shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    batch = _batch_for(cfg)
    logits = model_zoo.forward(cfg, params, batch)
    n_front = cfg.n_frontend_tokens if cfg.frontend == "image_patches" else 0
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    def loss_fn(p):
        lg = model_zoo.forward(cfg, p, batch).astype(jnp.float32)
        return jnp.mean(jax.scipy.special.logsumexp(lg, -1) - lg[..., 0])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    B, S = 2, 16
    n_front = cfg.n_frontend_tokens if cfg.frontend == "image_patches" else 0
    batch = _batch_for(cfg, B, S)
    _, cache = model_zoo.prefill(cfg, params, batch)
    max_len = 48 + n_front
    if cfg.family in ("dense", "moe"):
        full = model_zoo.cache_zeros(cfg, B, max_len, jnp.float32)
        full = KVCache(full.k.at[:, :, :cache.k.shape[2]].set(cache.k),
                       full.v.at[:, :, :cache.v.shape[2]].set(cache.v))
        cache = full
        pos = jnp.full((B,), S + n_front, jnp.int32)
    elif cfg.family == "zamba2":
        full = model_zoo.cache_zeros(cfg, B, max_len, jnp.float32)
        cache = dataclasses.replace(
            full, mamba=cache.mamba,
            k=full.k.at[:, :, :cache.k.shape[2]].set(cache.k),
            v=full.v.at[:, :, :cache.v.shape[2]].set(cache.v))
        pos = jnp.full((B,), S, jnp.int32)
    elif cfg.family == "whisper":
        from repro.models.whisper import EncDecCache
        full = EncDecCache.zeros(cfg, B, 48, 24, jnp.float32)
        cache = EncDecCache(
            full.self_k.at[:, :, :cache.self_k.shape[2]].set(cache.self_k),
            full.self_v.at[:, :, :cache.self_v.shape[2]].set(cache.self_v),
            cache.cross_k, cache.cross_v)
        pos = jnp.full((B,), S, jnp.int32)
    else:
        pos = jnp.full((B,), S, jnp.int32)
    logits, cache = model_zoo.decode(
        cfg, params, cache, jnp.ones((B,), jnp.int32), pos)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-27b", "qwen2.5-3b"])
def test_decode_matches_teacher_forced(arch):
    """Greedy incremental decode reproduces the full-forward logits."""
    cfg = get_config(arch).reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    B, S = 1, 24
    toks = jnp.asarray(
        np.random.default_rng(1).integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits = lm_forward(cfg, params, toks)
    cache = model_zoo.cache_zeros(cfg, B, S + 4, jnp.float32)
    # feed tokens one at a time
    outs = []
    for t in range(S):
        lg, cache = model_zoo.decode(cfg, params, cache, toks[:, t],
                                     jnp.full((B,), t, jnp.int32))
        outs.append(np.asarray(lg))
    inc = np.stack(outs, axis=1)
    np.testing.assert_allclose(inc, np.asarray(full_logits), atol=2e-4,
                               rtol=1e-4)


def test_chunked_prefill_matches_direct():
    """lm_step over chunks == one-shot forward (chunked prefill semantics)."""
    cfg = get_config("llama3.2-1b").reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    B, S, C = 1, 32, 8
    toks = jnp.asarray(
        np.random.default_rng(2).integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    want = lm_forward(cfg, params, toks)
    cache = model_zoo.cache_zeros(cfg, B, S, jnp.float32)
    got = []
    for c0 in range(0, S, C):
        pos = jnp.arange(c0, c0 + C, dtype=jnp.int32)[None]
        lg, cache = lm_step(cfg, params, cache, toks[:, c0:c0 + C], pos)
        got.append(np.asarray(lg))
    got = np.concatenate(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(want), atol=2e-4, rtol=1e-4)


def test_sliding_window_limits_context():
    """gemma2-style local layers must ignore tokens beyond the window."""
    cfg = get_config("gemma2-27b").reduced(
        n_layers=2, layer_pattern=("local",), sliding_window=8)
    params = model_zoo.init(cfg, KEY, jnp.float32)
    rng = np.random.default_rng(3)
    t1 = rng.integers(1, cfg.vocab_size, (1, 32))
    t2 = t1.copy()
    t2[0, :8] = rng.integers(1, cfg.vocab_size, 8)   # perturb far history
    l1 = model_zoo.forward(cfg, params, {"tokens": jnp.asarray(t1, jnp.int32)})
    l2 = model_zoo.forward(cfg, params, {"tokens": jnp.asarray(t2, jnp.int32)})
    # last position attends only to the final window -> identical logits
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-5)


def test_final_softcap_bounds_logits():
    cfg = get_config("gemma2-27b").reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    logits = model_zoo.forward(cfg, params, _batch_for(cfg))
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_param_counts_full_scale():
    """Full-scale configs land near their nameplate sizes."""
    expect = {"gemma2-27b": (26e9, 30e9), "internlm2-20b": (17e9, 22e9),
              "qwen2.5-3b": (2.5e9, 4e9), "llama3.2-1b": (1.0e9, 1.6e9),
              "dbrx-132b": (115e9, 140e9), "rwkv6-1.6b": (1.3e9, 2.2e9),
              "llava-next-34b": (30e9, 37e9),
              "granite-moe-3b-a800m": (2.6e9, 4e9),
              "zamba2-1.2b": (0.9e9, 1.7e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params_less_than_total():
    cfg = get_config("dbrx-132b")
    assert cfg.param_count(active_only=True) < 0.45 * cfg.param_count()
