"""Hypothesis property tests over the whole engine: random multi-round
workloads under every policy must terminate with invariants intact, exact
event bookkeeping, and no lost sessions. Plus the ServingAPI layer."""
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:            # hermetic env: seeded-example fallback
    from _hypo import given, settings, st

from repro.configs.qwen3_coder_30b import CONFIG as QWEN3
from repro.core import events as ev
from repro.core.session import Round, make_session
from repro.engine.backend import SimBackend
from repro.engine.engine import Engine, EngineConfig, run_sim
from repro.models.perf_model import H100

BACKEND = SimBackend(QWEN3, H100)

session_strategy = st.lists(
    st.tuples(st.integers(100, 40_000),          # new_input_tokens
              st.integers(8, 200),               # decode_tokens
              st.sampled_from(["terminal", "file_editor", "test_runner"]),
              st.floats(0.1, 60.0)),             # tool seconds
    min_size=1, max_size=5)


@settings(max_examples=20, deadline=None)
@given(st.lists(session_strategy, min_size=1, max_size=8),
       st.sampled_from(["fcfs", "mars", "infercept", "continuum-dy"]),
       st.integers(2_000, 12_000))
def test_random_workloads_terminate_with_invariants(specs, policy, blocks):
    eng = Engine(EngineConfig(total_kv_blocks=blocks, cpu_slots=4),
                 policy, BACKEND)
    sessions = []
    for i, rounds_spec in enumerate(specs):
        rounds = [Round(a, d, (k if j < len(rounds_spec) - 1 else None),
                        (t if j < len(rounds_spec) - 1 else 0.0))
                  for j, (a, d, k, t) in enumerate(rounds_spec)]
        sessions.append(make_session(i * 1.0, rounds, ideal_time=1.0))
    finished, _ = run_sim(eng, sessions, max_time=1e6, max_ticks=400_000)
    eng.check_invariants()
    # conservation: every session either finished or was capacity-rejected
    assert len(finished) + len(eng.rejected) == len(sessions)
    assert eng.blocks.free == eng.blocks.total          # everything released
    assert eng.blocks.pinned == 0
    # event bookkeeping: submits == first tokens == ends, per finished session
    for s in finished:
        n = len(s.rounds)
        assert len(s.ttfts) == n
        assert s.finish_time >= s.arrival_time
    # paired tool events
    assert eng.bus.counts.get(ev.TOOL_START, 0) == \
        eng.bus.counts.get(ev.TOOL_END, 0)
    # paired pin accounting (every pin was eventually unpinned or evicted)
    pins = eng.bus.counts.get(ev.PIN, 0)
    unpins = eng.bus.counts.get(ev.UNPIN, 0)
    revoked = sum(1 for e in eng.bus.log if e.kind == ev.EVICT and
                  e.data.get("reason") in ("pin_revoked", "reclaim"))
    assert pins == unpins + revoked


def test_serving_api_session_continuity():
    """ServingAPI: one job_id spans rounds; futures resolve with tokens and
    per-round TTFT; KV continuity shows up as a warm second round."""
    from repro.configs.registry import get_config
    from repro.core.events import EventBus
    from repro.engine.api import ChatRequest, ServingAPI
    from repro.engine.engine import run_live
    from repro.engine.jax_runner import JaxBackend
    from repro.engine.tools import RealToolExecutor

    cfg = get_config("llama3.2-1b").reduced()
    backend = JaxBackend(cfg, max_slots=2, max_len=256)
    bus = EventBus()
    tools = RealToolExecutor(cpu_slots=1, bus=bus)
    eng = Engine(EngineConfig(total_kv_blocks=2 * 255 // 32, token_budget=128,
                              max_decode_batch=2, decode_granularity=4,
                              cpu_slots=1),
                 "mars", backend, bus=bus, tool_exec=tools)
    api = ServingAPI(eng)
    effects = []
    f1 = api.submit(ChatRequest(job_id="job-A", prompt_tokens=list(range(2, 50)),
                                max_tokens=8,
                                tool_call={"kind": "t",
                                           "fn": lambda: effects.append(1)}))
    f2 = api.submit(ChatRequest(job_id="job-A", prompt_tokens=list(range(2, 20)),
                                max_tokens=8, final=True))
    session = api._jobs["job-A"]
    finished, _ = run_live(eng, [], timeout=90)
    tools.shutdown()
    r1 = f1.result(timeout=5)
    r2 = f2.result(timeout=5)
    assert len(r1["tokens"]) == 8 and len(r2["tokens"]) == 8
    assert effects == [1]                      # the tool really ran
    assert session.phase.value == "finished"
    assert r2["ttft"] is not None
    assert api.active_jobs() == []


def test_serving_api_rejects_oversized_job():
    from repro.engine.api import ChatRequest, ServingAPI
    eng = Engine(EngineConfig(total_kv_blocks=10), "mars", BACKEND)
    api = ServingAPI(eng)
    fut = api.submit(ChatRequest(job_id="big", prompt_tokens=[1] * 50_000,
                                 max_tokens=8, final=True))
    with pytest.raises(RuntimeError, match="rejected"):
        fut.result(timeout=1)
