import os
import sys

# Tests and benches see ONE device; only launch/dryrun sets the 512-device
# flag (per assignment). A couple of distributed tests spawn their own
# subprocess with more host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
