"""Bench-regression gate tests: the figure-coverage rule (a bench that
emits rows without any baselines entry must FAIL the gate, not silently
pass) plus the committed baselines file staying in sync with the figures
the CI smokes actually emit."""
import importlib.util
import json
import os

REPO = os.path.join(os.path.dirname(__file__), "..")

_spec = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(REPO, "scripts", "check_bench.py"))
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _spec_with(figures):
    return {"checks": [{"figure": f, "name": "x", "field": "v",
                        "baseline": 1, "min": 0} for f in figures]}


def test_uncovered_figure_fails_the_gate(tmp_path):
    out = tmp_path / "bench.json"
    out.write_text(json.dumps({"rows": [
        {"figure": "covered", "name": "x", "v": 1},
        {"figure": "brand_new_bench", "name": "y", "v": 2},
    ]}))
    spec_path = tmp_path / "baselines.json"
    spec_path.write_text(json.dumps(_spec_with(["covered"])))
    rc = check_bench.main([str(out), "--baselines", str(spec_path)])
    assert rc == 1


def test_covered_figures_pass(tmp_path):
    out = tmp_path / "bench.json"
    out.write_text(json.dumps({"rows": [
        {"figure": "covered", "name": "x", "v": 1},
        {"figure": "covered", "name": "extra_row", "v": 9},  # rows beyond
    ]}))                                  # the checked one stay allowed
    spec_path = tmp_path / "baselines.json"
    spec_path.write_text(json.dumps(_spec_with(["covered"])))
    rc = check_bench.main([str(out), "--baselines", str(spec_path)])
    assert rc == 0


def test_coverage_failures_lists_each_missing_figure():
    rows = [{"figure": "a"}, {"figure": "b"}, {"figure": "b"}]
    out = check_bench.coverage_failures(_spec_with(["a"]), rows)
    assert len(out) == 1 and "'b'" in out[0]
    assert check_bench.coverage_failures(_spec_with(["a", "b"]), rows) == []


def test_committed_baselines_cover_every_ci_smoke_figure():
    """Every figure the six CI dry smokes emit has at least one committed
    check — the coverage rule holds on the real pipeline config."""
    with open(os.path.join(REPO, "benchmarks", "baselines.json")) as f:
        spec = json.load(f)
    checked = {c["figure"] for c in spec["checks"]}
    # one figure per bench wired into scripts/ci.sh
    assert {"kernels", "kvcache", "paged_runner", "swap_stream",
            "cross_replica", "tiered_store"} <= checked
