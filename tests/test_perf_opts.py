"""Correctness of the beyond-paper perf optimizations (EXPERIMENTS.md §Perf):
each must be mathematically exact vs the baseline path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import model_zoo
from repro.models.transformer import (WindowedKVCache, lm_decode,
                                      lm_decode_windowed, lm_forward)

KEY = jax.random.PRNGKey(3)


def test_chunked_ce_exact():
    from repro.distributed.steps import chunked_cross_entropy, cross_entropy
    cfg = get_config("llama3.2-1b").reduced()
    params = model_zoo.init(cfg, KEY, jnp.float32)
    toks = jax.random.randint(KEY, (2, 64), 1, cfg.vocab_size)
    tgts = jnp.roll(toks, -1, axis=1)
    logits = lm_forward(cfg, params, toks)
    hidden = lm_forward(cfg, params, toks, return_hidden=True)
    a = cross_entropy(logits, tgts)
    b = chunked_cross_entropy(cfg, params, hidden, tgts, chunk=16)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


def test_windowed_decode_matches_full_cache():
    """Ring-buffered local layers must reproduce the full-cache decode
    exactly, including once the context exceeds the window."""
    cfg = get_config("gemma2-27b").reduced(
        n_layers=4, sliding_window=8, layer_pattern=("local", "global"))
    params = model_zoo.init(cfg, KEY, jnp.float32)
    B, T = 1, 24                       # 3x the window
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T), 1, cfg.vocab_size)

    full = model_zoo.cache_zeros(cfg, B, T, jnp.float32)
    Lp = cfg.n_layers // 2
    ws = WindowedKVCache(
        jnp.zeros((Lp, B, cfg.sliding_window, cfg.n_kv_heads, cfg.head_dim_)),
        jnp.zeros((Lp, B, cfg.sliding_window, cfg.n_kv_heads, cfg.head_dim_)),
        jnp.zeros((Lp, B, T, cfg.n_kv_heads, cfg.head_dim_)),
        jnp.zeros((Lp, B, T, cfg.n_kv_heads, cfg.head_dim_)))
    for t in range(T):
        pos = jnp.full((B,), t, jnp.int32)
        lg_full, full = lm_decode(cfg, params, full, toks[:, t], pos)
        lg_win, ws = lm_decode_windowed(cfg, params, ws, toks[:, t], pos)
        np.testing.assert_allclose(np.asarray(lg_win), np.asarray(lg_full),
                                   atol=3e-4, rtol=1e-4,
                                   err_msg=f"step {t}")


def test_moe_replicated_same_math():
    """Replicated-expert sharding changes placement, not math (specs only)."""
    import os
    from repro.distributed.sharding import param_specs
    from tests.test_distributed import _FakeMesh
    cfg = get_config("granite-moe-3b-a800m")
    mesh = _FakeMesh({"data": 16, "model": 16})
    params = jax.eval_shape(lambda k: model_zoo.init(cfg, k, jnp.bfloat16), KEY)
    base = param_specs(cfg, mesh, params)
    os.environ["REPRO_OPT"] = "moe_replicated"
    try:
        opt = param_specs(cfg, mesh, params)
    finally:
        os.environ.pop("REPRO_OPT")
    # only the expert weights change; they become fully replicated
    def check(path, a, b):
        names = "/".join(str(getattr(p, "key", p)) for p in path)
        if "moe" in names and any(x in names for x in ("w_gate", "w_up", "w_down")):
            assert all(ax is None for ax in b), (names, b)
        else:
            assert a == b, names
    jax.tree_util.tree_map_with_path(check, base, opt)
