"""Dry-run / roofline plumbing tests: the HLO collective parser, skip rules,
and a real lower+compile of one cell on a small host-device mesh."""
import os
import subprocess
import sys

import pytest

from repro.configs.registry import ARCH_IDS, cell_is_supported
from repro.configs.shapes import SHAPES
from repro.launch.dryrun import collective_bytes


def test_collective_parser_counts_output_shapes():
    hlo = """
  %ar = bf16[128,256]{1,0} all-reduce(bf16[128,256] %x), replica_groups={}
  %ag.1 = f32[64]{0} all-gather(f32[16] %y), dimensions={0}
  %noise = bf16[4,4]{1,0} add(bf16[4,4] %a, bf16[4,4] %b)
  %a2a = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-to-all(bf16[8,8] %p, bf16[8,8] %q)
  %done = f32[64]{0} all-reduce-done(f32[64] %ar2)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 2
    assert out["all-gather"] == 64 * 4
    assert out["all-to-all"] == 2 * 8 * 8 * 2
    assert out["total"] == out["all-reduce"] + out["all-gather"] + out["all-to-all"]


def test_skip_rules_match_assignment():
    skipped = [a for a in ARCH_IDS if not cell_is_supported(a, "long_500k")[0]]
    assert set(skipped) == {"internlm2-20b", "qwen2.5-3b", "llama3.2-1b",
                            "whisper-tiny", "llava-next-34b", "dbrx-132b",
                            "granite-moe-3b-a800m"}
    for a in ("gemma2-27b", "rwkv6-1.6b", "zamba2-1.2b"):
        assert cell_is_supported(a, "long_500k")[0]
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_is_supported(a, s)[0]


def test_dryrun_cell_compiles_on_small_mesh():
    """Full-scale llama decode_32k lowers+compiles on a 2x4 host mesh and
    reports flops/bytes/collectives (subprocess: needs 8 host devices)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch.dryrun import run_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = run_cell("llama3.2-1b", "decode_32k", mesh=mesh, verbose=False)
assert r["status"] == "ok", r.get("error")
assert r["flops"] > 0 and r["collectives"]["total"] >= 0
print("CELL-OK")
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "CELL-OK" in out.stdout, out.stdout + out.stderr


def test_roofline_analysis_fields():
    from repro.launch.roofline import analyze
    rec = {"status": "ok", "arch": "llama3.2-1b", "shape": "train_4k",
           "n_devices": 256, "flops": 5e13, "bytes_accessed": 5e12,
           "collectives": {"total": 7e10}}
    row = analyze(rec)
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["terms_s"]["memory"] == pytest.approx(5e12 / 819e9)
    assert 0 < row["useful_ratio"] < 2
    assert row["lever"]
